"""Fig. 13 (c): EVE false-positive rate vs bits-per-record.

Baselines available offline: a naive per-key Bloom filter over every key
in each deleted range (the paper's motivating strawman, §4.3) at the same
total memory.  (Grafite/REncoder/bloomRF are not reimplemented; the paper
reports EVE beating them by >20% — our EVE-vs-naive gap bounds the same
effect.)  Protocol follows the paper: random ranges of length 100, then
random queries; FPR measured on keys covered by no range.
"""

from __future__ import annotations

import numpy as np

from repro.core import BloomBits, EVE, RAEConfig

from .harness import SCALE, emit

U = 1 << 28
RANGE_LEN = 100


def run():
    n_ranges = 140_000 * SCALE
    n_queries = 100_000
    rng = np.random.default_rng(0)
    los = rng.integers(0, U // 2 - RANGE_LEN, size=n_ranges) \
        .astype(np.uint64)
    for bpk in (6, 10, 14):
        # EVE: bpk bits per RANGE RECORD.
        eve = EVE(RAEConfig(capacity=20_000 * SCALE, bits_per_record=bpk,
                            key_universe=U))
        for i, lo in enumerate(los.tolist()):
            eve.insert_range(lo, lo + RANGE_LEN, i + 1)
        # Naive: same TOTAL memory, but must insert every covered key.
        total_bits = eve.nbytes * 8
        naive = BloomBits(total_bits, 4)
        for lo in los[:max(1, n_ranges // 20)].tolist():  # 5% sample =
            naive.insert(np.arange(lo, lo + RANGE_LEN, dtype=np.uint64))
        naive_load = 20  # extrapolation factor for the fill ratio
        # Queries: keys in the guaranteed-empty upper half.
        q = rng.integers(U // 2 + RANGE_LEN, U, size=n_queries) \
            .astype(np.uint64)
        fpr_eve = float(eve.maybe_deleted_batch(
            q, np.full(n_queries, 1, dtype=np.uint64)).mean())
        # Naive FPR extrapolated to full load: p = (1-e^{-kn/m})^k.
        k_h = 4
        n_keys = n_ranges * RANGE_LEN
        m = total_bits
        fpr_naive = float((1 - np.exp(-k_h * n_keys / m)) ** k_h)
        fpr_naive_measured = float(naive.might_contain(q).mean())
        emit(f"fig13c/bpk{bpk}/eve", 0.0, f"fpr={fpr_eve:.4f}")
        emit(f"fig13c/bpk{bpk}/naive_per_key", 0.0,
             f"fpr_model={fpr_naive:.4f} "
             f"fpr_at_5pct_load={fpr_naive_measured:.4f}")


if __name__ == "__main__":
    run()
