"""Fig. 13 (a)/(b): LSM-DRtree vs LSM-Rtree tail latency; index query cost
with and without EVE.

(a) point-lookup I/O percentiles (p50/p95/p99) for GLORAN vs GLORAN0
    (LSM-Rtree global index) under growing range-delete counts;
(b) per-query global-index I/O for LSM-R / LSM-DR / LSM-DR + EVE.
"""

from __future__ import annotations

import numpy as np

from repro.core import (GloranConfig, GloranIndex, IOStats, LSMDRTreeConfig,
                        RAEConfig)

from .harness import SCALE, emit

U = 1 << 22


def _build(use_drtree: bool, use_eve: bool, n_deletes: int, seed=0):
    g = GloranIndex(GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=2048, size_ratio=10),
        eve=RAEConfig(capacity=50_000, key_universe=U),
        use_eve=use_eve, use_drtree=use_drtree))
    rng = np.random.default_rng(seed)
    for seq in range(1, n_deletes + 1):
        lo = int(rng.integers(0, U - 256))
        g.range_delete(lo, lo + int(rng.integers(16, 256)), seq)
    return g, rng


def run():
    for n_del in (20_000 * SCALE, 100_000 * SCALE):
        # (a) tail latency: per-query index I/O distribution.
        for name, dr in (("lsm_rtree", False), ("lsm_drtree", True)):
            g, rng = _build(dr, False, n_del)
            samples = []
            for _ in range(400):
                k = int(rng.integers(0, U))
                s = int(rng.integers(0, n_del))
                r0 = g.io.reads
                g.is_deleted(k, s)
                samples.append(g.io.reads - r0)
            p50, p95, p99 = np.percentile(samples, [50, 95, 99])
            emit(f"fig13a/n{n_del}/{name}", 0.0,
                 f"io_p50={p50:.1f} io_p95={p95:.1f} io_p99={p99:.1f}")
        # (b) index query cost with/without EVE (valid keys dominate).
        for name, eve in (("lsm_dr", False), ("lsm_dr_eve", True)):
            g, rng = _build(True, eve, n_del, seed=1)
            keys = rng.integers(0, U, size=3000).astype(np.uint64)
            seqs = np.full(3000, n_del + 10, dtype=np.uint64)  # post-delete
            r0 = g.io.reads
            g.is_deleted_batch(keys, seqs)
            emit(f"fig13b/n{n_del}/{name}", 0.0,
                 f"io_per_query={(g.io.reads - r0) / 3000:.4f}")


if __name__ == "__main__":
    run()
