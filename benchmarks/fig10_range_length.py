"""Fig. 10: range-delete length sweep — throughput, range-delete latency,
disk size (space amplification), memory footprint.  Balanced workload."""

from __future__ import annotations

from .harness import SCALE, WorkloadMix, emit, preload, run_workload, \
    standard_tree

STRATEGIES = ("decomp", "lookup_delete", "scan_delete", "lrr", "gloran")
U = 1 << 21


def run():
    n_pre = 120_000 * SCALE
    for length in (16, 128, 1024):
        for strat in STRATEGIES:
            n_ops = 12_000 * SCALE
            if strat == "decomp" and length == 1024:
                n_ops = 4_000 * SCALE  # tombstone flood; keep bounded
            tree = standard_tree(strat, universe=U)
            preload(tree, n_pre, U)
            mix = WorkloadMix(lookup=0.475, update=0.475,
                              range_delete=0.05, range_delete_len=length,
                              universe=U)
            res = run_workload(tree, n_ops, mix, seed=length)
            emit(f"fig10/len{length}/{strat}",
                 1e6 / max(res.ops_per_sec, 1e-9),
                 f"modeled_ops_s={res.modeled_ops_per_sec():.0f} "
                 f"ops_s={res.ops_per_sec:.0f} "
                 f"rdel_us={res.us_per_op('range_delete'):.1f} "
                 f"disk_mb={res.disk_bytes / 1e6:.1f} "
                 f"mem_mb={res.memory_bytes / 1e6:.2f}")


if __name__ == "__main__":
    run()
