"""Fig. 9: throughput across workloads x range-delete ratios x methods.

Workloads: lookup-heavy (90/10), balanced (50/50), update-heavy (10/90);
range-delete ratio replaces part of the updates.  Derived column:
ops/s | lookup I/O per op | range-delete I/O per op.
"""

from __future__ import annotations

from .harness import SCALE, WorkloadMix, emit, preload, run_workload, \
    standard_tree

STRATEGIES = ("decomp", "lookup_delete", "scan_delete", "lrr", "gloran")
WORKLOADS = {
    "lookup_heavy": (0.9, 0.1),
    "balanced": (0.5, 0.5),
    "update_heavy": (0.1, 0.9),
}
U = 1 << 21


def run():
    n_pre = 150_000 * SCALE
    n_ops = 20_000 * SCALE
    for wname, (lk, up) in WORKLOADS.items():
        for rd_pct in (0, 5, 10):
            rd = rd_pct / 100.0
            for strat in STRATEGIES:
                tree = standard_tree(strat, universe=U)
                preload(tree, n_pre, U)
                mix = WorkloadMix(lookup=lk, update=max(0.0, up - rd),
                                  range_delete=rd, range_delete_len=128,
                                  universe=U)
                res = run_workload(tree, n_ops, mix, seed=rd_pct)
                emit(f"fig9/{wname}/rd{rd_pct}/{strat}",
                     1e6 / max(res.ops_per_sec, 1e-9),
                     f"modeled_ops_s={res.modeled_ops_per_sec():.0f} "
                     f"ops_s={res.ops_per_sec:.0f} "
                     f"lookup_io={res.io_per_op('lookup'):.3f} "
                     f"rdel_io={res.io_per_op('range_delete'):.3f}")


if __name__ == "__main__":
    run()
