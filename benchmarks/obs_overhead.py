"""Tracer overhead benchmark -> BENCH_obs.json.

The observability layer's contract is "free when off, cheap when on":
the instrumented request path must stay within noise of an
uninstrumented one under the default ``NullTracer``, and within 10%
with a recording ``Tracer`` installed.  This bench quantifies both on
the engine's batched-lookup hot path:

  disabled   the off switch cannot be compared against pre-
             instrumentation code in-tree, so it is measured two ways:
             (a) the direct cost of one ``obs.span()`` call under the
             ``NullTracer`` (timed over 200k calls), projected onto a
             measured batch — spans/batch x null-span cost / batch
             wall, and (b) for context, the same projection for the
             recording tracer's span cost.
  enabled    median wall ratio, recording ``Tracer`` vs ``NullTracer``,
             interleaved reps on identical probe batches.

    PYTHONPATH=src python benchmarks/obs_overhead.py

Env:
    REPRO_OBS_BENCH_SMOKE=1    ~10 s subset (scripts/check.sh)
    REPRO_BENCH_OUT=path.json  output path (default BENCH_obs.json)

Acceptance (gated in scripts/check.sh): projected disabled overhead
<= 2% of batch wall; enabled wall ratio <= 1.10.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro import obs
from repro.engine import Engine, EngineConfig, OpBatch
from repro.lsm import LSMConfig

SMOKE = os.environ.get("REPRO_OBS_BENCH_SMOKE") == "1"
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_obs.json")

UNIVERSE = 1 << 22
PRELOAD = 30_000 if SMOKE else 100_000
BATCH = 4096
ROUNDS = 4 if SMOKE else 8
REPS = 5 if SMOKE else 9
NULL_CALLS = 200_000


def make_engine() -> tuple[Engine, np.ndarray]:
    eng = Engine(
        num_shards=4, strategy="gloran",
        lsm_config=LSMConfig(buffer_capacity=4096, key_size=16,
                             value_size=48, key_universe=UNIVERSE),
        config=EngineConfig(partition="range", pipeline=True, procs=0,
                            cache_blocks=0, kernel_min_batch=32,
                            kernel_min_areas=32, kernel_min_filter=512))
    keys = np.random.default_rng(5).integers(
        0, UNIVERSE, size=PRELOAD).astype(np.uint64)
    for i in range(0, len(keys), 8192):
        kk = keys[i:i + 8192]
        eng.put_batch(kk, kk + np.uint64(1))
    eng.flush()
    return eng, keys


def run_lookups(eng: Engine, probes: np.ndarray) -> float:
    t0 = time.perf_counter()
    for p in probes:
        eng.submit(OpBatch.gets(p)).get_results()
    return time.perf_counter() - t0


def span_cost(tracer) -> float:
    """Median per-call seconds of ``obs.span`` under ``tracer``."""
    prev = obs.get_tracer()
    obs.set_tracer(tracer)
    try:
        samples = []
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(NULL_CALLS):
                with obs.span("bench.noop"):
                    pass
            samples.append((time.perf_counter() - t0) / NULL_CALLS)
            if isinstance(tracer, obs.Tracer):
                tracer.clear()
        return float(np.median(samples))
    finally:
        obs.set_tracer(prev)


def run() -> dict:
    eng, keys = make_engine()
    rng = np.random.default_rng(99)
    probes = keys[rng.integers(0, len(keys), size=(ROUNDS + 1, BATCH))]
    run_lookups(eng, probes[:1])  # warm jit + pools

    # Spans per batch: one traced batch, count recorded events.
    with obs.enabled() as tr:
        run_lookups(eng, probes[1:2])
        eng.drain()
    spans_per_batch = len(tr.events())

    # Interleaved enabled/disabled reps on identical probe streams.
    walls = {False: [], True: []}
    for _ in range(REPS):
        for on in (False, True):
            tracer = obs.Tracer() if on else obs.NULL_TRACER
            prev = obs.get_tracer()
            obs.set_tracer(tracer)
            try:
                walls[on].append(run_lookups(eng, probes[1:]))
            finally:
                obs.set_tracer(prev)
    wall_off = float(np.median(walls[False]))
    wall_on = float(np.median(walls[True]))
    batch_wall = wall_off / ROUNDS

    null_cost = span_cost(obs.NULL_TRACER)
    live_cost = span_cost(obs.Tracer())
    projected_off = spans_per_batch * null_cost / batch_wall
    projected_on = spans_per_batch * live_cost / batch_wall

    result = {
        "config": {"preload_entries": PRELOAD, "batch": BATCH,
                   "rounds": ROUNDS, "reps": REPS, "shards": 4,
                   "null_timing_calls": NULL_CALLS, "smoke": SMOKE},
        "spans_per_batch": spans_per_batch,
        "null_span_cost_ns": round(null_cost * 1e9, 2),
        "recording_span_cost_ns": round(live_cost * 1e9, 2),
        "batch_wall_ms": round(batch_wall * 1e3, 3),
        "wall_seconds_disabled": round(wall_off, 4),
        "wall_seconds_enabled": round(wall_on, 4),
        "acceptance": {
            # Off switch: projected fraction of batch wall spent in
            # null spans (direct measurement of the only cost the
            # instrumentation adds when disabled).
            "disabled_projected_overhead_frac": round(projected_off, 5),
            # On switch: measured wall ratio (noisy on shared CI boxes;
            # the projected recording overhead is the stable signal).
            "enabled_wall_ratio": round(wall_on / wall_off, 4),
            "enabled_projected_overhead_frac": round(projected_on, 5),
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    a = result["acceptance"]
    print(f"# wrote {OUT}: {spans_per_batch} spans/batch, null span "
          f"{result['null_span_cost_ns']}ns -> disabled overhead "
          f"{a['disabled_projected_overhead_frac']:.3%} of batch wall; "
          f"enabled ratio {a['enabled_wall_ratio']}x "
          f"(projected {a['enabled_projected_overhead_frac']:.3%})",
          flush=True)
    return result


if __name__ == "__main__":
    run()
