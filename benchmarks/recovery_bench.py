"""Cold-start recovery benchmark -> the ``recovery`` section of
BENCH_engine.json.

Measures what a restart costs as a function of WAL-tail length: build a
durable store, close it, ``recover()`` from the directory, and time the
wall — once per tail length, with and without a snapshot covering the
prefix.  Every recovered store is verified against the original
(probe gets + full scan + level shapes) before its row is published.

    PYTHONPATH=src python benchmarks/recovery_bench.py

Env:
    REPRO_RECOVERY_BENCH_SMOKE=1  small tails (scripts/check.sh)
    REPRO_BENCH_OUT=path.json     output path (default BENCH_engine.json,
                                  merged: other sections are preserved)
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(4)

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig  # noqa: E402
from repro.durable import recover, take_snapshot  # noqa: E402
from repro.engine import Engine, EngineConfig  # noqa: E402
from repro.lsm import LSMConfig  # noqa: E402

SMOKE = os.environ.get("REPRO_RECOVERY_BENCH_SMOKE") == "1"
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_engine.json")

UNIVERSE = 1 << 22
BATCH = 4096
SHARDS = 2
TAIL_BATCHES = (4, 16) if SMOKE else (4, 16, 64)


def cfgs():
    lsm = LSMConfig(buffer_capacity=4096, key_size=16, value_size=48,
                    key_universe=UNIVERSE)
    glo = GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=512, size_ratio=10,
                              key_size=16),
        eve=RAEConfig(capacity=100_000, key_universe=UNIVERSE))
    return lsm, glo


def build_store(wal_dir: str, n_batches: int, *,
                snapshot_at: int | None = None) -> Engine:
    lsm, glo = cfgs()
    cfg = EngineConfig(partition="range", pipeline=False, devices=0,
                       procs=0,
                       wal_dir=wal_dir, fsync="rotate")
    eng = Engine(SHARDS, strategy="gloran", lsm_config=lsm,
                 gloran_config=glo, config=cfg)
    rng = np.random.default_rng(23)
    for i in range(n_batches):
        keys = rng.integers(0, UNIVERSE, size=BATCH).astype(np.uint64)
        eng.put_batch(keys, keys + np.uint64(1))
        if i % 4 == 3:
            lo = int(rng.integers(0, UNIVERSE - 2048))
            eng.range_delete(lo, lo + 2048)
        if snapshot_at is not None and i == snapshot_at:
            take_snapshot(eng)
    return eng


def verify(a: Engine, b: Engine) -> None:
    probes = np.random.default_rng(9).integers(
        0, UNIVERSE, size=4096).astype(np.uint64)
    fa, va = a.get_batch(probes)
    fb, vb = b.get_batch(probes)
    assert np.array_equal(fa, fb) and np.array_equal(va[fa], vb[fb])
    sa = a.range_scan(0, UNIVERSE // 64)
    sb = b.range_scan(0, UNIVERSE // 64)
    assert np.array_equal(sa[0], sb[0]) and np.array_equal(sa[1], sb[1])
    for sha, shb in zip(a.shards, b.shards):
        assert sha.tree.stats()["levels"] == shb.tree.stats()["levels"]


def bench_row(n_batches: int, *, with_snapshot: bool) -> dict:
    tmp = tempfile.mkdtemp(prefix="repro-recovery-")
    try:
        snap_at = (n_batches * 3) // 4 if with_snapshot else None
        eng = build_store(tmp, n_batches, snapshot_at=snap_at)
        entries = eng.num_entries
        eng.close()
        wal_bytes = sum(
            os.path.getsize(os.path.join(root, f))
            for root, _, files in os.walk(tmp) for f in files
            if f.endswith(".wal"))
        t0 = time.perf_counter()
        rec = recover(tmp, config=EngineConfig(procs=0, devices=0,
                                               pipeline=False))
        wall = time.perf_counter() - t0
        verify(eng, rec)
        row = {
            "tail_batches": n_batches,
            "entries": entries,
            "snapshot": with_snapshot,
            "wal_bytes": wal_bytes,
            "frames_replayed": rec.recovery["frames_replayed"],
            "snapshot_loaded": rec.recovery["snapshot_loaded"],
            "recovery_wall_s": round(wall, 4),
            "replay_frames_per_sec": round(
                rec.recovery["frames_replayed"] / wall) if wall else None,
        }
        rec.close()
        return row
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run() -> dict:
    rows = []
    for n in TAIL_BATCHES:
        for with_snapshot in (False, True):
            row = bench_row(n, with_snapshot=with_snapshot)
            rows.append(row)
            print(f"# recovery x{n:3d} batches "
                  f"(snapshot={'y' if with_snapshot else 'n'}): "
                  f"{row['recovery_wall_s']}s, "
                  f"{row['frames_replayed']} frames replayed, "
                  f"{row['wal_bytes'] / 1e6:.1f} MB WAL", flush=True)
    section = {
        "config": {"shards": SHARDS, "batch": BATCH,
                   "fsync": "rotate", "smoke": SMOKE},
        "rows": rows,
        # Cold-start scaling: recovery wall vs WAL-tail length, and the
        # snapshot fast path's effect on the same store.
        "max_recovery_wall_s": max(r["recovery_wall_s"] for r in rows),
        "verified": True,
    }
    doc = {}
    if os.path.exists(OUT):
        try:
            with open(OUT) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}
    doc["recovery"] = section
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {OUT}: recovery section, "
          f"max wall {section['max_recovery_wall_s']}s", flush=True)
    return section


if __name__ == "__main__":
    run()
