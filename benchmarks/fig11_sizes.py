"""Fig. 11: entry-size and data-scale sweeps (GLORAN vs the LRR SOTA).

(a) key size 64/256/1024 B (entry fixed ~1 KB); (b) value size 256/2048 B
(key 64 B); (c) data scale 1e5 vs 4e5 preloaded entries.  Balanced
workload with 5% range deletes, as in the paper.
"""

from __future__ import annotations

from .harness import SCALE, WorkloadMix, emit, preload, run_workload, \
    standard_tree

U = 1 << 21
MIX = WorkloadMix(lookup=0.475, update=0.475, range_delete=0.05,
                  range_delete_len=128, universe=U)


def _one(tag, strat, key_size, value_size, n_pre, n_ops):
    tree = standard_tree(strat, universe=U, key_size=key_size,
                         value_size=value_size)
    preload(tree, n_pre, U)
    res = run_workload(tree, n_ops, MIX, seed=1)
    emit(f"fig11/{tag}/{strat}", 1e6 / max(res.ops_per_sec, 1e-9),
         f"modeled_ops_s={res.modeled_ops_per_sec():.0f} "
         f"ops_s={res.ops_per_sec:.0f} "
         f"lookup_io={res.io_per_op('lookup'):.3f}")


def run():
    n_pre, n_ops = 100_000 * SCALE, 15_000 * SCALE
    for k in (64, 256, 1024):
        for s in ("lrr", "gloran"):
            _one(f"key{k}", s, k, 1024 - k, n_pre, n_ops)
    for v in (256, 2048):
        for s in ("lrr", "gloran"):
            _one(f"val{v}", s, 64, v, n_pre, n_ops)
    for scale_n in (100_000, 400_000):
        for s in ("lrr", "gloran"):
            _one(f"scale{scale_n}", s, 256, 768, scale_n * SCALE, n_ops)


if __name__ == "__main__":
    run()
