"""Batched range-op benchmark -> BENCH_range.json.

Compares the engine's batched range-scan path (one routed
``range_scan_batch`` call per batch of ranges: shared memtable snapshot
per shard, vectorized slice bounds, REMIX-style sorted-view merges, one
batched GLORAN validity pass on the interval-kernel hook) against the
seed-style per-call loop (one ``LSMTree.range_scan`` Python call per
range) on the same data and range distribution.  Also reports batched
range deletes vs the per-call delete loop.

    PYTHONPATH=src python benchmarks/range_bench.py

Env:
    REPRO_RANGE_BENCH_SMOKE=1   ~10 s subset (scripts/check.sh)
    REPRO_BENCH_SCALE=full      ~4x workload
    REPRO_BENCH_OUT=path.json   output path (default BENCH_range.json)

Engines use range partitioning: scans clip to overlapping slabs, so a
batch of scans spreads across shards instead of broadcasting — the
partition scheme a range-heavy workload would pick.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import Engine, EngineConfig
from repro.lsm import LSMConfig, LSMTree

SMOKE = os.environ.get("REPRO_RANGE_BENCH_SMOKE") == "1"
SCALE = 4 if os.environ.get("REPRO_BENCH_SCALE") == "full" else 1
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_range.json")

UNIVERSE = 1 << 22
SCAN_LEN = 512
RDEL_LEN = 128

if SMOKE:
    PRELOAD = 20_000
    N_RDEL = 400
    SHARDS = (1, 4)
    BATCHES = (64, 256)  # 256: the batching win fully amortized (gated)
    ROUNDS = 3
else:
    PRELOAD = 60_000 * SCALE
    N_RDEL = 1500 * SCALE
    SHARDS = (1, 2, 4)
    BATCHES = (16, 64, 256)
    ROUNDS = 5


def lsm_cfg() -> LSMConfig:
    return LSMConfig(buffer_capacity=4096, key_size=16, value_size=48,
                     key_universe=UNIVERSE)


def gloran_cfg() -> GloranConfig:
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=256, size_ratio=10,
                              key_size=16),
        eve=RAEConfig(capacity=100_000, key_universe=UNIVERSE))


def engine_cfg() -> EngineConfig:
    # Lower launch gate than engine_bench: EVE's negative probes prune
    # most scan candidates before the index, so the surviving batches
    # are small but still worth one launch per level per scan batch.
    return EngineConfig(partition="range", cache_blocks=16384, procs=0,
                        kernel_min_batch=32, kernel_min_areas=64,
                        kernel_min_filter=4096)


def preload(store, seed: int) -> None:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, UNIVERSE, size=PRELOAD).astype(np.uint64)
    for i in range(0, len(keys), 8192):
        kk = keys[i:i + 8192]
        store.put_batch(kk, kk + np.uint64(1))
    for _ in range(N_RDEL):
        lo = int(rng.integers(0, UNIVERSE - RDEL_LEN - 1))
        store.range_delete(lo, lo + RDEL_LEN)


def scan_batches(batch: int, rounds: int, seed: int) -> list:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(rounds + 1):
        los = rng.integers(0, UNIVERSE - SCAN_LEN - 1, size=batch)
        out.append([(int(lo), int(lo) + SCAN_LEN) for lo in los])
    return out


def bench_scan_loop(tree: LSMTree, batch: int) -> float:
    """Seed-style baseline: one ``range_scan`` Python call per range."""
    batches = scan_batches(batch, ROUNDS, seed=51)
    for lo, hi in batches[0]:
        tree.range_scan(lo, hi)  # warm
    t0 = time.perf_counter()
    for ranges in batches[1:]:
        for lo, hi in ranges:
            tree.range_scan(lo, hi)
    return ROUNDS * batch / (time.perf_counter() - t0)


def bench_scan_engine(eng: Engine, batch: int) -> dict:
    batches = scan_batches(batch, ROUNDS, seed=51)
    eng.range_scan_batch(batches[0])  # warm caches + jit
    r0 = eng.io_reads
    k0 = eng.kernel_counters
    t0 = time.perf_counter()
    n_entries = 0
    for ranges in batches[1:]:
        for keys, _ in eng.range_scan_batch(ranges):
            n_entries += len(keys)
    dt = time.perf_counter() - t0
    n = ROUNDS * batch
    return {
        "scans_per_sec": n / dt,
        "entries_per_scan": n_entries / n,
        "io_reads_per_scan": (eng.io_reads - r0) / n,
        "interval_kernel_calls":
            eng.kernel_counters.interval_calls - k0.interval_calls,
    }


def bench_rdel(make, batch: int = 64) -> dict:
    """Batched vs per-call range deletes on fresh stores."""
    rng = np.random.default_rng(77)
    spans = [(int(lo), int(lo) + RDEL_LEN)
             for lo in rng.integers(0, UNIVERSE - RDEL_LEN - 1,
                                    size=batch)]
    eng = make()
    t0 = time.perf_counter()
    eng.range_delete_batch(spans)
    dt_batch = time.perf_counter() - t0
    eng = make()
    t0 = time.perf_counter()
    for lo, hi in spans:
        eng.range_delete(lo, hi)
    dt_loop = time.perf_counter() - t0
    return {"batched_rdels_per_sec": batch / dt_batch,
            "loop_rdels_per_sec": batch / dt_loop,
            "speedup": dt_loop / dt_batch}


def run() -> dict:
    tree = LSMTree(lsm_cfg(), "gloran", gloran_cfg())
    preload(tree, seed=5)
    rows = []
    base = {b: bench_scan_loop(tree, b) for b in BATCHES}
    for b, v in base.items():
        print(f"# per-call scan loop  batch={b}: {v:,.0f} scans/s",
              flush=True)
    for shards in SHARDS:
        eng = Engine(num_shards=shards, strategy="gloran",
                     lsm_config=lsm_cfg(), gloran_config=gloran_cfg(),
                     config=engine_cfg())
        preload(eng, seed=5)
        for batch in BATCHES:
            m = bench_scan_engine(eng, batch)
            row = {
                "shards": shards,
                "batch": batch,
                "engine_scans_per_sec": round(m["scans_per_sec"], 1),
                "per_call_scans_per_sec": round(base[batch], 1),
                "speedup_vs_per_call_loop": round(
                    m["scans_per_sec"] / base[batch], 2),
                "entries_per_scan": round(m["entries_per_scan"], 1),
                "io_reads_per_scan": round(m["io_reads_per_scan"], 3),
                "interval_kernel_calls": m["interval_kernel_calls"],
            }
            rows.append(row)
            print(f"# engine x{shards} batch={batch}: "
                  f"{m['scans_per_sec']:,.0f} scans/s "
                  f"({row['speedup_vs_per_call_loop']}x), "
                  f"ik={m['interval_kernel_calls']}", flush=True)
    rdel = bench_rdel(lambda: Engine(
        num_shards=4, strategy="gloran", lsm_config=lsm_cfg(),
        gloran_config=gloran_cfg(), config=engine_cfg()))
    print(f"# range_delete_batch x64: {rdel['speedup']:.2f}x vs loop",
          flush=True)
    target = [r for r in rows if r["shards"] == max(SHARDS)]
    result = {
        "config": {
            "preload_entries": PRELOAD,
            "preload_range_deletes": N_RDEL,
            "universe": UNIVERSE,
            "scan_len": SCAN_LEN,
            "rounds": ROUNDS,
            "strategy": "gloran",
            "partition": "range",
            "smoke": SMOKE,
        },
        "per_call_scans_per_sec": {str(b): round(v, 1)
                                   for b, v in base.items()},
        "rows": rows,
        "range_delete_batch": {k: round(v, 2) for k, v in rdel.items()},
        "acceptance": {
            "min_speedup_max_shards": min(
                (r["speedup_vs_per_call_loop"] for r in target),
                default=None),
            "max_speedup_max_shards": max(
                (r["speedup_vs_per_call_loop"] for r in target),
                default=None),
            # The regression gates.  Multi-shard rows depend on the
            # host's core budget (the CI box floats between 2 and many
            # cores; threads past the core count add overhead without
            # wall wins) and the per-call baseline itself got ~10x
            # faster once the memtable snapshot was cached — so the
            # max-shard minimum above is reported for visibility but
            # the gated figures are core-count independent: every
            # single-shard row (the batched machinery end-to-end, no
            # threading) and the best fully-amortized row.
            "min_speedup_single_shard": min(
                (r["speedup_vs_per_call_loop"] for r in rows
                 if r["shards"] == 1), default=None),
            "best_speedup_any_shards": max(
                (r["speedup_vs_per_call_loop"] for r in rows),
                default=None),
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}: min {max(SHARDS)}-shard scan speedup = "
          f"{result['acceptance']['min_speedup_max_shards']}x, best = "
          f"{result['acceptance']['best_speedup_any_shards']}x", flush=True)
    return result


if __name__ == "__main__":
    run()
