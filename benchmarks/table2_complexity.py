"""Table 2 validation: point-lookup I/O complexity vs #range deletes Q.

The paper's core claim: LRR lookups cost O(Q * k/B + L*phi + L) — LINEAR
in Q — while GLORAN costs O(log^2(Q/F)) for obsolete keys, O(eps*log^2)
for valid keys, and O(phi*log(N/F)) for absent keys (never touching the
index).  We sweep Q and report measured I/O per lookup for the three key
classes V (valid), N (non-existent), O (obsoleted).
"""

from __future__ import annotations

import numpy as np

from .harness import SCALE, emit, preload, standard_tree

U = 1 << 22


def run():
    n_pre = 200_000 * SCALE
    rng = np.random.default_rng(0)
    for q in (1_000, 10_000, 50_000):
        for strat in ("lrr", "gloran"):
            tree = standard_tree(strat, universe=U)
            preload(tree, n_pre, U)
            # Issue Q range deletes of length 64 over the lower half of
            # the key space; upper half stays valid.
            half = U // 2
            los = rng.integers(0, half - 64, size=q).astype(np.uint64)
            for lo in los.tolist():
                tree.range_delete(lo, lo + 64)
            tree.flush()

            def probe(keys, cls):
                r0 = tree.io.reads
                found, _ = tree.get_batch(keys)
                per = (tree.io.reads - r0) / len(keys)
                emit(f"table2/q{q}/{strat}/lookup_{cls}", 0.0,
                     f"io_per_lookup={per:.4f} found={found.mean():.2f}")

            # V: keys in the untouched upper half that exist.
            upper = rng.integers(half, U, size=4000).astype(np.uint64)
            fu, _ = tree.get_batch(upper)
            if fu.any():
                probe(upper[fu][:1500], "V")
            # N: absent keys (above the universe used for preload).
            probe(rng.integers(U, U * 2, size=1500).astype(np.uint64), "N")
            # O: keys inside deleted ranges (mostly obsolete/absent).
            probe((los[:1500] + 32).astype(np.uint64), "O")


if __name__ == "__main__":
    run()
