"""Shared benchmark harness: preloaded trees, timed runs, CSV rows.

Every benchmark maps to one paper table/figure (see DESIGN.md §8) and
emits ``name,us_per_call,derived`` rows; ``derived`` carries the paper's
headline metric for that artifact (I/O per op, normalized throughput,
FPR, ...).  REPRO_BENCH_SCALE=full enlarges workloads ~10x.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.baselines import WorkloadMix, make_tree, run_workload

SCALE = 10 if os.environ.get("REPRO_BENCH_SCALE") == "full" else 1
ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def preload(tree, n: int, universe: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    step = 8192
    for _ in range(0, n, step):
        keys = rng.integers(0, universe, size=step).astype(np.uint64)
        tree.put_batch(keys, keys * np.uint64(31) + np.uint64(7))


def standard_tree(strategy: str, universe: int = 1 << 22, **kw):
    return make_tree(strategy, buffer_capacity=4096, size_ratio=10,
                     universe=universe, **kw)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
