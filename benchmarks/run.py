"""Benchmark entry point: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig9,table2]``
prints ``name,us_per_call,derived`` CSV rows (also saved to
benchmarks/results.csv).  REPRO_BENCH_SCALE=full for ~10x workloads.
"""

from __future__ import annotations

import argparse
import time

from . import (engine_bench, fig9_throughput, fig10_range_length,
               fig11_sizes, fig13_eve_fpr, fig13_index, kernels_bench,
               table2_complexity, table3_range_lookup)
from .harness import ROWS

MODULES = {
    "fig9": fig9_throughput,
    "fig10": fig10_range_length,
    "fig11": fig11_sizes,
    "table2": table2_complexity,
    "fig13_index": fig13_index,
    "fig13_eve": fig13_eve_fpr,
    "table345": table3_range_lookup,
    "kernels": kernels_bench,
    "engine": engine_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    picks = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in picks:
        mod = MODULES[name]
        print(f"# --- {name} ---", flush=True)
        t1 = time.time()
        mod.run()
        print(f"# {name} done in {time.time() - t1:.1f}s", flush=True)
    with open("benchmarks/results.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in ROWS:
            f.write(f"{r[0]},{r[1]:.3f},{r[2]}\n")
    print(f"# total {time.time() - t0:.1f}s, {len(ROWS)} rows")


if __name__ == "__main__":
    main()
