"""Sharded batched query engine benchmark -> BENCH_engine.json.

Sweeps shards x batch size x range-delete ratio and compares the
engine's batched lookup path (fused cascade kernel over device-resident
filter state, with the Bloom + interval per-level stage as fallback,
block cache) against the seed's per-key ``LSMTree.get`` Python loop on
the same data and probe distribution.  Probes are drawn from the
inserted key population (serving-style: schedulers look up sessions
that exist), so the GLORAN validity stage — where the interval kernel
runs — sees real candidate batches.

A second sweep (``cascade_sweep``) isolates the cascade itself: lookup
throughput vs range-delete ratio (0/1/5/20%), fused-cascade vs the
per-level kernel path on identical data, reporting kernel launches and
host->device upload bytes per lookup.  Its acceptance figure gates
cascade >= 1.5x over the per-level path at batch >= 4096.

    PYTHONPATH=src python benchmarks/engine_bench.py

Env:
    REPRO_ENGINE_BENCH_SMOKE=1   ~10 s subset (scripts/check.sh)
    REPRO_BENCH_SCALE=full       ~10x workload
    REPRO_BENCH_OUT=path.json    output path (default BENCH_engine.json)

Kernel launches run in Pallas interpret mode on CPU containers; their
per-launch overhead is real there and amortizes only over large
candidate batches — exactly what the engine's ``kernel_min_batch``
gating encodes.  Rows with ``fused_filters=False`` isolate that cost.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import Engine, EngineConfig
from repro.lsm import LSMConfig, LSMTree

SMOKE = os.environ.get("REPRO_ENGINE_BENCH_SMOKE") == "1"
SCALE = 10 if os.environ.get("REPRO_BENCH_SCALE") == "full" else 1
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_engine.json")

UNIVERSE = 1 << 22
RANGE_LEN = 128

if SMOKE:
    PRELOAD = 30_000
    SHARDS = (1, 4)
    BATCHES = (1024,)
    RATIOS = (0.1,)
    ROUNDS = 3
else:
    PRELOAD = 100_000 * SCALE
    SHARDS = (1, 2, 4, 8)
    BATCHES = (256, 1024, 4096)
    RATIOS = (0.0, 0.05, 0.2)
    ROUNDS = 5


def lsm_cfg() -> LSMConfig:
    return LSMConfig(buffer_capacity=4096, key_size=16, value_size=48,
                     key_universe=UNIVERSE)


def gloran_cfg() -> GloranConfig:
    # Small index write buffer so range-delete churn actually reaches the
    # on-disk DR-tree levels that the interval kernel serves.
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=512, size_ratio=10,
                              key_size=16),
        eve=RAEConfig(capacity=100_000, key_universe=UNIVERSE))


def engine_cfg(fused: bool = True, cascade: bool = True) -> EngineConfig:
    return EngineConfig(cache_blocks=16384, procs=0,
                        use_bloom_kernel=fused, use_interval_kernel=fused,
                        use_cascade_kernel=fused and cascade,
                        kernel_min_batch=128, kernel_min_areas=64,
                        kernel_min_filter=4096)


def preload(store, keys: np.ndarray, n_rdel: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    for i in range(0, len(keys), 8192):
        kk = keys[i:i + 8192]
        store.put_batch(kk, kk + np.uint64(1))
    for _ in range(n_rdel):
        lo = int(rng.integers(0, UNIVERSE - RANGE_LEN - 1))
        store.range_delete(lo, lo + RANGE_LEN)


def probe_batches(keys: np.ndarray, batch: int, rounds: int,
                  seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return keys[rng.integers(0, len(keys), size=(rounds + 1, batch))]


def bench_scalar(tree: LSMTree, keys: np.ndarray, batch: int,
                 ratio: float) -> float:
    """The seed path: one ``tree.get`` Python call per key."""
    probes = probe_batches(keys, batch, ROUNDS, seed=99)
    for k in probes[0].tolist():
        tree.get(k)  # warm
    t0 = time.perf_counter()
    for p in probes[1:]:
        for k in p.tolist():
            tree.get(k)
    dt = time.perf_counter() - t0
    return ROUNDS * batch / dt


def bench_engine(eng: Engine, keys: np.ndarray, batch: int,
                 rounds: int | None = None) -> dict:
    rounds = ROUNDS if rounds is None else rounds
    probes = probe_batches(keys, batch, rounds, seed=99)
    eng.get_batch(probes[0])  # warm caches + jit
    eng.reset_stats()  # per-measurement latency window (counters cumulate)
    r0, k0 = eng.io_reads, eng.kernel_counters
    c0 = eng.cache_snapshot()
    t0 = time.perf_counter()
    for p in probes[1:]:
        eng.get_batch(p)
    dt = time.perf_counter() - t0
    k1 = eng.kernel_counters
    c1 = eng.cache_snapshot()
    # Deltas only: the engine (and its cache) persists across rows, so
    # lifetime counters would cross-contaminate batch-size measurements.
    hits = c1["hits"] - c0["hits"]
    misses = c1["misses"] - c0["misses"]
    n = rounds * batch
    launches = ((k1.cascade_calls - k0.cascade_calls)
                + (k1.bloom_calls - k0.bloom_calls)
                + (k1.interval_calls - k0.interval_calls))
    hist = eng.stats_.latency.get("get")
    lat = hist.snapshot() if hist is not None else {}
    return {
        "latency_us": {q: lat.get(q)
                       for q in ("p50_us", "p95_us", "p99_us")},
        "ops_per_sec": n / dt,
        "io_reads_per_lookup": (eng.io_reads - r0) / n,
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        "interval_kernel_calls": k1.interval_calls - k0.interval_calls,
        "interval_kernel_queries": k1.interval_queries - k0.interval_queries,
        "bloom_kernel_calls": k1.bloom_calls - k0.bloom_calls,
        "cascade_kernel_calls": k1.cascade_calls - k0.cascade_calls,
        "kernel_launches_per_lookup": launches / n,
        "upload_bytes_per_lookup": (k1.upload_bytes - k0.upload_bytes) / n,
    }


def cascade_sweep() -> list[dict]:
    """Lookup throughput vs range-delete ratio: fused cascade vs the
    per-level kernel path, on identical data and probe streams.

    One shard, block cache off: the sweep isolates the kernel-dispatch
    structure itself.  The tree uses a small buffer / size ratio so the
    data spreads over several SSTable levels (the steady serving shape
    — a leveled LSM mid-compaction, not one fully-compacted run), the
    range deletes land after the last bottom compaction so their
    records are live in the global index, and every level clears the
    per-level gating thresholds: the per-level path launches one bloom
    kernel per SSTable level plus one interval kernel per DR-tree level
    per ``get_batch``, each re-touching filter state, while the cascade
    launches ONCE over the registry's persistent device state.
    Launches and upload bytes per lookup are steady-state deltas over
    the measured rounds (packs uploaded at warmup).
    """
    ratios = (0.2,) if SMOKE else (0.0, 0.01, 0.05, 0.20)
    batches = (4096,) if SMOKE else (1024, 4096)
    lsm = LSMConfig(buffer_capacity=512, size_ratio=4, key_size=16,
                    value_size=48, key_universe=UNIVERSE)
    rng = np.random.default_rng(13)
    rows = []
    for ratio in ratios:
        keys = rng.integers(0, UNIVERSE, size=PRELOAD).astype(np.uint64)
        n_rdel = int(PRELOAD * ratio / 4)
        engines = {}
        for name, cascade in (("cascade", True), ("per_level", False)):
            cfg = EngineConfig(cache_blocks=0, procs=0, use_bloom_kernel=True,
                               use_interval_kernel=True,
                               use_cascade_kernel=cascade,
                               kernel_min_batch=128, kernel_min_areas=64,
                               kernel_min_filter=512)
            eng = Engine(num_shards=1, strategy="gloran",
                         lsm_config=lsm, gloran_config=gloran_cfg(),
                         config=cfg)
            preload(eng, keys, n_rdel, seed=5)
            engines[name] = eng
        for batch in batches:
            row = {"rdel_ratio": ratio, "batch": batch}
            # Long windows + interleaved best-of-3: these are single-
            # process wall measurements on shared hardware whose
            # throughput drifts over seconds, so the two paths are
            # measured alternately (each repetition samples the same
            # machine epoch for both) and each keeps its best rep —
            # otherwise a sustained slow period landing on one side
            # dominates the speedup ratio.
            best: dict = {}
            for _ in range(3):
                for name, eng in engines.items():
                    m = bench_engine(eng, keys, batch,
                                     rounds=6 if SMOKE else 20)
                    if name not in best or \
                            m["ops_per_sec"] > best[name]["ops_per_sec"]:
                        best[name] = m
            for name, m in best.items():
                row[f"{name}_ops_per_sec"] = round(m["ops_per_sec"], 1)
                row[f"{name}_launches_per_lookup"] = round(
                    m["kernel_launches_per_lookup"], 6)
                row[f"{name}_upload_bytes_per_lookup"] = round(
                    m["upload_bytes_per_lookup"], 4)
                row[f"{name}_io_reads_per_lookup"] = round(
                    m["io_reads_per_lookup"], 4)
            row["cascade_speedup_vs_per_level"] = round(
                row["cascade_ops_per_sec"] / row["per_level_ops_per_sec"],
                2)
            rows.append(row)
            print(f"# cascade sweep ratio={ratio} batch={batch}: "
                  f"{row['cascade_ops_per_sec']:,.0f} vs "
                  f"{row['per_level_ops_per_sec']:,.0f} ops/s "
                  f"({row['cascade_speedup_vs_per_level']}x), launches/"
                  f"lookup {row['cascade_launches_per_lookup']:.5f} vs "
                  f"{row['per_level_launches_per_lookup']:.5f}",
                  flush=True)
    return rows


def run() -> dict:
    rng = np.random.default_rng(7)
    rows = []
    scalar_baselines = {}
    for ratio in RATIOS:
        keys = rng.integers(0, UNIVERSE, size=PRELOAD).astype(np.uint64)
        # Delete count scales with ratio x entries so the global index
        # actually cascades through its on-disk levels under churn.
        n_rdel = int(PRELOAD * ratio / 4)
        tree = LSMTree(lsm_cfg(), "gloran", gloran_cfg())
        preload(tree, keys, n_rdel, seed=5)
        base = bench_scalar(tree, keys, max(BATCHES), ratio)
        scalar_baselines[str(ratio)] = round(base, 1)
        print(f"# scalar per-key loop  ratio={ratio}: {base:,.0f} ops/s",
              flush=True)
        variants = [(s, True) for s in SHARDS]
        variants += [(4, False)] if 4 in SHARDS and not SMOKE else []
        for shards, fused in variants:
            eng = Engine(num_shards=shards, strategy="gloran",
                         lsm_config=lsm_cfg(), gloran_config=gloran_cfg(),
                         config=engine_cfg(fused))
            preload(eng, keys, n_rdel, seed=5)
            for batch in BATCHES:
                m = bench_engine(eng, keys, batch)
                row = {
                    "shards": shards,
                    "batch": batch,
                    "rdel_ratio": ratio,
                    "fused_filters": fused,
                    "engine_ops_per_sec": round(m["ops_per_sec"], 1),
                    "scalar_ops_per_sec": round(base, 1),
                    "speedup_vs_per_key_loop": round(
                        m["ops_per_sec"] / base, 2),
                    "io_reads_per_lookup": round(
                        m["io_reads_per_lookup"], 4),
                    "cache_hit_rate": round(m["cache_hit_rate"], 4),
                    "interval_kernel_calls": m["interval_kernel_calls"],
                    "interval_kernel_queries": m["interval_kernel_queries"],
                    "bloom_kernel_calls": m["bloom_kernel_calls"],
                    "cascade_kernel_calls": m["cascade_kernel_calls"],
                    "get_batch_latency_us": m["latency_us"],
                }
                rows.append(row)
                print(f"# engine x{shards} batch={batch} ratio={ratio} "
                      f"fused={fused}: {m['ops_per_sec']:,.0f} ops/s "
                      f"({row['speedup_vs_per_key_loop']}x), "
                      f"ik={m['interval_kernel_calls']} "
                      f"bk={m['bloom_kernel_calls']} "
                      f"ck={m['cascade_kernel_calls']} "
                      f"cache={m['cache_hit_rate']:.2f}", flush=True)
    sweep = cascade_sweep()
    target = [r for r in rows
              if r["shards"] == 4 and r["batch"] >= 1024
              and r["fused_filters"]]
    result = {
        "config": {
            "preload_entries": PRELOAD,
            "universe": UNIVERSE,
            "range_delete_len": RANGE_LEN,
            "rounds": ROUNDS,
            "strategy": "gloran",
            "smoke": SMOKE,
            "probe_distribution": "drawn from inserted keys",
        },
        "scalar_per_key_ops_per_sec": scalar_baselines,
        "rows": rows,
        "cascade_sweep": sweep,
        "acceptance": {
            "min_speedup_4shard_batch_ge_1024": min(
                (r["speedup_vs_per_key_loop"] for r in target),
                default=None),
            "max_speedup_4shard_batch_ge_1024": max(
                (r["speedup_vs_per_key_loop"] for r in target),
                default=None),
            "cascade_min_speedup_vs_perlevel_batch_ge_4096": min(
                (r["cascade_speedup_vs_per_level"] for r in sweep
                 if r["batch"] >= 4096), default=None),
        },
    }
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}: min 4-shard/batch>=1024 speedup = "
          f"{result['acceptance']['min_speedup_4shard_batch_ge_1024']}x, "
          f"cascade vs per-level @>=4096 = "
          f"{result['acceptance']['cascade_min_speedup_vs_perlevel_batch_ge_4096']}x",
          flush=True)
    return result


if __name__ == "__main__":
    run()
