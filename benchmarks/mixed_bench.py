"""Mixed-workload benchmark (fig9 scale) -> BENCH_mixed.json.

The paper's headline scenario: point gets, range scans, and range
deletes arriving interleaved in one op stream (§6, fig9).  Every batch
goes through the typed plan/submit API — ``OpBatch`` construction,
``Planner`` compilation, ``Engine.submit`` — sweeping the get/scan/
range-delete mix ratio, the shard count, and pipelined vs serial shard
execution, with a submit-ahead window of 2 so planning batch n+1
overlaps executing batch n (the serve-loop pattern).

    PYTHONPATH=src python benchmarks/mixed_bench.py

Env:
    REPRO_MIXED_BENCH_SMOKE=1   ~20 s subset (scripts/check.sh)
    REPRO_BENCH_SCALE=full      ~4x workload
    REPRO_BENCH_OUT=path.json   output path (default BENCH_mixed.json)
    REPRO_TRACE_OUT=trace.json  also run one traced 4-shard pipelined
                                pass and export it as Chrome trace-event
                                JSON (load in Perfetto / chrome://tracing)
    REPRO_TRACE_ONLY=1          skip the benchmark sweeps, only export
                                the trace (fast CI artifact mode)

Throughput is reported two ways, extending this repo's existing
device-grounded convention (``WorkloadResult.modeled_ops_per_sec``:
the simulator *counts* block I/Os instead of sleeping on them, so raw
wall-clock alone under-charges I/O):

  wall      raw host wall-clock for both execution modes, measured with
            interleaved repetitions and every kernel shape pre-warmed.
            Python's GIL serializes the simulator's host compute, so on
            a small CI box the pipelined wall number mostly reflects
            thread scheduling, not the architecture — it is published
            for exactly that transparency.
  modeled   the architecture projection the per-shard wall/stall
            ledgers exist to make observable.  Both sides derive from
            the SAME serial run (identical plans, identical per-shard
            work):

              serial    = measured wall + (fleet-total I/Os) * T_IO
                          (one thread executes every shard plan and
                          issues every I/O in sequence)
              pipelined = max over shards of (that shard's busy seconds
                          + its I/Os * T_IO), plus the measured
                          non-overlapped coordination time (plan +
                          merge-back: serial wall minus the shards'
                          busy sum)
                          (each shard runs on its own executor and
                          drives its own I/O queue; the critical path
                          is the busiest shard)

            T_IO = 20us, a 4 KB NVMe random read — the paper's
            hardware, same constant as ``repro.baselines``.

Two acceptance figures:

  modeled   the modeled mixed-batch speedup, pipelined vs serial,
            geomean across mixes at the maximum shard count.
  wall      ``wall_speedup``: MEASURED wall, pipelined multi-device vs
            the serial single-device path, in timed-I/O mode
            (``EngineConfig.io_wait_s = T_IO``: each shard worker
            sleeps out the block I/Os its plan steps charge, so wall
            time includes the store's device waits and those waits
            overlap across shard workers exactly as concurrent NVMe
            queues would).  Each shard is pinned to its own XLA device
            (``shard_devices``; the bench forces
            ``--xla_force_host_platform_device_count`` up front), so
            kernel dispatch compute also overlaps.  This is the gated
            number — the model stays as the projection, the wall clock
            is the proof.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.launch.mesh import ensure_host_devices

SMOKE = os.environ.get("REPRO_MIXED_BENCH_SMOKE") == "1"
# Per-shard XLA devices need host-platform devices forced BEFORE jax's
# backends initialize (first engine build); an XLA_FLAGS count already
# forced by the environment (e.g. CI) is respected.
ensure_host_devices(4)

from repro.core import (GloranConfig, LSMDRTreeConfig, RAEConfig, RTree,  # noqa: E402
                        StagingBuffer, disjointize)
from repro.engine import Engine, EngineConfig, OpBatch  # noqa: E402
from repro.lsm import LSMConfig  # noqa: E402
SCALE = 4 if os.environ.get("REPRO_BENCH_SCALE") == "full" else 1
OUT = os.environ.get("REPRO_BENCH_OUT", "BENCH_mixed.json")
TRACE_OUT = os.environ.get("REPRO_TRACE_OUT", "")
TRACE_ONLY = os.environ.get("REPRO_TRACE_ONLY") == "1"

UNIVERSE = 1 << 22
SCAN_ENTRIES = 256  # target live entries per scan (span = entries/density)
RDEL_LEN = 512  # keyspace span of one range delete (a session-block expiry)
GET_HIT_FRAC = 0.85  # gets probing live keys (serving-registry pattern)
BURST = 64  # mean same-kind arrival burst length
DEPTH = 2  # submit-ahead window (plan n+1 while n executes)
T_IO = 20e-6  # seconds per counted block I/O (4 KB NVMe random read,
#               same device grounding as repro.baselines.WorkloadResult)

# (get, range_scan, range_delete) op fractions per mix.
MIXES = {
    "read_mostly": (0.94, 0.04, 0.02),
    "scan_heavy": (0.65, 0.30, 0.05),
    "delete_heavy": (0.85, 0.05, 0.10),
    "rdel_dominant": (0.25, 0.05, 0.70),
}

if SMOKE:
    PRELOAD = 48_000
    N_RDEL = 300
    SHARDS = (4,)
    BATCH = 8192
    ROUNDS = 1
    REPS = 2
    MIX_KEYS = ("read_mostly", "rdel_dominant")
    N_BUF = 6_000
else:
    PRELOAD = 120_000 * SCALE
    N_RDEL = 1200 * SCALE
    SHARDS = (1, 2, 4)
    BATCH = 8192
    ROUNDS = 2
    REPS = 3
    MIX_KEYS = tuple(MIXES)
    N_BUF = 24_000 * SCALE


def lsm_cfg() -> LSMConfig:
    return LSMConfig(buffer_capacity=4096, key_size=16, value_size=48,
                     key_universe=UNIVERSE)


def gloran_cfg() -> GloranConfig:
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=512, size_ratio=10,
                              key_size=16),
        eve=RAEConfig(capacity=100_000, key_universe=UNIVERSE))


def engine_cfg(pipeline: bool, devices: int | None = None,
               procs: int = 0) -> EngineConfig:
    # Kernel-heavy gating (the TPU-deployment stand-in, as in
    # engine_bench's fused-filter rows): every SSTable filter and
    # DR-tree level probe runs through the Pallas kernels, so the
    # pipeline's win — overlapping per-shard kernel launches instead of
    # queueing them behind one Python thread — is what gets measured.
    # The block cache stays off: its per-block host loop is serial
    # Python, which engine_bench measures separately.  ``devices`` is
    # passed explicitly (not left to REPRO_ENGINE_DEVICES) so the
    # serial baseline is always the single-device path and the
    # pipelined side always pins per-shard devices, whatever the env.
    # scheduler is pinned off: the legacy sweep rows measure the
    # pipelined-vs-serial architecture and must not drift across the CI
    # REPRO_ENGINE_BG_COMPACT matrix cells; the background scheduler
    # has its own dedicated section (``bench_bg_scheduler``).  procs is
    # pinned the same way (default 0, in-process): only
    # ``bench_proc_parallel`` runs worker processes, explicitly.
    return EngineConfig(partition="range", pipeline=pipeline,
                        cache_blocks=0, kernel_min_batch=32,
                        kernel_min_areas=32, kernel_min_filter=512,
                        devices=devices, scheduler=False, procs=procs)


def preload_keys() -> np.ndarray:
    return np.random.default_rng(5).integers(
        0, UNIVERSE, size=PRELOAD).astype(np.uint64)


def make_engine(shards: int, pipeline: bool,
                devices: int | None = None, procs: int = 0) -> Engine:
    eng = Engine(num_shards=shards, strategy="gloran",
                 lsm_config=lsm_cfg(), gloran_config=gloran_cfg(),
                 config=engine_cfg(pipeline, devices, procs))
    keys = preload_keys()
    for i in range(0, len(keys), 8192):
        kk = keys[i:i + 8192]
        eng.put_batch(kk, kk + np.uint64(1))
    rng = np.random.default_rng(6)
    rdels = rng.integers(0, UNIVERSE - RDEL_LEN - 1, size=N_RDEL)
    eng.range_delete_batch([(int(lo), int(lo) + RDEL_LEN)
                            for lo in rdels])
    eng.flush()
    return eng


def mixed_batches(mix: tuple, rounds: int, seed: int) -> list[OpBatch]:
    """One interleaved OpBatch per round (+1 warm), same for every
    engine configuration (seeded).

    Kinds arrive in bursts (geometric, mean ``BURST``) — the serving
    tier's arrival pattern: a scheduler tick issues a run of page
    lookups, a scan job a run of scans, the expiry reaper a run of range
    deletes.  Expected op fractions still match ``mix`` (every burst has
    the same mean length).  Gets probe live keys with probability
    ``GET_HIT_FRAC`` (a registry looks up sessions it registered); scan
    spans are sized to cover ~``SCAN_ENTRIES`` live entries.
    """
    rng = np.random.default_rng(seed)
    probs = np.asarray(mix, dtype=float)
    live = preload_keys()
    scan_len = SCAN_ENTRIES * UNIVERSE // PRELOAD
    out = []
    for _ in range(rounds + 1):
        ops: list[tuple] = []
        while len(ops) < BATCH:
            kind = int(rng.choice(3, p=probs))
            burst = min(int(rng.geometric(1.0 / BURST)),
                        BATCH - len(ops))
            if kind == 0:
                hot = rng.random(burst) < GET_HIT_FRAC
                keys = np.where(hot, live[rng.integers(0, len(live),
                                                       size=burst)],
                                rng.integers(0, UNIVERSE, size=burst)
                                .astype(np.uint64))
                for k in keys.tolist():
                    ops.append(("get", int(k)))
            elif kind == 1:
                for lo in rng.integers(0, UNIVERSE - scan_len - 1,
                                       size=burst).tolist():
                    ops.append(("range_scan", lo, lo + scan_len))
            else:
                for lo in rng.integers(0, UNIVERSE - RDEL_LEN - 1,
                                       size=burst).tolist():
                    ops.append(("range_delete", lo, lo + RDEL_LEN))
        out.append(OpBatch.from_ops(ops))
    return out


def run_batches(eng: Engine, batches: list[OpBatch]) -> float:
    """Submit with a depth-``DEPTH`` in-flight window; returns seconds."""
    t0 = time.perf_counter()
    inflight = []
    for b in batches:
        inflight.append(eng.submit(b))
        if len(inflight) >= DEPTH:
            inflight.pop(0).wait()
    for p in inflight:
        p.wait()
    return time.perf_counter() - t0


def shard_io(eng: Engine) -> list[int]:
    # Surface accessors, not sh.tree.io: proc shards have no local tree
    # (the mirrors update on every reply, so this stays cheap).
    return [sh.io_reads + sh.io_writes for sh in eng.shards]


def _shard_busy(eng: Engine) -> list[float]:
    return [eng.stats_.shard_wall.get(s, 0.0)
            for s in range(eng.num_shards)]


def _measure(eng: Engine, batches: list[OpBatch]):
    """One measured rep; (wall s, per-shard I/Os, per-shard busy s)."""
    io0, b0 = shard_io(eng), _shard_busy(eng)
    dt = run_batches(eng, batches)
    ios = [b - a for a, b in zip(io0, shard_io(eng))]
    busy = [b - a for a, b in zip(b0, _shard_busy(eng))]
    return dt, ios, busy


def bench_cell(mix_name: str, shards: int) -> tuple[dict, dict]:
    """One (mix, shard-count) cell: serial + pipelined rows.

    The two engines are built identically and the measurement reps
    alternate serial/pipelined on the same per-rep batches, so bursty
    host interference (shared CI cores) hits both sides alike; the
    reported speedup is the median per-rep ratio.
    """
    # The serial engine IS the single-device baseline (devices=0, the
    # ungated fallback path); the pipelined engine pins one XLA device
    # per shard.  Both are explicit so the env can't change what this
    # cell compares.
    engines = {False: make_engine(shards, False, devices=0),
               True: make_engine(shards, True, devices=shards)}
    # Twice REPS measured rounds: the first half serves the modeled
    # rows, the second half the timed-I/O wall_speedup reps.
    all_batches = mixed_batches(MIXES[mix_name], ROUNDS * REPS * 2,
                                seed=71)
    # Pre-warm every kernel shape the measured batches will launch on a
    # throwaway engine: jit compilation is process-global and one-time,
    # so neither measured side may pay it (whichever ran first would
    # otherwise foot the whole compile bill and look slower).
    scratch = make_engine(shards, True, devices=shards)
    for b in all_batches:
        scratch.submit(b).wait()
    del scratch
    for eng in engines.values():
        eng.submit(all_batches[0]).wait()  # warm caches + state
    n = ROUNDS * BATCH
    walls: dict = {False: [], True: []}
    m_serial: list[float] = []
    m_piped: list[float] = []
    cell_ios = None
    for rep in range(REPS):
        rep_batches = all_batches[1 + rep * ROUNDS:
                                  1 + (rep + 1) * ROUNDS]
        for pl in (False, True):
            dt, ios, busy = _measure(engines[pl], rep_batches)
            walls[pl].append(dt)
            if pl:
                continue
            # Architecture projection from the serial run's per-shard
            # ledgers (identical plans either way; see module
            # docstring): serial serializes all busy time and all I/O
            # on one thread; pipelined's critical path is the busiest
            # shard plus the non-overlapped plan/merge coordination.
            cell_ios = ios if cell_ios is None else \
                [a + b for a, b in zip(cell_ios, ios)]
            coord = max(dt - sum(busy), 0.0)
            m_serial.append(dt + sum(ios) * T_IO)
            m_piped.append(
                max(b + i * T_IO for b, i in zip(busy, ios)) + coord)
    modeled = {False: m_serial, True: m_piped}
    rows = {}
    for pl in (False, True):
        eng = engines[pl]
        snap = eng.stats()["engine"]
        stall = sum(snap["shard_stall_seconds"].values())
        wall = sum(snap["shard_wall_seconds"].values())
        rows[pl] = {
            "mix": mix_name,
            "shards": shards,
            "pipeline": pl,
            "wall_ops_per_sec": round(REPS * n / sum(walls[pl]), 1),
            "modeled_ops_per_sec": round(REPS * n / sum(modeled[pl]), 1),
            "io_per_op": round(sum(cell_ios) / (REPS * n), 3),
            "max_shard_io_frac": round(max(cell_ios) /
                                       max(sum(cell_ios), 1), 3),
            "shard_stall_frac": round(stall / max(wall + stall, 1e-12),
                                      3),
            # Engine-side batch-latency tails per op class (whole engine
            # lifetime: preload + warm + measured reps) and per-shard
            # plan-execution p99 — the EngineStats histograms the PR's
            # observability layer keeps regardless of tracing.
            "batch_latency_us": {
                op: {q: h[q] for q in ("p50_us", "p95_us", "p99_us")}
                for op, h in snap["latency"].items()},
            "shard_p99_us": {s: h["p99_us"]
                             for s, h in snap["shard_latency"].items()},
        }
    rows[True]["speedup_vs_serial_modeled"] = round(float(np.median(
        [s / p for s, p in zip(m_serial, m_piped)])), 2)
    rows[True]["speedup_vs_serial_wall"] = round(float(np.median(
        [s / p for s, p in zip(walls[False], walls[True])])), 2)
    for pl in (False, True):
        rows[pl]["devices"] = engines[pl].stats()["devices"]["distinct"]
    # -------- measured-wall gate: timed-I/O mode (see module docstring).
    # Same engines (both sides executed identical batches, so their tree
    # states are identical), now sleeping out every charged block I/O.
    # The serial single-device side pays its I/O sequentially; the
    # pipelined per-device side overlaps shard waits and shard kernel
    # compute — THE wall-clock win the model has been projecting, now
    # measured.  Rows at <2 shards carry wall_speedup=None (no overlap
    # to measure).
    wall_speedup = None
    timed: dict = {False: [], True: []}
    if shards >= 2:
        for eng in engines.values():
            eng.config.io_wait_s = T_IO
        for rep in range(REPS):
            rep_batches = all_batches[1 + (REPS + rep) * ROUNDS:
                                      1 + (REPS + rep + 1) * ROUNDS]
            for pl in (False, True):
                dt, _, _ = _measure(engines[pl], rep_batches)
                timed[pl].append(dt)
        wall_speedup = round(float(np.median(
            [s / p for s, p in zip(timed[False], timed[True])])), 2)
        rows[True]["wall_timed"] = {
            "serial_single_device_s": round(sum(timed[False]), 4),
            "pipelined_multi_device_s": round(sum(timed[True]), 4),
            "io_wait_s_per_block": T_IO,
        }
    rows[True]["wall_speedup"] = wall_speedup
    return rows[False], rows[True]


def bench_buffer_insert() -> dict:
    """Delete-path staging microbench: before/after buffer-insert wall.

    The same range-delete record stream runs through the historical
    R-tree write buffer (per-record Python descent + disjointize on
    flush — PR 3's hot spot in delete-heavy mixes) and through the
    columnar ``StagingBuffer`` (burst-sized vectorized appends + the
    incrementally merged ``drain_disjoint``), with identical flush
    points (every ``buffer_capacity`` records).  Both walls include the
    flush-time disjointize, so the ratio is the end-to-end buffer
    absorption speedup the refactor delivers.
    """
    cap = gloran_cfg().index.buffer_capacity
    rng = np.random.default_rng(12)
    los = rng.integers(0, UNIVERSE - RDEL_LEN - 1,
                       size=N_BUF).astype(np.uint64)
    his = los + np.uint64(RDEL_LEN)
    smins = np.zeros(N_BUF, dtype=np.uint64)
    seqs = np.arange(1, N_BUF + 1, dtype=np.uint64)

    t0 = time.perf_counter()
    rt = RTree()
    for lo, hi, s in zip(los.tolist(), his.tolist(), seqs.tolist()):
        rt.insert(lo, hi, 0, s)
        if rt.size >= cap:
            disjointize(rt.extract_all())
            rt.clear()
    rtree_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    sb = StagingBuffer(cap)
    for a0 in range(0, N_BUF, BURST):  # engine plan-step-sized arrivals
        a1 = min(N_BUF, a0 + BURST)
        at = a0
        while at < a1:
            take = min(max(cap - sb.size, 1), a1 - at)
            sb.insert_batch(los[at:at + take], his[at:at + take],
                            smins[at:at + take], seqs[at:at + take])
            at += take
            if sb.size >= cap:
                sb.drain_disjoint()
                sb.clear()
    staging_s = time.perf_counter() - t1
    out = {
        "records": N_BUF,
        "arrival_burst": BURST,
        "buffer_capacity": cap,
        "rtree_buffer_seconds": round(rtree_s, 4),
        "staging_buffer_seconds": round(staging_s, 4),
        "speedup": round(rtree_s / staging_s, 2),
    }
    print(f"# buffer insert x{N_BUF}: rtree {rtree_s:.3f}s -> staging "
          f"{staging_s:.3f}s ({out['speedup']}x)", flush=True)
    return out


def bench_wal_overhead() -> dict:
    """Durability cost: put-heavy mixed throughput, WAL on vs off.

    The same deterministic batch stream (puts with periodic range
    deletes, fresh store both sides so flush points match exactly) runs
    through a no-WAL engine and a WAL engine with group commit +
    ``fsync="batch"`` — the strongest policy, one fsync per submitted
    shard plan.  Interleaved reps, median-of-medians ratio; the
    acceptance gate holds it under 1.25x.
    """
    import shutil
    import tempfile

    rng = np.random.default_rng(17)
    # No smoke reduction: the stream must be long enough to amortize
    # per-stream fixed costs (first-segment creation, warmup batches),
    # or the ratio measures those instead of the steady-state fsync
    # cost.  Full size is ~2 s — cheap enough for check.sh.
    n_batches = 12
    batches = []
    for i in range(n_batches):
        keys = rng.integers(0, UNIVERSE, size=BATCH).astype(np.uint64)
        batches.append(OpBatch.puts(keys, keys + np.uint64(1)))
        if i % 3 == 2:
            lo = int(rng.integers(0, UNIVERSE - RDEL_LEN - 1))
            batches.append(OpBatch.range_deletes([(lo, lo + RDEL_LEN)]))

    def one_pass(wal_dir: str | None) -> tuple[float, dict | None]:
        cfg = EngineConfig(partition="range", pipeline=False, devices=0,
                           wal_dir=wal_dir, fsync="batch",
                           scheduler=False, procs=0)
        eng = Engine(num_shards=2, strategy="gloran",
                     lsm_config=lsm_cfg(), gloran_config=gloran_cfg(),
                     config=cfg)
        t0 = time.perf_counter()
        for b in batches:
            eng.submit(b, pipeline=False).wait()
        wall = time.perf_counter() - t0
        wal = (eng.stats().get("wal") if wal_dir is not None else None)
        eng.close()
        return wall, wal

    walls: dict = {"none": [], "wal": []}
    wal_counters = None
    reps = max(REPS, 3)
    for _ in range(reps):
        for mode in ("none", "wal"):
            tmp = (tempfile.mkdtemp(prefix="repro-walbench-")
                   if mode == "wal" else None)
            try:
                wall, counters = one_pass(tmp)
            finally:
                if tmp is not None:
                    shutil.rmtree(tmp, ignore_errors=True)
            walls[mode].append(wall)
            if counters is not None:
                wal_counters = counters
    nw = float(np.median(walls["none"]))
    ww = float(np.median(walls["wal"]))
    n_ops = sum(len(b) for b in batches)
    out = {
        "ops": n_ops,
        "reps": reps,
        "fsync": "batch",
        "nowal_wall_seconds": round(nw, 4),
        "wal_wall_seconds": round(ww, 4),
        "nowal_ops_per_sec": round(n_ops / nw),
        "wal_ops_per_sec": round(n_ops / ww),
        "overhead_ratio": round(ww / nw, 3),
        "wal_bytes": wal_counters["bytes"],
        "wal_fsyncs": wal_counters["fsyncs"],
        "wal_frames": wal_counters["frames"],
    }
    print(f"# wal overhead: {nw:.3f}s -> {ww:.3f}s "
          f"({out['overhead_ratio']}x, {out['wal_fsyncs']} fsyncs, "
          f"{out['wal_bytes'] / 1e6:.1f} MB logged)", flush=True)
    return out


def bench_flush_materialize() -> dict:
    """Memtable->run materialization: row-tuple loop vs columnar sort.

    The flush path used to materialize the memtable with a Python
    row-tuple comprehension (``np.array([(k, s, t, v) ...])``); it now
    reuses the read path's cached columnar snapshot
    (``LSMTree._mem_sorted`` -> ``build_sstable(presorted=True)``).
    This micro-bench times both materializations over the same
    10^5-entry memtable dict and checks they produce identical runs.
    """
    from repro.lsm.sstable import build_sstable
    from repro.lsm.tree import LSMTree

    n = 100_000
    rng = np.random.default_rng(41)
    keys = rng.permutation(
        rng.integers(0, UNIVERSE, size=n).astype(np.uint64))
    tree = LSMTree(LSMConfig(buffer_capacity=n + 1, key_size=16,
                             value_size=48, key_universe=UNIVERSE),
                   strategy="decomp")
    tree.put_batch(keys, keys + np.uint64(1))
    cfg = tree.config

    def legacy():
        items = np.array([(k, s, t, v)
                          for k, (s, t, v) in tree.mem.items()],
                         dtype=np.uint64)
        return build_sstable(items[:, 0], items[:, 1],
                             items[:, 2].astype(np.uint8), items[:, 3],
                             cfg)

    def columnar():
        tree._mem_snap = None  # charge the sort to this path
        mk, ms, mt, mv = tree._mem_sorted()
        return build_sstable(mk, ms, mt, mv, cfg, presorted=True)

    reps = max(REPS, 3)
    walls = {"legacy": [], "columnar": []}
    runs = {}
    for _ in range(reps):
        for name, fn in (("legacy", legacy), ("columnar", columnar)):
            t0 = time.perf_counter()
            runs[name] = fn()
            walls[name].append(time.perf_counter() - t0)
    np.testing.assert_array_equal(runs["legacy"].keys,
                                  runs["columnar"].keys)
    np.testing.assert_array_equal(runs["legacy"].vals,
                                  runs["columnar"].vals)
    lw = float(np.median(walls["legacy"]))
    cw = float(np.median(walls["columnar"]))
    out = {
        "entries": n,
        "reps": reps,
        "legacy_wall_seconds": round(lw, 4),
        "columnar_wall_seconds": round(cw, 4),
        "speedup": round(lw / cw, 2),
    }
    print(f"# flush materialize x{n}: rows {lw:.3f}s -> columnar "
          f"{cw:.3f}s ({out['speedup']}x)", flush=True)
    return out


def bench_bg_scheduler() -> dict:
    """Background compaction: put tail latency + steady-state uploads.

    A session-expiry stream (each round puts a fresh key window, range-
    deletes the previous one, then reads) runs against two otherwise
    identical engines: inline flushes (``scheduler=False``) vs the
    background scheduler with the Lethe-style tombstone-density trigger.
    Batches submit serially (depth 1) so each batch's wall is exactly
    what it carries:

      inline  the put batch that fills the memtable pays the flush +
              L0 merge (and any cascade) on its own wall — the p99 put
              tail IS the compaction.
      bg      the same put batch only seals the memtable; the flush job
              runs at the next plan's drain point (the read batch),
              and tombstone-dense levels compact proactively, purging
              range-deleted entries at the bottom.

    Reported: per-batch put p99 from the engine latency histograms
    (``stats()["engine"]["latency"]["put"]``) over the measured window,
    and the same window's host->device ``upload_bytes`` delta — the
    proactive purge keeps levels and the GLORAN index small, so the
    read path's device re-packs move fewer bytes at steady state.
    """
    warm, rounds = (1, 5) if SMOKE else (2, 12)
    span_w = 1 << 14  # key window per round; fully expired next round

    def round_batches(r: int, rng) -> list[OpBatch]:
        base = (r * span_w) % (UNIVERSE - 2 * span_w)
        keys = base + rng.choice(span_w, size=4096,
                                 replace=False).astype(np.uint64)
        prev = (base - span_w) % (UNIVERSE - 2 * span_w)
        step = span_w // 32
        rdels = [(int(prev + j * step), int(prev + (j + 1) * step))
                 for j in range(32)]
        reads = base + rng.integers(0, span_w,
                                    size=2048).astype(np.uint64)
        return [OpBatch.puts(keys[:2048], keys[:2048] + np.uint64(1)),
                OpBatch.puts(keys[2048:], keys[2048:] + np.uint64(1)),
                OpBatch.gets(reads),
                OpBatch.range_deletes(rdels)]

    def one_side(background: bool) -> dict:
        cfg = EngineConfig(partition="range", pipeline=False, devices=0,
                           procs=0,
                           kernel_min_batch=32, kernel_min_areas=32,
                           kernel_min_filter=512,
                           scheduler=background, max_frozen=4,
                           tombstone_trigger=0.1 if background
                           else None)
        eng = Engine(num_shards=1, strategy="gloran",
                     lsm_config=lsm_cfg(), gloran_config=gloran_cfg(),
                     config=cfg)
        rng = np.random.default_rng(53)
        for r in range(warm):
            for b in round_batches(r, rng):
                eng.submit(b, pipeline=False).wait()
        eng.reset_stats()
        up0 = eng.kernel_counters.upload_bytes
        for r in range(warm, warm + rounds):
            for b in round_batches(r, rng):
                eng.submit(b, pipeline=False).wait()
        snap = eng.stats()
        put = snap["engine"]["latency"]["put"]
        out = {
            "p99_put_us": put["p99_us"],
            "p50_put_us": put["p50_us"],
            "upload_bytes": eng.kernel_counters.upload_bytes - up0,
            "entries": eng.num_entries,
        }
        if background:
            out["sched"] = snap["sched"]
        eng.close()
        return out

    inline = one_side(False)
    bg = one_side(True)
    out = {
        "rounds": rounds,
        "puts_per_round": 4096,
        "inline_p99_put_us": inline["p99_put_us"],
        "bg_p99_put_us": bg["p99_put_us"],
        "p99_put_improvement": round(
            inline["p99_put_us"] / max(bg["p99_put_us"], 1e-9), 2),
        "inline_p50_put_us": inline["p50_put_us"],
        "bg_p50_put_us": bg["p50_put_us"],
        "inline_upload_bytes": inline["upload_bytes"],
        "bg_upload_bytes": bg["upload_bytes"],
        "upload_bytes_ratio": round(
            bg["upload_bytes"] / max(inline["upload_bytes"], 1), 3),
        "inline_entries": inline["entries"],
        "bg_entries": bg["entries"],
        "sched": bg["sched"],
    }
    print(f"# bg scheduler: put p99 {inline['p99_put_us']:.0f}us -> "
          f"{bg['p99_put_us']:.0f}us ({out['p99_put_improvement']}x), "
          f"uploads {inline['upload_bytes'] / 1e6:.1f}MB -> "
          f"{bg['upload_bytes'] / 1e6:.1f}MB "
          f"(ratio {out['upload_bytes_ratio']}), "
          f"{out['sched']['proactive_jobs']} proactive jobs", flush=True)
    return out


def bench_proc_parallel() -> dict:
    """Process-parallel shard execution: MEASURED compute-bound wall.

    The thread pipeline overlaps I/O waits and kernel dispatch but the
    GIL serializes the simulator's host compute; worker processes are
    the answer for compute-bound stores.  This section measures exactly
    that regime: ``io_wait_s = 0`` (no sleeps to overlap — pure host
    compute), serial in-process single-thread baseline (``procs=0,
    pipeline=False, devices=0``) vs one worker process per shard
    (``procs=shards``, shared-memory columnar transport) at the max
    shard count, identical preloaded stores both sides.

    The measured mix is read-only (gets + scans, no range deletes) so
    store state is byte-identical across the interleaved serial/proc
    reps — every rep re-executes the same plans against the same tree.
    The reported ``proc_wall_speedup`` (median per-rep serial/proc
    ratio) is the gated figure; it scales with the host's cores, so
    ``host_cpus`` rides along and scripts/check.sh gates core-aware
    (>= 1.8x needs >= 4 usable cores; a 1-core box only measures the
    transport overhead, floor-gated for sanity).

    Per-row transport overhead comes from the engine's ``proc`` ledger:
    bytes shipped each way over the shared-memory rings and the
    enqueue->dequeue latency histogram (t_send stamped at token send,
    compared against monotonic clock at worker receive — comparable
    across processes, CLOCK_MONOTONIC system-wide).
    """
    shards = max(SHARDS)
    mix = (0.80, 0.20, 0.0)
    host_cpus = len(os.sched_getaffinity(0))
    rounds, reps = ROUNDS, max(REPS, 3)
    batches = mixed_batches(mix, rounds * reps, seed=83)
    engines = {"serial": make_engine(shards, False, devices=0, procs=0),
               "proc": make_engine(shards, True, devices=0,
                                   procs=shards)}
    for eng in engines.values():  # warm jit (workers compile their own)
        eng.submit(batches[0]).wait()
    walls: dict = {"serial": [], "proc": []}
    for rep in range(reps):
        rep_batches = batches[1 + rep * rounds:1 + (rep + 1) * rounds]
        for side in ("serial", "proc"):
            walls[side].append(run_batches(engines[side], rep_batches))
    n_ops = rounds * BATCH
    speedup = round(float(np.median(
        [s / p for s, p in zip(walls["serial"], walls["proc"])])), 2)
    st = engines["proc"].stats()
    t = st["proc"]
    dq = t["dequeue_latency_us"]
    rows = []
    for side in ("serial", "proc"):
        w = float(np.median(walls[side]))
        row = {
            "mode": side,
            "shards": shards,
            "workers": shards if side == "proc" else 0,
            "io_wait_s": 0.0,
            "wall_seconds": round(sum(walls[side]), 4),
            "wall_ops_per_sec": round(n_ops / w, 1),
        }
        if side == "proc":
            row["transport"] = {
                "requests": t["requests"],
                "bytes_sent": t["bytes_sent"],
                "bytes_received": t["bytes_received"],
                "bytes_per_request": round(
                    (t["bytes_sent"] + t["bytes_received"])
                    / max(t["requests"], 1), 1),
                "dequeue_p50_us": dq["p50_us"],
                "dequeue_p99_us": dq["p99_us"],
            }
        rows.append(row)
    for eng in engines.values():
        eng.close()
    out = {
        "shards": shards,
        "workers": shards,
        "host_cpus": host_cpus,
        "mix": mix,
        "reps": reps,
        "ops_per_rep": n_ops,
        "rows": rows,
        "proc_wall_speedup": speedup,
    }
    print(f"# proc parallel x{shards} workers ({host_cpus} cpus): "
          f"serial {sum(walls['serial']):.3f}s -> proc "
          f"{sum(walls['proc']):.3f}s ({speedup}x), "
          f"{(t['bytes_sent'] + t['bytes_received']) / 1e6:.1f} MB "
          f"shipped, dequeue p99 {dq['p99_us']:.0f}us", flush=True)
    return out


def export_trace(path: str, shards: int = 4) -> dict:
    """One traced {shards}-shard pipelined mixed pass -> Chrome trace.

    The exported JSON loads in Perfetto / chrome://tracing: one track
    per shard worker thread (submit -> plan.compile -> shard.plan ->
    per-step shard.* -> kernel.* spans, engine.collect on the caller
    track).  Also prints the ``analysis.report`` trace digest."""
    from repro import obs
    from repro.analysis.report import trace_report

    eng = make_engine(shards, True)
    batches = mixed_batches(MIXES["scan_heavy"], 4, seed=91)
    eng.submit(batches[0]).wait()  # warm jit outside the trace
    with obs.enabled() as tr:
        run_batches(eng, batches[1:])
        eng.drain()
        tr.export_chrome(path)
        rep = trace_report(tr.chrome_events())
    print(f"# wrote {path}: {len(tr.events())} spans over "
          f"{len(batches) - 1} batches x{shards} shards; wall "
          f"{rep['wall_us']:.0f}us, perfect-overlap bound "
          f"{rep['modeled_us']:.0f}us, stall shares "
          + " ".join(f"s{s}:{r['stall_share']:.0%}"
                     for s, r in rep["shards"].items()), flush=True)
    return rep


def run() -> dict:
    if TRACE_OUT and TRACE_ONLY:
        export_trace(TRACE_OUT)
        return {}
    rows = []
    for mix_name in MIX_KEYS:
        for shards in SHARDS:
            serial, piped = bench_cell(mix_name, shards)
            rows += [serial, piped]
            print(f"# {mix_name:12s} x{shards}: serial "
                  f"{serial['modeled_ops_per_sec']:,.0f} modeled ops/s, "
                  f"pipelined {piped['modeled_ops_per_sec']:,.0f} "
                  f"({piped['speedup_vs_serial_modeled']}x modeled, "
                  f"{piped['speedup_vs_serial_wall']}x wall, "
                  f"{piped['wall_speedup']}x timed wall on "
                  f"{piped['devices']} devices), stall "
                  f"{piped['shard_stall_frac']:.0%}", flush=True)
    max_s = max(SHARDS)
    target = [r for r in rows if r["shards"] == max_s
              and r.get("speedup_vs_serial_modeled")]
    geo = float(np.exp(np.mean(np.log(
        [r["speedup_vs_serial_modeled"] for r in target])))) \
        if target else None
    timed_rows = [r for r in rows if r["shards"] >= 2
                  and r.get("wall_speedup") is not None]
    buf = bench_buffer_insert()
    wal = bench_wal_overhead()
    flm = bench_flush_materialize()
    bg = bench_bg_scheduler()
    proc = bench_proc_parallel()
    result = {
        "config": {
            "preload_entries": PRELOAD,
            "preload_range_deletes": N_RDEL,
            "universe": UNIVERSE,
            "batch": BATCH,
            "rounds": ROUNDS,
            "reps": REPS,
            "scan_entries": SCAN_ENTRIES,
            "rdel_len": RDEL_LEN,
            "get_hit_frac": GET_HIT_FRAC,
            "submit_depth": DEPTH,
            "buffer_insert_records": N_BUF,
            "mixes": {k: MIXES[k] for k in MIX_KEYS},
            "t_io_seconds": T_IO,
            "strategy": "gloran",
            "partition": "range",
            "smoke": SMOKE,
        },
        "rows": rows,
        "buffer_insert": buf,
        "wal": wal,
        "flush_materialize": flm,
        "bg_scheduler": bg,
        "proc_parallel": proc,
        "acceptance": {
            # Background compaction gates (scripts/check.sh): the put
            # p99 under the delete-heavy session-expiry stream must be
            # >= 2x better with the scheduler on (puts stop carrying
            # flush/compaction), and the measured window must move
            # FEWER host->device bytes (proactive tombstone-density
            # compaction purges dead entries, so device re-packs
            # shrink at steady state).
            "bg_p99_put_improvement": bg["p99_put_improvement"],
            "bg_upload_bytes_ratio": bg["upload_bytes_ratio"],
            # Durability gate: put-heavy throughput with group-commit
            # WAL (fsync per submitted batch) within 1.25x of no-WAL.
            "wal_overhead": wal["overhead_ratio"],
            # Delete-path refactor: columnar staging buffer vs the
            # per-record R-tree write buffer, same stream + flush points.
            "staging_buffer_insert_speedup": buf["speedup"],
            # Headline: modeled mixed-batch throughput, pipelined vs
            # serial, across the mixes at the max shard count (geomean;
            # per-mix and wall numbers are all in ``rows``).
            "geomean_pipeline_speedup_max_shards": round(geo, 2)
            if geo else None,
            "min_pipeline_speedup_max_shards": min(
                (r["speedup_vs_serial_modeled"] for r in target),
                default=None),
            "max_pipeline_speedup_max_shards": max(
                (r["speedup_vs_serial_modeled"] for r in target),
                default=None),
            "min_pipeline_speedup_max_shards_wall": min(
                (r["speedup_vs_serial_wall"] for r in target),
                default=None),
            # THE wall-clock gate (scripts/check.sh): measured wall in
            # timed-I/O mode, pipelined per-shard-device engines vs the
            # serial single-device path, worst mix at >= 2 shards.
            "min_wall_speedup_ge2_shards": min(
                (r["wall_speedup"] for r in timed_rows), default=None),
            # Process-parallel gate: measured COMPUTE-BOUND wall
            # (io_wait_s=0, no sleeps to overlap — the regime threads
            # can't speed up), one worker process per shard vs serial
            # in-process.  Core-aware in check.sh: the required ratio
            # depends on proc_host_cpus.
            "proc_wall_speedup": proc["proc_wall_speedup"],
            "proc_host_cpus": proc["host_cpus"],
            "proc_transport_dequeue_p99_us":
                proc["rows"][1]["transport"]["dequeue_p99_us"],
            "wall_speedup_max_shards": {
                r["mix"]: r["wall_speedup"] for r in timed_rows
                if r["shards"] == max_s},
        },
    }
    if TRACE_OUT:
        export_trace(TRACE_OUT)
    with open(OUT, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {OUT}: geomean {max_s}-shard modeled pipeline "
          f"speedup = "
          f"{result['acceptance']['geomean_pipeline_speedup_max_shards']}"
          f"x, min timed wall speedup (>=2 shards) = "
          f"{result['acceptance']['min_wall_speedup_ge2_shards']}x, "
          f"proc wall speedup = "
          f"{result['acceptance']['proc_wall_speedup']}x on "
          f"{result['acceptance']['proc_host_cpus']} cpus", flush=True)
    return result


if __name__ == "__main__":
    run()
