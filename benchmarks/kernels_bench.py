"""Kernel micro-benchmarks (CPU: jnp oracle path timed; the Pallas kernels
execute in interpret mode on this container, so wall numbers here
characterize the REFERENCE path — kernel correctness is covered by
tests/test_kernels.py and on-TPU wall time comes from the roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eve import BloomBits, fold64to32
from repro.kernels.bloom.ref import bloom_probe_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.interval.ref import interval_query_ref
from repro.kernels.ssd.ref import ssd_chunked_ref

from .harness import emit


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rng = np.random.default_rng(0)
    # Bloom probe: 64k keys, 1M-bit filter.
    bb = BloomBits(1 << 20, 6)
    keys = jnp.asarray(fold64to32(
        rng.integers(0, 1 << 62, size=65536).astype(np.uint64)))
    words = jnp.asarray(bb.words)
    f = jax.jit(lambda k, w: bloom_probe_ref(
        k, w, m_bits=bb.m_bits, seeds=tuple(int(s) for s in bb.seeds)))
    emit("kernels/bloom_probe_64k", _time(f, keys, words),
         "per_key_ns=" + f"{_time(f, keys, words) * 1e3 / 65536:.1f}")

    # Interval query: 64k queries vs 100k disjoint areas.
    n = 100_000
    los = np.sort(rng.choice(1 << 30, size=2 * n, replace=False)
                  .astype(np.uint32))
    lo, hi = jnp.asarray(los[0::2]), jnp.asarray(los[1::2])
    smin = jnp.zeros(n, jnp.uint32)
    smax = jnp.asarray(rng.integers(1, 1 << 20, size=n).astype(np.uint32))
    qk = jnp.asarray(rng.integers(0, 1 << 30, size=65536).astype(np.uint32))
    qs = jnp.asarray(rng.integers(0, 1 << 20, size=65536).astype(np.uint32))
    g = jax.jit(interval_query_ref)
    emit("kernels/interval_query_64k_vs_100k", _time(g, qk, qs, lo, hi,
                                                     smin, smax),
         f"per_query_ns={_time(g, qk, qs, lo, hi, smin, smax) * 1e3 / 65536:.1f}")

    # Flash attention (ref path): B1 S1024 H8 D64.
    q = jnp.asarray(rng.standard_normal((1, 1024, 8, 64)), jnp.float32)
    h = jax.jit(lambda a: attention_ref(a, a, a, causal=True))
    emit("kernels/attention_1k_ref", _time(h, q, n=3), "path=jnp_ref")

    # SSD chunked scan: B1 S2048 H8 P64 N64.
    x = jnp.asarray(rng.standard_normal((1, 2048, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.random((1, 2048, 8)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(8) - 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((1, 2048, 64)), jnp.float32)
    s = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128))
    emit("kernels/ssd_2k_ref", _time(s, x, dt, A, B, C, n=3),
         "path=jnp_ref")


if __name__ == "__main__":
    run()
