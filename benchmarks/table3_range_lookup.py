"""Table 3: throughput with range lookups in the mix (normalized to
Decomp), plus Tables 4-5: YCSB-style Zipfian workloads and db_bench-style
uniform mixes (10% of updates replaced by range deletes)."""

from __future__ import annotations

from .harness import SCALE, WorkloadMix, emit, preload, run_workload, \
    standard_tree

STRATEGIES = ("decomp", "scan_delete", "lookup_delete", "lrr", "gloran")
U = 1 << 21


def _sweep(tag: str, mixes: dict, n_pre: int, n_ops: int,
           distribution: str = "uniform"):
    for mname, mix in mixes.items():
        base = None
        for strat in STRATEGIES:
            tree = standard_tree(strat, universe=U)
            preload(tree, n_pre, U)
            mix2 = WorkloadMix(**{**mix.__dict__,
                                  "distribution": distribution})
            res = run_workload(tree, n_ops, mix2, seed=3)
            m = res.modeled_ops_per_sec()
            if base is None:
                base = m
            emit(f"{tag}/{mname}/{strat}",
                 1e6 / max(res.ops_per_sec, 1e-9),
                 f"norm_tput={m / base:.2f}x "
                 f"modeled_ops_s={m:.0f} ops_s={res.ops_per_sec:.0f}")


def run():
    n_pre, n_ops = 120_000 * SCALE, 15_000 * SCALE
    # Table 3: balanced + range lookups at 2% / 10%.
    _sweep("table3", {
        f"rl{p}": WorkloadMix(lookup=0.5 - p / 100, update=0.45,
                              range_delete=0.05, range_lookup=p / 100,
                              range_lookup_len=100, universe=U)
        for p in (2, 10)}, n_pre, n_ops)
    # Table 4: YCSB-ish Zipfian.
    _sweep("table4_ycsb", {
        "point_l": WorkloadMix(lookup=0.9, update=0.0, range_delete=0.01,
                               universe=U),
        "balance": WorkloadMix(lookup=0.5, update=0.45, range_delete=0.05,
                               universe=U),
        "update": WorkloadMix(lookup=0.1, update=0.81, range_delete=0.09,
                              universe=U),
        "range_l": WorkloadMix(lookup=0.0, update=0.72, range_delete=0.08,
                               range_lookup=0.2, universe=U),
    }, n_pre, n_ops, distribution="zipfian")
    # Table 5: db_bench-ish uniform mixes, rd = 10% of updates.
    _sweep("table5_dbbench", {
        f"lk{p}": WorkloadMix(lookup=p / 100, update=0.9 * (1 - p / 100),
                              range_delete=0.1 * (1 - p / 100), universe=U)
        for p in (10, 50, 90)}, n_pre, n_ops)


if __name__ == "__main__":
    run()
