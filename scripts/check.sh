#!/usr/bin/env bash
# Local verification: tier-1 tests + a ~10 s engine benchmark smoke so
# batched-lookup throughput drift is caught before it lands.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Per-run pytest timeout when the plugin is available (CI installs
# pytest-timeout): a deadlocked shard/device worker fails fast instead
# of hanging the job.  Local environments without the plugin run plain.
PYTEST_TIMEOUT=""
if python -c "import pytest_timeout" 2>/dev/null; then
    PYTEST_TIMEOUT="--timeout=900 --timeout-method=thread"
fi
python -m pytest -x -q ${PYTEST_TIMEOUT}

REPRO_ENGINE_BENCH_SMOKE=1 REPRO_BENCH_OUT=/tmp/BENCH_engine_smoke.json \
    python benchmarks/engine_bench.py

python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_engine_smoke.json"))
s = d["acceptance"]["min_speedup_4shard_batch_ge_1024"]
assert s is not None and s >= 2.0, \
    f"engine speedup regressed: {s}x < 2x vs per-key loop"
print(f"check OK: 4-shard batched lookups {s}x vs per-key loop")
c = d["acceptance"]["cascade_min_speedup_vs_perlevel_batch_ge_4096"]
assert c is not None and c >= 1.5, \
    f"fused cascade regressed: {c}x < 1.5x vs per-level kernel path"
print(f"check OK: fused lookup cascade {c}x vs per-level kernels "
      f"at batch >= 4096")
EOF

REPRO_RANGE_BENCH_SMOKE=1 REPRO_BENCH_OUT=/tmp/BENCH_range_smoke.json \
    python benchmarks/range_bench.py

python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_range_smoke.json"))
s = d["acceptance"]["best_speedup_any_shards"]
assert s is not None and s >= 2.0, \
    f"batched range-scan speedup regressed: {s}x < 2x vs per-call loop"
m = d["acceptance"]["min_speedup_single_shard"]
assert m is not None and m >= 1.4, \
    f"single-shard batched scans regressed: {m}x < 1.4x vs per-call loop"
print(f"check OK: batched range scans best {s}x / 1-shard min {m}x "
      f"vs per-call loop")
EOF

REPRO_MIXED_BENCH_SMOKE=1 REPRO_BENCH_OUT=/tmp/BENCH_mixed_smoke.json \
    python benchmarks/mixed_bench.py

python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_mixed_smoke.json"))
s = d["acceptance"]["geomean_pipeline_speedup_max_shards"]
assert s is not None and s >= 1.5, \
    f"pipelined mixed-batch speedup regressed: {s}x < 1.5x vs serial"
print(f"check OK: pipelined mixed batches {s}x (modeled) vs serial")
# The tentpole gate: MEASURED wall in timed-I/O mode, pipelined
# per-shard-device engines vs the serial single-device path — the
# model's projected overlap must show up on the clock.
w = d["acceptance"]["min_wall_speedup_ge2_shards"]
assert w is not None and w >= 1.3, \
    f"measured wall speedup regressed: {w}x < 1.3x at >=2 shards"
print(f"check OK: measured timed-I/O wall speedup {w}x (>=2 shards, "
      f"per-shard devices) vs serial single-device")
# Delete-heavy smoke row (range-delete-dominant mix) runs above; the
# staging-buffer gate pins the columnar delete path's absorption win.
b = d["acceptance"]["staging_buffer_insert_speedup"]
assert b is not None and b >= 2.0, \
    f"staging-buffer insert speedup regressed: {b}x < 2x vs R-tree buffer"
print(f"check OK: columnar staging buffer inserts {b}x vs R-tree buffer")
mixes = {r["mix"] for r in d["rows"]}
assert "rdel_dominant" in mixes, "delete-heavy smoke row missing"
lat = next(r["batch_latency_us"] for r in d["rows"] if r["pipeline"])
assert lat and all({"p50_us", "p95_us", "p99_us"} <= set(h)
                   for h in lat.values()), \
    "engine.stats latency percentiles missing from mixed-bench rows"
# Durability gate: group-commit WAL (fsync="batch") must keep a
# put-heavy stream within 1.25x of the no-WAL wall.
w = d["acceptance"]["wal_overhead"]
assert w is not None and w <= 1.25, \
    f"WAL overhead regressed: {w}x > 1.25x vs no-WAL put-heavy stream"
print(f"check OK: group-commit WAL overhead {w}x <= 1.25x")
# Background compaction gates: put p99 under the delete-heavy
# session-expiry stream must be >= 2x better with the scheduler on
# (puts seal instead of carrying flush/compaction), and the measured
# window must move fewer host->device bytes (proactive tombstone-
# density compaction purges dead entries, shrinking device re-packs).
p = d["acceptance"]["bg_p99_put_improvement"]
assert p is not None and p >= 2.0, \
    f"background-scheduler put p99 win regressed: {p}x < 2x vs inline"
print(f"check OK: background scheduler put p99 {p}x better than inline")
u = d["acceptance"]["bg_upload_bytes_ratio"]
assert u is not None and u < 1.0, \
    f"background-scheduler upload bytes not lower: ratio {u} >= 1.0"
print(f"check OK: background steady-state upload bytes ratio {u} < 1.0")
# Process-parallel gate: measured COMPUTE-BOUND wall (io_wait_s=0),
# one worker process per shard vs serial in-process.  Core-aware —
# process parallelism can only speed up host compute when the host has
# cores to run it on: >= 1.8x with >= 4 usable cores, >= 1.1x with 2-3
# cores, and on a 1-core box only a sanity floor (>= 0.45x) pinning
# that the shared-memory transport stays within ~2x of in-process.
p = d["acceptance"]["proc_wall_speedup"]
cpus = d["acceptance"]["proc_host_cpus"]
need = 1.8 if cpus >= 4 else (1.1 if cpus >= 2 else 0.45)
assert p is not None and p >= need, \
    f"proc-parallel wall speedup regressed: {p}x < {need}x " \
    f"({cpus} usable cores)"
print(f"check OK: proc-parallel compute-bound wall {p}x >= {need}x "
      f"({cpus} usable cores, 4 workers)")
EOF

# Durability: cold-start recovery smoke.  Each row round-trips a store
# through close -> recover() and verifies gets/scans/level shapes
# against the original; the snapshot rows additionally exercise
# take_snapshot + WAL-tail-only replay.
REPRO_RECOVERY_BENCH_SMOKE=1 REPRO_BENCH_OUT=/tmp/BENCH_engine_smoke.json \
    python benchmarks/recovery_bench.py

python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_engine_smoke.json"))
r = d["recovery"]
assert r["verified"], "recovery rows were not verified against originals"
snap = [x for x in r["rows"] if x["snapshot"]]
assert snap and all(x["snapshot_loaded"] for x in snap), \
    "snapshot fast path did not engage on the snapshot rows"
print(f"check OK: recovery verified on {len(r['rows'])} rows, "
      f"max wall {r['max_recovery_wall_s']}s, snapshot fast path used")
EOF

# Durability: real SIGKILL mid-stream, then recover + verify the acked
# prefix against the seeded oracle envelope.
python scripts/kill_and_recover.py

REPRO_OBS_BENCH_SMOKE=1 REPRO_BENCH_OUT=/tmp/BENCH_obs_smoke.json \
    python benchmarks/obs_overhead.py

python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_obs_smoke.json"))
a = d["acceptance"]
off = a["disabled_projected_overhead_frac"]
assert off <= 0.02, \
    f"disabled tracer overhead too high: {off:.2%} of batch wall > 2%"
print(f"check OK: disabled tracer costs {off:.3%} of batch wall "
      f"({d['spans_per_batch']} spans x {d['null_span_cost_ns']}ns)")
on = a["enabled_wall_ratio"]
assert on <= 1.10, \
    f"enabled tracer overhead too high: {on}x wall ratio > 1.10x"
print(f"check OK: enabled tracer wall ratio {on}x <= 1.10x")
EOF
