#!/usr/bin/env python
"""Kill-and-recover check: SIGKILL a live writer mid-stream, recover,
verify the durable prefix — the CI form of the crash-consistency
property against a REAL process death (no simulated truncation).

Parent/child protocol:

  child   opens a durable engine (``fsync="batch"``) on a shared WAL
          directory and streams seeded mixed batches (puts, point
          deletes, range deletes, periodic flushes).  After each
          acknowledged batch it appends one line — ``<batch_index>`` —
          to ``acked.log`` (write + flush + fsync), the parent's record
          of what durability was promised.
  parent  waits until a few batches are acked, then SIGKILLs the child
          (no shutdown path runs), recovers the store from the WAL
          directory, regenerates the same seeded stream, and verifies:
          every *acked* batch's effects are present — gets return
          exactly the oracle state of the acked prefix; a possibly
          half-acked trailing batch is allowed to be present or absent
          atomically per shard plan (frames are atomic units).

Exit 0 on success.  Run:  PYTHONPATH=src python scripts/kill_and_recover.py
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

UNIVERSE = 1 << 20
BATCH = 512
N_BATCHES = 200
SHARDS = 2
SEED = 31


def make_batches():
    """The deterministic workload both processes derive independently."""
    rng = np.random.default_rng(SEED)
    out = []
    for i in range(N_BATCHES):
        keys = rng.integers(1, UNIVERSE - 1, BATCH).astype(np.uint64)
        vals = keys * np.uint64(2 + (i % 7))
        dels = keys[: BATCH // 8]
        lo = int(rng.integers(1, UNIVERSE // 2))
        rd = (lo, lo + int(rng.integers(64, 4096)))
        out.append((keys, vals, dels, rd, i % 5 == 4))
    return out


def engine_config(wal_dir):
    from repro.engine import EngineConfig
    return EngineConfig(partition="hash", pipeline=False, devices=0,
                        procs=0,
                        wal_dir=wal_dir, fsync="batch")


def build_engine(wal_dir):
    from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
    from repro.engine import Engine
    from repro.lsm import LSMConfig
    lsm = LSMConfig(buffer_capacity=1024, key_size=16, value_size=16,
                    key_universe=UNIVERSE)
    glo = GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=128, key_size=16),
        eve=RAEConfig(capacity=4096, key_universe=UNIVERSE))
    return Engine(SHARDS, strategy="gloran", lsm_config=lsm,
                  gloran_config=glo, config=engine_config(wal_dir))


def child_main(wal_dir: str) -> None:
    eng = build_engine(wal_dir)
    ack = open(os.path.join(wal_dir, "acked.log"), "w")
    for i, (keys, vals, dels, rd, do_flush) in enumerate(make_batches()):
        eng.put_batch(keys, vals)
        eng.delete_batch(dels)
        eng.range_delete(*rd)
        if do_flush:
            eng.flush()
        ack.write(f"{i}\n")
        ack.flush()
        os.fsync(ack.fileno())
    # Never reached under the parent's SIGKILL; harmless standalone.
    eng.close()


def oracle_state(n_acked: int) -> list[dict]:
    """The post-crash envelope: visible key->val states the store may
    legally serve.  [0] is the fully-acked prefix; [1..3] apply the
    in-flight batch's sub-ops (puts, then point deletes, then the range
    delete) — each lands as its own per-shard WAL frame, so any prefix
    of them can be durable on a given shard."""
    state: dict = {}
    for keys, vals, dels, (lo, hi), _ in make_batches()[:n_acked]:
        for k, v in zip(keys.tolist(), vals.tolist()):
            state[k] = v
        for k in dels.tolist():
            state.pop(k, None)
        for k in [k for k in state if lo <= k < hi]:
            del state[k]
    envelope = [state]
    if n_acked < N_BATCHES:
        keys, vals, dels, (lo, hi), _ = make_batches()[n_acked]
        s1 = dict(state)
        for k, v in zip(keys.tolist(), vals.tolist()):
            s1[k] = v
        s2 = dict(s1)
        for k in dels.tolist():
            s2.pop(k, None)
        s3 = {k: v for k, v in s2.items() if not lo <= k < hi}
        envelope += [s1, s2, s3]
    return envelope


def parent_main() -> int:
    wal_dir = tempfile.mkdtemp(prefix="repro-killrec-")
    child = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child", wal_dir],
        env={**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH",
                                                        "src")})
    ack_path = os.path.join(wal_dir, "acked.log")
    deadline = time.time() + 180
    target = 8
    try:
        while time.time() < deadline:
            if child.poll() is not None:
                print("child exited before the kill — workload too "
                      "small for this host; treating as failure")
                return 1
            try:
                n = sum(1 for _ in open(ack_path))
            except OSError:
                n = 0
            if n >= target:
                break
            time.sleep(0.05)
        else:
            print("timeout waiting for acked batches")
            return 1
        child.send_signal(signal.SIGKILL)
        child.wait()
    finally:
        if child.poll() is None:
            child.kill()
    acked = [int(x) for x in open(ack_path).read().split()]
    n_acked = max(acked) + 1 if acked else 0
    print(f"killed mid-stream after {n_acked} acked batches")

    from repro.durable import recover
    from repro.engine import EngineConfig
    rec = recover(wal_dir, config=EngineConfig(procs=0, devices=0,
                                               pipeline=False))
    print(f"recovered: {rec.recovery}")

    # Acked-prefix state must be FULLY present.  The batch after the
    # last ack may be partially durable (each of its sub-ops is its own
    # per-shard atomic frame), so every key's served state must match
    # SOME stage of the envelope — never a value from nowhere, never a
    # lost acked write.
    envelope = oracle_state(n_acked)
    want = envelope[0]
    keys = np.array(sorted(want), dtype=np.uint64)
    found, vals = rec.get_batch(keys)
    bad = 0
    for k, f, v in zip(keys.tolist(), found.tolist(), vals.tolist()):
        ok = any((f and st.get(k) == v) or (not f and k not in st)
                 for st in envelope)
        if not ok:
            bad += 1
            if bad <= 5:
                print(f"MISMATCH key={k} found={f} val={v} "
                      f"want={want[k]} envelope="
                      f"{[st.get(k) for st in envelope]}")
    if bad:
        print(f"FAIL: {bad} acked keys lost or corrupted")
        return 1
    m = rec.stats()["metrics"]
    assert m["recovery.wall_s"] > 0.0 and m["wal.bytes"] > 0
    rec.close()
    print(f"OK: all {len(keys)} acked keys verified "
          f"(recovery {m['recovery.wall_s']:.3f}s, "
          f"{int(m['recovery.frames_replayed'])} frames)")
    import shutil
    shutil.rmtree(wal_dir, ignore_errors=True)
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        child_main(sys.argv[2])
    else:
        sys.exit(parent_main())
