"""LSM-tree substrate: model-based correctness across all range-delete
strategies + the paper's headline I/O behavior."""

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.lsm import LSMConfig, LSMTree, STRATEGIES


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran():
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=16,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=1 << 20))


class Model:
    """Reference semantics: a dict + applied range deletes."""

    def __init__(self):
        self.d = {}

    def put(self, k, v):
        self.d[k] = v

    def delete(self, k):
        self.d.pop(k, None)

    def range_delete(self, lo, hi):
        for k in [k for k in self.d if lo <= k < hi]:
            del self.d[k]

    def get(self, k):
        return self.d.get(k)

    def scan(self, lo, hi):
        return sorted((k, v) for k, v in self.d.items() if lo <= k < hi)


def run_ops(strategy, ops):
    t = LSMTree(small_cfg(), strategy=strategy,
                gloran_config=small_gloran() if strategy == "gloran" else None)
    m = Model()
    for op in ops:
        if op[0] == "put":
            t.put(op[1], op[2])
            m.put(op[1], op[2])
        elif op[0] == "del":
            t.delete(op[1])
            m.delete(op[1])
        elif op[0] == "rdel":
            t.range_delete(op[1], op[2])
            m.range_delete(op[1], op[2])
    return t, m


def make_ops(rng, n, universe=2000, rdel_ratio=0.05, max_len=100):
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < rdel_ratio:
            lo = int(rng.integers(0, universe - 2))
            hi = lo + int(rng.integers(1, max_len))
            ops.append(("rdel", lo, hi))
        elif r < rdel_ratio + 0.05:
            ops.append(("del", int(rng.integers(0, universe))))
        else:
            k = int(rng.integers(0, universe))
            ops.append(("put", k, int(rng.integers(1, 1 << 30))))
    return ops


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_model_equivalence_point_lookups(strategy):
    rng = np.random.default_rng(42)
    ops = make_ops(rng, 1500)
    t, m = run_ops(strategy, ops)
    probe = rng.integers(0, 2100, size=600)
    for k in probe.tolist():
        assert t.get(k) == m.get(k), f"{strategy}: key {k}"


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_model_equivalence_batch_lookups(strategy):
    rng = np.random.default_rng(7)
    ops = make_ops(rng, 1200, rdel_ratio=0.08)
    t, m = run_ops(strategy, ops)
    keys = rng.integers(0, 2100, size=800).astype(np.uint64)
    found, vals = t.get_batch(keys)
    for j, k in enumerate(keys.tolist()):
        want = m.get(k)
        assert found[j] == (want is not None), f"{strategy}: key {k}"
        if want is not None:
            assert vals[j] == want


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_model_equivalence_range_scan(strategy):
    rng = np.random.default_rng(11)
    ops = make_ops(rng, 1200, rdel_ratio=0.06)
    t, m = run_ops(strategy, ops)
    for _ in range(25):
        lo = int(rng.integers(0, 1900))
        hi = lo + int(rng.integers(1, 300))
        ks, vs = t.range_scan(lo, hi)
        got = sorted(zip(ks.tolist(), vs.tolist()))
        assert got == m.scan(lo, hi), f"{strategy}: [{lo},{hi})"


def test_compaction_reclaims_space():
    t, m = run_ops("gloran", make_ops(np.random.default_rng(1), 3000,
                                      universe=500, rdel_ratio=0.1))
    # After enough compactions, dead entries must be bounded.
    assert t.num_entries < 3 * max(1, len(m.d)) + t.config.buffer_capacity * 4


def test_gloran_gc_advances_floor():
    t, _ = run_ops("gloran", make_ops(np.random.default_rng(2), 4000,
                                      universe=800, rdel_ratio=0.1))
    assert t.gloran.gc_floor > 0  # bottom compactions happened


def test_paper_headline_lookup_io():
    """§3: with range deletes, LRR point lookups pay >= 1 I/O per level for
    rt blocks + linear tombstone scans; GLORAN decouples that."""
    rng = np.random.default_rng(3)
    ops = make_ops(rng, 4000, universe=100_000, rdel_ratio=0.05, max_len=200)
    t_lrr, _ = run_ops("lrr", ops)
    t_glo, _ = run_ops("gloran", ops)
    keys = rng.integers(0, 100_000, size=500).astype(np.uint64)
    r0 = t_lrr.io.reads
    t_lrr.get_batch(keys)
    lrr_reads = t_lrr.io.reads - r0
    r0 = t_glo.io.reads
    t_glo.get_batch(keys)
    glo_reads = t_glo.io.reads - r0
    assert glo_reads < lrr_reads, (glo_reads, lrr_reads)


def test_nonexistent_keys_skip_global_index():
    """Table 2 Lookup(N): absent keys never touch the LSM-DRtree."""
    t = LSMTree(small_cfg(), strategy="gloran",
                gloran_config=small_gloran())
    for k in range(0, 2000, 2):
        t.put(k, k + 1)
    for s in range(5):
        t.range_delete(s * 100, s * 100 + 50)
    idx_reads0 = t.gloran.io.by_tag.get("drtree_probe", 0)
    # Odd keys above the data: non-existent.
    for k in range(100_001, 100_200, 2):
        assert t.get(k) is None
    assert t.gloran.io.by_tag.get("drtree_probe", 0) == idx_reads0


def test_update_after_range_delete_visible():
    """§4.1 temporal-correctness hazard."""
    for strategy in STRATEGIES:
        t = LSMTree(small_cfg(), strategy=strategy,
                    gloran_config=small_gloran()
                    if strategy == "gloran" else None)
        t.put(8, 100)
        t.range_delete(5, 15)
        assert t.get(8) is None
        t.put(8, 200)  # re-insert AFTER the range delete
        assert t.get(8) == 200, strategy
        t.flush()
        assert t.get(8) == 200, strategy


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["put", "del", "rdel"]),
                              st.integers(0, 300), st.integers(1, 60)),
                    min_size=1, max_size=120),
           st.sampled_from(["lrr", "gloran"]))
    def test_property_lsm_matches_model(raw_ops, strategy):
        ops = []
        for kind, a, b in raw_ops:
            if kind == "put":
                ops.append(("put", a, b))
            elif kind == "del":
                ops.append(("del", a))
            else:
                ops.append(("rdel", a, a + b))
        t, m = run_ops(strategy, ops)
        for k in range(0, 310, 7):
            assert t.get(k) == m.get(k), (strategy, k)
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests "
                             "not collected")
    def test_property_lsm_matches_model():
        pass
