"""Substrate tests: pipeline determinism, checkpoint atomicity + elastic
restore, fault-tolerant train loop (failure injection, resume, straggler),
gradient compression numerics, serving loop with GLORAN session registry."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config, smoke
from repro.launch.mesh import make_mesh_compat
from repro.data import PipelineConfig, TokenPipeline, VersionedSampleStore
from repro.models import Transformer, tree_init
from repro.optim import OptimizerConfig, quantize_roundtrip
from repro.runtime import (ServeLoop, SessionRegistry, StragglerDetector,
                           TrainLoopConfig, TransientFailure, run_training)


def tiny_model():
    cfg = smoke(get_config("h2o-danube-3-4b"))
    return Transformer(cfg)


def tiny_pipeline(cfg, n_hosts=1, host_id=0):
    return TokenPipeline(PipelineConfig(vocab=cfg.vocab, global_batch=4,
                                        seq_len=16, seed=7, n_hosts=n_hosts,
                                        host_id=host_id))


# ------------------------------------------------------------- pipeline
class TestPipeline:
    def test_deterministic_across_restarts(self):
        cfg = smoke(get_config("minitron-8b"))
        p1 = tiny_pipeline(cfg)
        batches1 = [p1.next() for _ in range(5)]
        p2 = tiny_pipeline(cfg)
        p2.restore({"step": 3, "seed": 7})
        b3 = p2.next()
        np.testing.assert_array_equal(b3["tokens"], batches1[3]["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        cfg = smoke(get_config("minitron-8b"))
        full = tiny_pipeline(cfg).next()
        h0 = TokenPipeline(PipelineConfig(vocab=cfg.vocab, global_batch=4,
                                          seq_len=16, seed=7, n_hosts=2,
                                          host_id=0)).next()
        h1 = TokenPipeline(PipelineConfig(vocab=cfg.vocab, global_batch=4,
                                          seq_len=16, seed=7, n_hosts=2,
                                          host_id=1)).next()
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


# ------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def test_atomic_save_restore_roundtrip(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        state = {"w": jnp.arange(12.0).reshape(3, 4),
                 "nested": {"b": jnp.ones((5,))}}
        m.save(10, state, extra={"step": 10, "pipeline": {"step": 3,
                                                          "seed": 7}})
        m.wait()
        got, extra = m.restore(state)
        np.testing.assert_array_equal(got["w"], state["w"])
        assert extra["step"] == 10
        assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    def test_keep_last_k(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        s = {"w": jnp.zeros((2,))}
        for step in (1, 2, 3, 4):
            m.save(step, s, extra={}, blocking=True)
        assert m.list_steps() == [3, 4]

    def test_elastic_restore_resharding(self, tmp_path):
        """Restore onto a different device layout (elastic scaling)."""
        m = CheckpointManager(str(tmp_path), keep=1)
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        m.save(1, state, extra={}, blocking=True)
        mesh = make_mesh_compat((1,), ("x",))
        sh = {"w": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("x", None))}
        got, _ = m.restore(state, shardings=sh)
        np.testing.assert_array_equal(np.asarray(got["w"]), state["w"])
        assert got["w"].sharding == sh["w"]


# ------------------------------------------------------------ train loop
class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        model = tiny_model()
        pipe = tiny_pipeline(model.cfg)
        res = run_training(model, pipe, TrainLoopConfig(
            total_steps=20, checkpoint_every=10,
            checkpoint_dir=str(tmp_path)))
        assert res.final_step == 20
        assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])

    def test_transient_failures_are_retried(self, tmp_path):
        model = tiny_model()
        pipe = tiny_pipeline(model.cfg)
        fail_at = {3: 2, 7: 1}  # step -> remaining failures

        def injector(step):
            if fail_at.get(step, 0) > 0:
                fail_at[step] -= 1
                return True
            return False

        res = run_training(model, pipe, TrainLoopConfig(
            total_steps=10, checkpoint_every=5,
            checkpoint_dir=str(tmp_path)), failure_injector=injector)
        assert res.final_step == 10
        assert res.retries == 3

    def test_crash_resume_continues_from_checkpoint(self, tmp_path):
        model = tiny_model()
        pipe = tiny_pipeline(model.cfg)
        cfgA = TrainLoopConfig(total_steps=10, checkpoint_every=5,
                               checkpoint_dir=str(tmp_path))

        def hard_fail(step):
            if step == 7:
                raise RuntimeError("simulated node loss")
            return False

        with pytest.raises(RuntimeError):
            run_training(model, pipe, cfgA, failure_injector=hard_fail)
        # New job, same checkpoint dir: resumes at step 5.
        pipe2 = tiny_pipeline(model.cfg)
        res = run_training(model, pipe2, cfgA)
        assert res.resumed_from == 5
        assert res.final_step == 10
        assert pipe2.step == 10  # pipeline state also resumed

    def test_straggler_events_detected(self, tmp_path):
        model = tiny_model()
        pipe = tiny_pipeline(model.cfg)
        pipe.cfg.n_hosts = 1  # keep data on one host

        def durations(step, real):
            base = [0.1, 0.1, 0.1, 0.1]
            if step >= 8:
                base[2] = 0.9  # host 2 goes slow
            return base

        det_pipe = TokenPipeline(PipelineConfig(
            vocab=model.cfg.vocab, global_batch=4, seq_len=16, seed=7,
            n_hosts=4, host_id=0))
        res = run_training(model, det_pipe, TrainLoopConfig(
            total_steps=12, checkpoint_every=50,
            checkpoint_dir=str(tmp_path)), host_durations_fn=durations)
        assert any(e["host"] == 2 for e in res.straggler_events)


# ------------------------------------------------------ grad compression
class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((1000,)) * 3.0, jnp.float32)
        y, resid = quantize_roundtrip(x)
        np.testing.assert_allclose(np.asarray(y + resid), np.asarray(x),
                                   rtol=1e-6)
        # Block-scaled int8: error bounded by scale/2 per element.
        assert float(jnp.abs(resid).max()) < float(
            jnp.abs(x).max()) / 127.0 + 1e-6

    def test_compressed_psum_matches_exact_with_feedback(self):
        """Error feedback: the MEAN of compressed reductions over steps
        converges to the exact mean gradient."""
        from repro.optim.grad_compress import compressed_psum
        mesh = make_mesh_compat((1,), ("pod",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
        e = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        f = shard_map(lambda gg, ee: compressed_psum(gg, ee, "pod"),
                      mesh=mesh, in_specs=(PS(), PS()),
                      out_specs=(PS(), PS()), check_rep=False)
        for _ in range(50):
            red, e = f(g, e)
            total = total + red
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=2e-3)


# ---------------------------------------------------------------- serving
class TestServeLoop:
    def test_generation_and_registry(self):
        model = tiny_model()
        reg = SessionRegistry(strategy="gloran")
        rng = np.random.default_rng(2)
        b = 4
        sessions = np.arange(b, dtype=np.uint64) + 100
        for s in sessions:
            reg.register(int(s), np.arange(8), np.arange(8) + s)
        loop = ServeLoop(model, batch=b, max_len=64, registry=reg)
        prompts = rng.integers(0, model.cfg.vocab, size=(b, 8)).astype(
            np.int32)
        out = loop.run(prompts, steps=12, session_ids=sessions)
        assert out.shape == (b, 12)
        assert loop.stats.tokens_generated == b * 12
        assert loop.stats.registry_lookups > 0

    def test_range_expiry_keeps_lookups_fast(self):
        """After mass session expiry via range deletes, GLORAN registry
        point lookups stay cheap vs the LRR registry."""
        regs = {s: SessionRegistry(strategy=s) for s in ("gloran", "lrr")}
        rng = np.random.default_rng(3)
        for name, reg in regs.items():
            for sid in range(6000):
                reg.register(sid, np.arange(4), np.arange(4))
            for sid in range(0, 4800, 80):  # expire [sid, sid+40)
                reg.expire_range(sid, sid + 40)
            reg.tree.flush()  # persist memtable + tombstones to disk
            io0 = reg.tree.io.reads
            # Probe SURVIVING old sessions (on disk, amid deleted ranges).
            live = (rng.integers(0, 60, size=500) * 80 + 40 +
                    rng.integers(0, 40, size=500)).astype(np.uint64)
            found, _ = reg.lookup(live, np.zeros(500, dtype=np.uint64))
            assert found.all()
            reg.tree.io.by_tag["__probe"] = reg.tree.io.reads - io0
        assert regs["gloran"].tree.io.by_tag["__probe"] < \
            regs["lrr"].tree.io.by_tag["__probe"]


# ----------------------------------------------------- versioned dataset
class TestVersionedStore:
    def test_publish_purge_lookup(self):
        store = VersionedSampleStore(strategy="gloran")
        for v in range(5):
            store.publish(v, np.arange(200), np.arange(200) * (v + 1))
        store.purge_version(2)
        store.purge_version(3)
        assert store.get(2, 100) is None
        assert store.get(4, 100) == 500
        keys, vals = store.scan_version(1)
        assert len(keys) == 200
