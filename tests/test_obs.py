"""Observability layer: tracer spans + Chrome export, latency
histograms, the metrics registry, and their engine integration."""

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.analysis.report import trace_report
from repro.engine import Engine, EngineConfig, OpBatch
from repro.lsm import LSMConfig
from repro.obs import (LatencyHistogram, MetricsRegistry, NULL_TRACER,
                       Tracer)

UNIVERSE = 1 << 20


# --------------------------------------------------------------- tracer
def test_null_tracer_is_default_and_freely_nestable():
    assert not obs.tracing_enabled()
    with obs.span("a.b", n=1) as s1, obs.span("c.d") as s2:
        assert s1 is s2  # the shared no-op span: no allocation per call


def test_tracer_records_spans_with_attrs():
    with obs.enabled() as tr:
        with obs.span("stage.outer", n=3):
            with obs.span("stage.inner"):
                pass
    evs = tr.chrome_events()
    xs = [e for e in evs if e["ph"] == "X"]
    by_name = {e["name"]: e for e in xs}
    assert set(by_name) == {"stage.outer", "stage.inner"}
    assert by_name["stage.outer"]["args"] == {"n": 3}
    assert by_name["stage.outer"]["cat"] == "stage"


def test_enabled_scope_restores_previous_tracer():
    prev = obs.get_tracer()
    with obs.enabled():
        assert obs.tracing_enabled()
    assert obs.get_tracer() is prev


def test_chrome_events_well_formed_and_nested():
    """Every X event carries a matched begin/end (ts, ts+dur), timestamps
    are monotone against the tracer base, and a child span's window sits
    inside its parent's."""
    with obs.enabled() as tr:
        with obs.span("p.outer"):
            with obs.span("p.inner"):
                pass
        with obs.span("p.later"):
            pass
    evs = tr.chrome_events()
    json.dumps(evs)  # serializable as-is
    xs = [e for e in evs if e["ph"] == "X"]
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid"}
    by_name = {e["name"]: e for e in xs}
    inner, outer = by_name["p.inner"], by_name["p.outer"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert by_name["p.later"]["ts"] >= outer["ts"] + outer["dur"] - 1e-9
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(m["name"] == "thread_name" for m in meta)


def test_export_chrome_loads_back(tmp_path):
    path = tmp_path / "trace.json"
    with obs.enabled() as tr:
        with obs.span("x.y"):
            pass
    tr.export_chrome(str(path))
    data = json.loads(path.read_text())
    assert isinstance(data["traceEvents"], list)
    assert any(e.get("name") == "x.y" for e in data["traceEvents"])


def test_tracer_thread_safety_and_thread_tracks():
    tr = Tracer()
    gate = threading.Barrier(4)  # hold all threads live: distinct idents

    def work():
        gate.wait()
        for i in range(200):
            with tr.span("t.work", i=i):
                pass
        gate.wait()

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events()) == 800
    tids = {e["tid"] for e in tr.chrome_events() if e["ph"] == "X"}
    assert len(tids) == 4


def test_tracer_bounded_drops_not_grows():
    tr = Tracer(max_events=10)
    for _ in range(25):
        with tr.span("d.x"):
            pass
    assert len(tr.events()) == 10
    assert tr.dropped == 15


# ----------------------------------------------------------- histograms
def test_histogram_quantiles_track_np_percentile():
    rng = np.random.default_rng(0)
    # Log-uniform latencies: 1us .. 100ms, the range the buckets serve.
    vals = np.exp(rng.uniform(np.log(1e-6), np.log(0.1), size=20_000))
    h = LatencyHistogram()
    h.record_many(vals)
    for q in (0.5, 0.9, 0.95, 0.99):
        got = h.quantile(q)
        want = float(np.percentile(vals, q * 100))
        # 4 buckets/octave -> <= 2^(1/4)-1 ~ 19% relative bucket error.
        assert abs(got - want) / want < 0.19, (q, got, want)


def test_histogram_extremes_and_snapshot_schema():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0 and h.mean == 0.0
    h.record(3.2e-5)
    assert h.quantile(0.0) == h.quantile(1.0) == pytest.approx(3.2e-5)
    h.record_many(np.full(9, 3.2e-5))
    snap = h.snapshot()
    assert set(snap) == {"count", "total_seconds", "mean_us", "min_us",
                         "max_us", "p50_us", "p95_us", "p99_us"}
    assert snap["count"] == 10
    assert snap["p99_us"] == pytest.approx(32.0, rel=1e-6)
    json.dumps(snap)


def test_histogram_merge_and_reset():
    a, b = LatencyHistogram(), LatencyHistogram()
    a.record_many([1e-4] * 5)
    b.record_many([1e-2] * 5)
    a.merge(b)
    assert a.snapshot()["count"] == 10
    assert a.quantile(0.1) == pytest.approx(1e-4, rel=0.19)
    assert a.quantile(0.9) == pytest.approx(1e-2, rel=0.19)
    a.reset()
    assert a.snapshot()["count"] == 0


# ------------------------------------------------------ metrics registry
def test_metrics_registry_namespacing_and_schema():
    m = MetricsRegistry()
    m.inc("ops.count")
    m.inc("ops.count", 2)
    m.set("gauge.ratio", 0.5)
    m.absorb("kernels", {"bloom_calls": 3,
                         "nested": {"deep": 7, "skip_list": [1, 2]}})
    snap = m.snapshot()
    assert snap["ops.count"] == 3
    assert snap["kernels.nested.deep"] == 7
    assert "kernels.nested.skip_list" not in snap  # scalars only
    assert list(snap) == sorted(snap)  # stable key order
    json.dumps(snap)
    m.reset()
    assert m.snapshot() == {}


# --------------------------------------------------- engine integration
def _engine(**cfg):
    # procs pinned to 0: these tests introspect the parent tracer's own
    # span records (tr.events()) — in procs mode the shard spans are
    # foreign rows absorbed from the workers and only surface through
    # chrome_events(); tests/test_procs.py covers that path.
    cfg.setdefault("procs", 0)
    eng = Engine(num_shards=2, strategy="gloran",
                 lsm_config=LSMConfig(buffer_capacity=64, size_ratio=3,
                                      key_size=16, value_size=48,
                                      block_size=512,
                                      key_universe=UNIVERSE),
                 config=EngineConfig(**cfg))
    keys = np.arange(0, 4000, 2, dtype=np.uint64)
    eng.put_batch(keys, keys + np.uint64(1))
    eng.flush()
    return eng, keys


def test_engine_stats_latency_percentiles_per_op_and_shard():
    eng, keys = _engine()
    for i in range(4):
        eng.get_batch(keys[i * 100:(i + 1) * 100])
    eng.range_scan(100, 500)
    snap = eng.stats()["engine"]
    assert {"get", "put", "range_scan"} <= set(snap["latency"])
    g = snap["latency"]["get"]
    assert g["count"] == 4
    assert 0 < g["p50_us"] <= g["p95_us"] <= g["p99_us"] <= g["max_us"]
    assert set(snap["shard_latency"]) == {0, 1}
    json.dumps(snap)


def test_engine_metrics_snapshot_stable_keys():
    eng, keys = _engine()
    eng.get_batch(keys[:100])
    snap = eng.stats()["metrics"]
    assert any(k.startswith("kernels.") for k in snap)
    assert any(k.startswith("io.") for k in snap)
    assert "engine.entries" in snap and "cache.hit_rate" in snap
    assert list(snap) == sorted(snap)
    json.dumps(snap)


def test_engine_reset_stats_gives_fresh_window():
    eng, keys = _engine()
    eng.get_batch(keys[:100])
    assert eng.stats()["engine"]["latency"]["get"]["count"] == 1
    eng.reset_stats()
    snap = eng.stats()["engine"]
    assert snap["latency"] == {} and snap["shard_latency"] == {}
    eng.get_batch(keys[:100])
    assert eng.stats()["engine"]["latency"]["get"]["count"] == 1


def test_cache_hits_attributed_per_op_class():
    eng, keys = _engine(cache_blocks=256)
    eng.get_batch(keys[:200])
    eng.get_batch(keys[:200])
    eng.range_scan(0, 1000)
    by_class = eng.stats()["cache"]["by_class"]
    assert {"get", "range_scan"} <= set(by_class)
    assert by_class["get"]["hits"] > 0
    assert set(by_class["get"]) == {"hits", "misses", "hit_rate"}


def test_engine_spans_cover_submit_to_shard(tmp_path):
    eng, keys = _engine()
    with obs.enabled() as tr:
        eng.submit(OpBatch.gets(keys[:200])).get_results()
        eng.drain()
    names = {e["name"] for e in tr.events()}
    assert {"engine.submit", "plan.compile", "shard.plan", "shard.get",
            "engine.collect"} <= names
    # Correlation: nested spans carry the planner-stamped batch seq.
    plan = [e for e in tr.chrome_events()
            if e["ph"] == "X" and e["name"] == "shard.plan"]
    seqs = {e["args"]["batch"] for e in plan}
    assert len(seqs) == 1 and seqs.pop() >= 0


def test_trace_report_stalls_and_critical_path():
    eng, keys = _engine()
    with obs.enabled() as tr:
        for i in range(3):
            eng.submit(OpBatch.gets(keys[i * 300:(i + 1) * 300])) \
                .get_results()
        eng.drain()
    rep = trace_report(tr.chrome_events())
    assert len(rep["batches"]) == 3
    assert set(rep["shards"]) == {0, 1}
    share = sum(r["stall_share"] for r in rep["shards"].values())
    assert share == pytest.approx(1.0) or share == 0.0
    for b in rep["batches"]:
        assert b["critical_us"] <= b["window_us"] + 1e-9
    assert rep["wall_us"] >= rep["modeled_us"] - 1e-9
    assert rep["lookups"] == 900
    json.dumps(rep)


# ------------------------------------------- per-device shard workers
def _engine4():
    """4 pipelined shards, each homed on its own XLA device (skips on
    hosts without 4 devices — conftest forces 4 before jax init)."""
    import jax
    if len(jax.devices()) < 4:
        pytest.skip(f"host has {len(jax.devices())} XLA devices")
    eng = Engine(num_shards=4, strategy="gloran",
                 lsm_config=LSMConfig(buffer_capacity=64, size_ratio=3,
                                      key_size=16, value_size=48,
                                      block_size=512,
                                      key_universe=UNIVERSE),
                 config=EngineConfig(pipeline=True, devices=4, procs=0))
    keys = np.arange(0, 8000, 2, dtype=np.uint64)
    eng.put_batch(keys, keys + np.uint64(1))
    eng.flush()
    return eng, keys


def _assert_well_nested(evs):
    """Chrome X events on one thread must form proper span nesting:
    a span either sits fully inside the open span or starts after it."""
    stack = []  # open span end times
    for e in sorted(evs, key=lambda e: (e["ts"], -e["dur"])):
        end = e["ts"] + e["dur"]
        while stack and e["ts"] >= stack[-1] - 1e-9:
            stack.pop()
        if stack:
            assert end <= stack[-1] + 1e-9, \
                f"span {e['name']} leaks out of its parent"
        stack.append(end)


def test_concurrent_device_worker_spans_well_nested_per_thread():
    """Four shard workers tracing concurrently onto their own devices:
    every thread's span stream stays well-nested (the tracer is shared,
    the per-thread view must not interleave), and the shard.plan spans
    record four distinct home devices."""
    eng, keys = _engine4()
    with obs.enabled() as tr:
        handles = [eng.submit(OpBatch.gets(keys[i * 400:(i + 2) * 400]))
                   for i in range(6)]
        for h in handles:
            h.get_results()
        eng.drain()
    xs = [e for e in tr.chrome_events() if e["ph"] == "X"]
    by_tid: dict = {}
    for e in xs:
        by_tid.setdefault(e["tid"], []).append(e)
    assert len(by_tid) >= 5  # main thread + 4 shard workers
    for evs in by_tid.values():
        _assert_well_nested(evs)
    plan = [e for e in xs if e["name"] == "shard.plan"]
    devices = {e["args"]["device"] for e in plan}
    assert devices == {f"cpu:{i}" for i in range(4)}
    # Per-shard worker spans really ran off the main thread.
    main_tid = next(e["tid"] for e in xs if e["name"] == "engine.submit")
    assert {e["tid"] for e in plan}.isdisjoint({main_tid})


def test_shard_latency_p99_populated_for_every_device_shard():
    eng, keys = _engine4()
    for i in range(6):
        eng.get_batch(keys[i * 300:(i + 1) * 300])
    snap = eng.stats()["engine"]
    assert set(snap["shard_latency"]) == {0, 1, 2, 3}
    for s, h in snap["shard_latency"].items():
        assert h["count"] > 0, s
        assert 0 < h["p50_us"] <= h["p99_us"] <= h["max_us"], s
    json.dumps(snap)


def test_chrome_export_one_named_track_per_shard_worker(tmp_path):
    """The exported trace carries one thread_name metadata track per
    shard worker (named shard-N...), so per-device lanes show up as
    labeled rows in chrome://tracing / Perfetto."""
    eng, keys = _engine4()
    with obs.enabled() as tr:
        for i in range(4):
            eng.submit(OpBatch.gets(keys[i * 500:(i + 1) * 500]))
        eng.drain()
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    evs = json.loads(path.read_text())["traceEvents"]
    names = {m["args"]["name"]: m["tid"] for m in evs
             if m.get("ph") == "M" and m.get("name") == "thread_name"}
    worker_tracks = {n for n in names if n.startswith("shard-")}
    assert {n.split("_")[0] for n in worker_tracks} \
        == {f"shard-{s}" for s in range(4)}
    # Each worker track is a distinct tid, and shard spans land on it.
    tids = {names[n] for n in worker_tracks}
    assert len(tids) == len(worker_tracks)
    plan_tids = {e["tid"] for e in evs
                 if e.get("ph") == "X" and e["name"] == "shard.plan"}
    assert plan_tids <= tids


def test_disabled_tracer_records_nothing_on_engine_path():
    eng, keys = _engine()
    assert not obs.tracing_enabled()
    eng.get_batch(keys[:100])  # must not blow up, must not record
    tr = Tracer()
    obs.set_tracer(tr)
    try:
        eng.get_batch(keys[:100])
    finally:
        obs.set_tracer(NULL_TRACER)
    assert len(tr.events()) > 0
    n = len(tr.events())
    eng.get_batch(keys[:100])  # after restore: nothing new recorded
    assert len(tr.events()) == n
