"""Background delete-aware compaction scheduler (``lsm/scheduler.py``).

The contract under test is BYTE-IDENTITY: because background jobs run
only at deterministic drain points (plan start, ``Engine.drain``,
seal backpressure, recovery replay), an engine with the scheduler on
must produce the same read results, the same I/O ledger, the same
flush points and level shapes, and the same recovered-from-WAL state
as the inline engine — for ANY sequence of blocking engine calls,
across all 5 range-delete strategies and 1/2/4 shards.

On top of identity: backpressure stalls are counted, the proactive
tombstone-density trigger actually reclaims GLORAN garbage, the
merge-rank compaction routing is bit-exact with the host path, the
vectorized presorted flush build equals the legacy lexsort build, and
the scheduler/per-level metrics surface through ``engine.stats()``.
"""

from __future__ import annotations

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.eve import RAEConfig
from repro.core.gloran import GloranConfig
from repro.core.lsm_drtree import LSMDRTreeConfig
from repro.durable import recover
from repro.engine import Engine, EngineConfig
from repro.lsm.format import LSMConfig
from repro.lsm.sstable import build_sstable
from repro.lsm.tree import STRATEGIES, LSMTree

UNIVERSE = 1 << 16


def small_lsm():
    # Tiny capacities so short workloads cross flush/compaction points.
    return LSMConfig(buffer_capacity=32, size_ratio=4, key_size=16,
                     value_size=16, key_universe=UNIVERSE)


def small_gloran():
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=16, size_ratio=4,
                              key_size=16),
        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def make_engine(*, strategy="gloran", shards=2, scheduler=False,
                **cfg_kw):
    cfg_kw.setdefault("pipeline", False)
    cfg_kw.setdefault("procs", 0)  # suite reads shards[s].scheduler
    cfg = EngineConfig(devices=0, scheduler=scheduler, **cfg_kw)
    return Engine(shards, strategy=strategy, lsm_config=small_lsm(),
                  gloran_config=small_gloran(), config=cfg)


def mixed_ops(seed, n_rounds=6, batch=48):
    """Deterministic op script: ("put"|"del"|"rdel"|"flush"|"get"|"scan")
    tuples, heavy enough to cross several flush + cascade boundaries."""
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_rounds):
        keys = rng.integers(1, UNIVERSE - 1, batch).astype(np.uint64)
        ops.append(("put", keys, keys * np.uint64(2 + i)))
        if i % 2 == 0:
            ops.append(("del", keys[: batch // 4]))
            ops.append(("get", rng.integers(
                1, UNIVERSE - 1, batch).astype(np.uint64)))
        else:
            lo = int(rng.integers(1, UNIVERSE // 2))
            ops.append(("rdel", lo, lo + int(rng.integers(1, 2000))))
            lo = int(rng.integers(1, UNIVERSE - 2))
            ops.append(("scan", lo, lo + 3000))
        if i == n_rounds // 2:
            ops.append(("flush",))
    return ops


def apply_and_compare(a, b, ops):
    """Apply the same script to both engines, asserting every read op
    returns identical results (reads are the mid-stream observation
    points where background state must already coincide with inline)."""
    for op in ops:
        if op[0] == "put":
            a.put_batch(op[1], op[2])
            b.put_batch(op[1], op[2])
        elif op[0] == "del":
            a.delete_batch(op[1])
            b.delete_batch(op[1])
        elif op[0] == "rdel":
            a.range_delete(op[1], op[2])
            b.range_delete(op[1], op[2])
        elif op[0] == "flush":
            a.flush()
            b.flush()
        elif op[0] == "get":
            fa, va = a.get_batch(op[1])
            fb, vb = b.get_batch(op[1])
            np.testing.assert_array_equal(fa, fb)
            np.testing.assert_array_equal(va[fa], vb[fb])
        elif op[0] == "scan":
            ka, va = a.range_scan(op[1], op[2])
            kb, vb = b.range_scan(op[1], op[2])
            np.testing.assert_array_equal(ka, kb)
            np.testing.assert_array_equal(va, vb)


def assert_same_store(a, b, *, io=True):
    """Byte-identical visible state AND structure (and, by default, the
    cumulative simulated-I/O ledger) between two drained engines."""
    probes = np.arange(1, UNIVERSE, 37, dtype=np.uint64)
    fa, va = a.get_batch(probes)
    fb, vb = b.get_batch(probes)
    np.testing.assert_array_equal(fa, fb)
    np.testing.assert_array_equal(va[fa], vb[fb])
    sa = a.range_scan(0, UNIVERSE)
    sb = b.range_scan(0, UNIVERSE)
    np.testing.assert_array_equal(sa[0], sb[0])
    np.testing.assert_array_equal(sa[1], sb[1])
    for sha, shb in zip(a.shards, b.shards):
        ta, tb = sha.tree, shb.tree
        assert ta.stats()["levels"] == tb.stats()["levels"]
        assert ta.seq == tb.seq
        assert ta.num_entries == tb.num_entries
        for la, lb in zip(ta.levels, tb.levels):
            if la is None or lb is None:
                assert (la is None or len(la) == 0) == \
                       (lb is None or len(lb) == 0)
                continue
            np.testing.assert_array_equal(la.keys, lb.keys)
            np.testing.assert_array_equal(la.seqs, lb.seqs)
            np.testing.assert_array_equal(la.vals, lb.vals)
        if io:
            assert ta.io.snapshot() == tb.io.snapshot()


# ------------------------------------------------- tentpole: identity
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_background_matches_inline(strategy, shards):
    inline = make_engine(strategy=strategy, shards=shards,
                         scheduler=False)
    bg = make_engine(strategy=strategy, shards=shards, scheduler=True)
    apply_and_compare(inline, bg, mixed_ops(7, n_rounds=8))
    inline.flush()
    bg.flush()
    assert_same_store(inline, bg)
    # A drained background engine owes nothing.
    for sh in bg.shards:
        c = sh.scheduler.counters()
        assert c["queue_depth"] == 0
        assert c["frozen"] == 0
        assert c["compaction_debt"] == 0
        assert c["flush_jobs"] > 0  # the workload really went background


@pytest.mark.parametrize("max_frozen", [1, 2, 8])
def test_background_matches_inline_seal_limits(max_frozen):
    inline = make_engine(strategy="gloran", shards=1, scheduler=False)
    bg = make_engine(strategy="gloran", shards=1, scheduler=True,
                     max_frozen=max_frozen)
    rng = np.random.default_rng(11)
    # One oversized put batch seals many times inside a single plan:
    # with max_frozen=1 every seal past the first backpressures.
    keys = rng.integers(1, UNIVERSE - 1, 600).astype(np.uint64)
    for eng in (inline, bg):
        eng.put_batch(keys, keys + np.uint64(1))
        eng.range_delete(100, 5000)
        eng.put_batch(keys[:64], keys[:64] + np.uint64(9))
    assert_same_store(inline, bg)
    c = bg.shards[0].scheduler.counters()
    if max_frozen == 1:
        assert c["stall_count"] > 0
        assert bg.stats()["sched"]["stall_count"] == c["stall_count"]
    assert c["max_queue_depth"] >= 1


def test_mid_compaction_close_drains_pending_jobs():
    """Pipelined submits + immediate close: close() must quiesce every
    queued flush/cascade job and leave the same state as the inline
    engine that ran everything serially."""
    inline = make_engine(strategy="lrr", shards=4, scheduler=False)
    bg = make_engine(strategy="lrr", shards=4, scheduler=True,
                     pipeline=True)
    rng = np.random.default_rng(3)
    batches = [rng.integers(1, UNIVERSE - 1, 256).astype(np.uint64)
               for _ in range(6)]
    for i, keys in enumerate(batches):
        inline.put_batch(keys, keys * np.uint64(3 + i))
    inline.range_delete(1000, 9000)
    handles = []
    for i, keys in enumerate(batches):  # fire-and-forget pipelined
        from repro.engine.plan import OpBatch
        handles.append(bg.submit(OpBatch.puts(keys,
                                              keys * np.uint64(3 + i))))
    bg.range_delete(1000, 9000)
    bg.close()  # drains in-flight work AND pending scheduler jobs
    inline.close()
    for sh in bg.shards:
        c = sh.scheduler.counters()
        assert c["queue_depth"] == 0
        assert c["frozen"] == 0
        assert c["compaction_debt"] == 0
    assert_same_store(inline, bg, io=False)  # pipelined wall differs,
    # but the ledger must still agree per shard:
    for sha, shb in zip(inline.shards, bg.shards):
        assert sha.tree.io.snapshot() == shb.tree.io.snapshot()


def test_wal_recovery_background_matches_inline(tmp_path):
    """The WAL written by a background engine recovers to the same
    store as the WAL written by the inline engine (FLUSH frames are
    acked only after the background flush durably published)."""
    dirs = {m: str(tmp_path / m) for m in ("inline", "bg")}
    engines = {
        "inline": make_engine(strategy="gloran", shards=2,
                              scheduler=False, wal_dir=dirs["inline"]),
        "bg": make_engine(strategy="gloran", shards=2, scheduler=True,
                          wal_dir=dirs["bg"]),
    }
    ops = mixed_ops(23, n_rounds=6)
    for eng in engines.values():
        for op in ops:
            if op[0] == "put":
                eng.put_batch(op[1], op[2])
            elif op[0] == "del":
                eng.delete_batch(op[1])
            elif op[0] == "rdel":
                eng.range_delete(op[1], op[2])
            elif op[0] == "flush":
                eng.flush()
        eng.close()
    ra = recover(dirs["inline"])
    rb = recover(dirs["bg"])
    assert_same_store(ra, rb, io=False)
    # Each recovered store also matches its own live original shape.
    assert ra.recovery["frames_replayed"] > 0
    ra.close()
    rb.close()


# ----------------------------------------------- property: any stream
if HAS_HYPOTHESIS:

    @st.composite
    def op_streams(draw):
        n = draw(st.integers(min_value=3, max_value=10))
        ops = []
        for _ in range(n):
            kind = draw(st.sampled_from(
                ["put", "del", "rdel", "flush", "get", "scan"]))
            if kind == "put":
                seed = draw(st.integers(0, 2**16))
                size = draw(st.integers(1, 160))
                rng = np.random.default_rng(seed)
                keys = rng.integers(1, UNIVERSE - 1,
                                    size).astype(np.uint64)
                ops.append(("put", keys, keys + np.uint64(seed % 97)))
            elif kind == "del":
                seed = draw(st.integers(0, 2**16))
                rng = np.random.default_rng(seed)
                ops.append(("del", rng.integers(
                    1, UNIVERSE - 1, draw(st.integers(1, 40))
                ).astype(np.uint64)))
            elif kind == "rdel":
                lo = draw(st.integers(1, UNIVERSE - 3))
                ops.append(("rdel", lo,
                            lo + draw(st.integers(1, 4000))))
            elif kind == "get":
                seed = draw(st.integers(0, 2**16))
                rng = np.random.default_rng(seed)
                ops.append(("get", rng.integers(
                    1, UNIVERSE - 1, 64).astype(np.uint64)))
            elif kind == "scan":
                lo = draw(st.integers(0, UNIVERSE - 2))
                ops.append(("scan", lo, lo + draw(st.integers(1, 5000))))
            else:
                ops.append(("flush",))
        return ops

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(strategy=st.sampled_from(STRATEGIES),
           shards=st.sampled_from([1, 2, 4]),
           max_frozen=st.sampled_from([1, 4]),
           ops=op_streams())
    def test_background_identity_property(strategy, shards, max_frozen,
                                          ops):
        inline = make_engine(strategy=strategy, shards=shards,
                             scheduler=False)
        bg = make_engine(strategy=strategy, shards=shards,
                         scheduler=True, max_frozen=max_frozen)
        apply_and_compare(inline, bg, ops)
        inline.flush()
        bg.flush()
        assert_same_store(inline, bg)
else:  # pragma: no cover - optional dependency missing

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_background_identity_property():
        pass


# --------------------------------------- proactive tombstone trigger
def test_proactive_trigger_reclaims_gloran_garbage():
    """With ``tombstone_trigger`` set, tombstone-dense levels compact
    proactively: the GC floor advances and the global index sheds
    obsolete range records — without changing any visible result."""
    oracle = make_engine(strategy="gloran", shards=1, scheduler=False)
    plain = make_engine(strategy="gloran", shards=1, scheduler=True)
    eager = make_engine(strategy="gloran", shards=1, scheduler=True,
                        tombstone_trigger=0.05)
    rng = np.random.default_rng(5)
    keys = rng.integers(1, UNIVERSE - 1, 1500).astype(np.uint64)
    for eng in (oracle, plain, eager):
        eng.put_batch(keys, keys * np.uint64(7))
        for j in range(24):  # dense range-delete burst
            lo = 1 + j * (UNIVERSE // 32)
            eng.range_delete(lo, lo + UNIVERSE // 40)
        eng.put_batch(keys[:40], keys[:40] + np.uint64(1))  # plan kick
        eng.drain()
    probes = np.arange(1, UNIVERSE, 23, dtype=np.uint64)
    fo, vo = oracle.get_batch(probes)
    for eng in (plain, eager):
        f, v = eng.get_batch(probes)
        np.testing.assert_array_equal(f, fo)
        np.testing.assert_array_equal(v[f], vo[fo])
    assert eager.shards[0].scheduler.counters()["proactive_jobs"] > 0
    assert plain.shards[0].scheduler.counters()["proactive_jobs"] == 0
    ge, gp = eager.shards[0].tree.gloran, plain.shards[0].tree.gloran
    # Proactive bottom compactions raise the GC floor at least as far
    # as drains alone, and strictly reclaim index records or floor.
    assert ge.gc_floor >= gp.gc_floor
    assert (ge.gc_floor > gp.gc_floor or
            ge.index.num_records <= gp.index.num_records)


# ------------------------------------- merge-rank compaction routing
@pytest.mark.parametrize("strategy", ["gloran", "lrr"])
def test_compaction_merge_rank_parity(strategy):
    """Compaction ordering through the merge-rank kernel is bit-exact
    with the host searchsorted path: same levels, same I/O charges."""
    host = make_engine(strategy=strategy, shards=1,
                       use_merge_kernel=False)
    kern = make_engine(strategy=strategy, shards=1,
                       use_merge_kernel=True, kernel_min_merge=1)
    apply_and_compare(host, kern, mixed_ops(13, n_rounds=8))
    host.flush()
    kern.flush()
    assert_same_store(host, kern)


# --------------------------------------- vectorized presorted builds
def test_build_sstable_presorted_matches_lexsort():
    rng = np.random.default_rng(17)
    cfg = small_lsm()
    n = 700
    keys = rng.integers(1, 400, n).astype(np.uint64)  # many duplicates
    seqs = rng.permutation(np.arange(1, n + 1)).astype(np.uint64)
    types = (rng.integers(0, 2, n)).astype(np.uint8)
    vals = rng.integers(0, 1 << 40, n).astype(np.uint64)
    legacy = build_sstable(keys, seqs, types, vals, cfg, seed=3)
    order = np.lexsort((seqs, keys))  # key-major; presorted contract
    pre = build_sstable(keys[order], seqs[order], types[order],
                        vals[order], cfg, seed=3, presorted=True)
    np.testing.assert_array_equal(legacy.keys, pre.keys)
    np.testing.assert_array_equal(legacy.seqs, pre.seqs)
    np.testing.assert_array_equal(legacy.types, pre.types)
    np.testing.assert_array_equal(legacy.vals, pre.vals)
    np.testing.assert_array_equal(legacy.bloom.words, pre.bloom.words)


def test_vectorized_flush_keeps_last_write():
    tree = LSMTree(small_lsm(), strategy="decomp")
    for k in range(40):
        tree.put(k % 16, k)  # overwrites wrap the memtable
    tree.flush()
    run = next(lvl for lvl in tree.levels if lvl is not None and
               len(lvl))
    assert list(run.keys) == sorted(set(run.keys))
    for k, v in zip(run.keys, run.vals):
        assert tree.get(int(k)) == int(v)


# ------------------------------------------------- metrics surfacing
def test_scheduler_and_per_level_metrics_surface():
    eng = make_engine(strategy="lrr", shards=2, scheduler=True)
    apply_and_compare(eng, eng, [])  # no-op; keep helper honest
    rng = np.random.default_rng(29)
    keys = rng.integers(1, UNIVERSE - 1, 900).astype(np.uint64)
    eng.put_batch(keys, keys)
    eng.range_delete(10, 9000)
    eng.put_batch(keys[:50], keys[:50])
    stt = eng.stats()
    assert stt["sched"]["flush_jobs"] > 0
    assert stt["sched"]["queue_depth"] == 0  # stats() drains first
    m = stt["metrics"]
    assert m.get("sched.flush_jobs", 0) > 0
    assert "lsm.compaction.bytes.L0" in m
    assert any(k.startswith("lsm.rt_density.L") for k in m)
    lsm = stt["lsm"]
    assert any(k.startswith("rt_compaction.bytes.") for k in lsm)
