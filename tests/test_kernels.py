"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle vs
host numpy, across shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.eve import BloomBits, fold64to32
from repro.kernels.bloom.ops import bloom_probe
from repro.kernels.bloom.ref import bloom_probe_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.interval.ops import interval_query
from repro.kernels.interval.ref import interval_query_ref
from repro.kernels.ssd.ops import ssd_chunked_scan
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref


# --------------------------------------------------------------- bloom
@pytest.mark.parametrize("m_bits,n_hashes,n_keys", [
    (1 << 10, 4, 100), (1 << 14, 6, 1000), (1 << 16, 7, 5000),
])
def test_bloom_kernel_matches_host_filter(m_bits, n_hashes, n_keys):
    rng = np.random.default_rng(m_bits)
    bb = BloomBits(m_bits, n_hashes, seed=0x5EED)
    inserted = rng.integers(0, 1 << 62, size=n_keys).astype(np.uint64)
    bb.insert(inserted)
    probes = np.concatenate([
        inserted[: n_keys // 2],
        rng.integers(0, 1 << 62, size=n_keys).astype(np.uint64)])
    want = bb.might_contain(probes)
    keys32 = fold64to32(probes)
    got = np.asarray(bloom_probe(keys32, bb.words, m_bits=bb.m_bits,
                                 seeds=tuple(int(s) for s in bb.seeds)))
    np.testing.assert_array_equal(got, want)
    ref = np.asarray(bloom_probe_ref(jnp.asarray(keys32), jnp.asarray(
        bb.words), m_bits=bb.m_bits,
        seeds=tuple(int(s) for s in bb.seeds))).astype(bool)
    np.testing.assert_array_equal(ref, want)


def test_bloom_kernel_no_false_negatives():
    bb = BloomBits(1 << 12, 5)
    keys = np.arange(1, 500, dtype=np.uint64) * np.uint64(2654435761)
    bb.insert(keys)
    got = np.asarray(bloom_probe(fold64to32(keys), bb.words,
                                 m_bits=bb.m_bits,
                                 seeds=tuple(int(s) for s in bb.seeds)))
    assert got.all()


def test_bloom_chunked_path():
    from repro.kernels.bloom import ops as bops
    old = bops.MAX_WORDS_PER_CALL
    bops.MAX_WORDS_PER_CALL = 32  # force chunking
    try:
        bb = BloomBits(1 << 12, 4)  # 128 words -> 4 chunks
        keys = np.arange(1, 300, dtype=np.uint64) * np.uint64(11400714819)
        bb.insert(keys)
        probes = np.concatenate([keys, keys + np.uint64(1)])
        want = bb.might_contain(probes)
        got = np.asarray(bloom_probe(fold64to32(probes), bb.words,
                                     m_bits=bb.m_bits,
                                     seeds=tuple(int(s) for s in bb.seeds)))
        np.testing.assert_array_equal(got, want)
    finally:
        bops.MAX_WORDS_PER_CALL = old


# ------------------------------------------------------------- interval
def _random_disjoint(rng, n, universe=1 << 30, max_seq=1 << 20):
    los = np.sort(rng.choice(universe, size=2 * n, replace=False)
                  .astype(np.uint32))
    lo, hi = los[0::2], los[1::2]
    smax = rng.integers(1, max_seq, size=n).astype(np.uint32)
    smin = (smax * rng.random(n) * 0.5).astype(np.uint32)
    return lo, hi, smin, smax


@pytest.mark.parametrize("n_areas,n_queries", [(1, 64), (37, 500),
                                               (1024, 4096), (4097, 1000)])
def test_interval_kernel_matches_oracle(n_areas, n_queries):
    rng = np.random.default_rng(n_areas)
    lo, hi, smin, smax = _random_disjoint(rng, n_areas)
    keys = rng.integers(0, 1 << 30, size=n_queries).astype(np.uint32)
    # Half the probes land inside known intervals.
    pick = rng.integers(0, n_areas, size=n_queries // 2)
    keys[: n_queries // 2] = (lo[pick] + (hi[pick] - lo[pick]) // 2)
    seqs = rng.integers(0, 1 << 20, size=n_queries).astype(np.uint32)
    got = np.asarray(interval_query(keys, seqs, lo, hi, smin, smax))
    want = np.asarray(interval_query_ref(
        jnp.asarray(keys), jnp.asarray(seqs), jnp.asarray(lo),
        jnp.asarray(hi), jnp.asarray(smin), jnp.asarray(smax))).astype(bool)
    np.testing.assert_array_equal(got, want)
    # And against the numpy brute force.
    brute = ((lo[None, :] <= keys[:, None]) & (keys[:, None] < hi[None, :])
             & (smin[None, :] <= seqs[:, None])
             & (seqs[:, None] < smax[None, :])).any(axis=1)
    np.testing.assert_array_equal(got, brute)


def test_interval_chunked_path():
    from repro.kernels.interval import ops as iops
    old = iops.MAX_AREAS_PER_CALL
    iops.MAX_AREAS_PER_CALL = 64
    try:
        rng = np.random.default_rng(0)
        lo, hi, smin, smax = _random_disjoint(rng, 300)
        keys = rng.integers(0, 1 << 30, size=777).astype(np.uint32)
        seqs = rng.integers(0, 1 << 20, size=777).astype(np.uint32)
        got = np.asarray(interval_query(keys, seqs, lo, hi, smin, smax))
        brute = ((lo[None] <= keys[:, None]) & (keys[:, None] < hi[None])
                 & (smin[None] <= seqs[:, None])
                 & (seqs[:, None] < smax[None])).any(axis=1)
        np.testing.assert_array_equal(got, brute)
    finally:
        iops.MAX_AREAS_PER_CALL = old


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,causal,window", [
    (1, 128, 128, 4, 4, 64, True, None),      # MHA causal
    (2, 256, 256, 8, 2, 64, True, None),      # GQA 4:1
    (1, 128, 128, 4, 1, 128, True, 64),       # MQA + sliding window
    (2, 100, 100, 4, 2, 64, True, None),      # non-multiple seq (padding)
    (1, 64, 320, 4, 2, 64, True, None),       # decode-style suffix align
    (1, 128, 128, 4, 4, 64, False, None),     # non-causal
])
def test_flash_attention_matches_ref(b, sq, skv, hq, hkv, d, causal, window,
                                     dtype):
    rng = np.random.default_rng(sq + skv + hq)
    q = jnp.asarray(rng.standard_normal((b, sq, hq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype=dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_flash_attention_window_equals_masked_full():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 192, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 192, 4, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 192, 4, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=32, block_q=64,
                          block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------------ ssd
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32), (1, 256, 2, 64, 32, 64),
])
def test_ssd_chunked_ref_matches_quadratic(b, s, h, p, n, chunk):
    rng = np.random.default_rng(s + h)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(h) - 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    want = ssd_ref(x, dt, A, B, C)
    got = ssd_chunked_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 2, 32, 16, 32),
])
def test_ssd_kernel_matches_ref(b, s, h, p, n, chunk):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(h) - 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    want = ssd_chunked_ref(x, dt, A, B, C, chunk=chunk)
    got = ssd_chunked_scan(x, dt, A, B, C, chunk=chunk, use_kernel=True,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


def test_ssd_grad_flows_through_ref():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    dt = jnp.asarray(rng.random((1, 32, 2)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random(2) - 0.1, jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    g = jax.grad(lambda xx: ssd_chunked_ref(xx, dt, A, B, C,
                                            chunk=16).sum())(x)
    assert np.isfinite(np.asarray(g)).all()
