"""StagingBuffer vs. the historical R-tree write buffer: parity suite.

The columnar staging buffer replaced the Guttman R-tree as the
LSM-DRtree's write buffer.  These tests pin the contract that made that
swap invisible: identical flush trigger points, identical disjointize
output at every flush, and identical point-stab answers over arbitrary
insert/probe interleavings (under the system invariant — ``smin`` at
the GC floor — which is what ``GloranIndex.range_delete`` always
inserts).
"""

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import (AreaSet, DRTree, GloranConfig, GloranIndex,
                        LSMDRTree, LSMDRTreeConfig, RTree, StagingBuffer,
                        disjointize, disjointize_arrays)


class RTreeBufferHarness:
    """The pre-refactor buffer protocol: per-record R-tree descent on
    insert, raw-rectangle stabbing on probe, disjointize-on-flush."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.tree = RTree()
        self.flushes = []

    def insert(self, lo, hi, smin, smax):
        self.tree.insert(lo, hi, smin, smax)
        if self.tree.size >= self.capacity:
            self.flushes.append(disjointize(self.tree.extract_all()))
            self.tree.clear()

    def covers(self, key, seq):
        return self.tree.covers(key, seq)

    @property
    def size(self):
        return self.tree.size


class StagingHarness:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self.buf = StagingBuffer(capacity)
        self.flushes = []

    def insert(self, lo, hi, smin, smax):
        self.buf.insert(lo, hi, smin, smax)
        if self.buf.size >= self.capacity:
            # Both flush forms must agree: the incrementally merged view
            # and a one-shot disjointize of the raw records.
            drained = self.buf.drain_disjoint()
            oneshot = disjointize(self.buf.extract_all())
            np.testing.assert_array_equal(drained.to_records(),
                                          oneshot.to_records())
            self.flushes.append(drained)
            self.buf.clear()

    def covers(self, key, seq):
        return self.buf.covers(key, seq)

    @property
    def size(self):
        return self.buf.size


def _run_interleaving(ops, capacity):
    """Drive both buffers through one op stream; assert parity."""
    old = RTreeBufferHarness(capacity)
    new = StagingHarness(capacity)
    for op in ops:
        if op[0] == "ins":
            _, lo, hi, smin, smax = op
            old.insert(lo, hi, smin, smax)
            new.insert(lo, hi, smin, smax)
            assert old.size == new.size  # identical flush points
        else:
            _, key, seq = op
            assert old.covers(key, seq) == new.covers(key, seq), \
                f"probe divergence at {op}"
    assert len(old.flushes) == len(new.flushes)
    for a, b in zip(old.flushes, new.flushes):
        np.testing.assert_array_equal(a.to_records(), b.to_records())


if HAS_HYPOTHESIS:
    @st.composite
    def interleavings(draw, max_ops=120, universe=300, max_seq=80):
        """Mixed insert/probe streams under the system invariant."""
        floor = draw(st.integers(0, 4))
        n = draw(st.integers(1, max_ops))
        ops = []
        for _ in range(n):
            if draw(st.booleans()):
                lo = draw(st.integers(0, universe - 2))
                hi = draw(st.integers(lo + 1, universe))
                smax = draw(st.integers(floor + 1, max_seq))
                ops.append(("ins", lo, hi, floor, smax))
            else:
                ops.append(("probe", draw(st.integers(0, universe + 10)),
                            draw(st.integers(0, max_seq + 10))))
        return ops

    @settings(max_examples=80, deadline=None)
    @given(interleavings(), st.integers(2, 24))
    def test_staging_matches_rtree_buffer(ops, capacity):
        _run_interleaving(ops, capacity)

    @settings(max_examples=60, deadline=None)
    @given(interleavings(max_ops=60), st.data())
    def test_staging_covers_batch_matches_scalar(ops, data):
        buf = StagingBuffer()
        for op in ops:
            if op[0] == "ins":
                _, lo, hi, smin, smax = op
                buf.insert(lo, hi, smin, smax)
        keys = np.array([data.draw(st.integers(0, 310)) for _ in range(16)],
                        dtype=np.uint64)
        seqs = np.array([data.draw(st.integers(0, 90)) for _ in range(16)],
                        dtype=np.uint64)
        got = buf.covers_batch(keys, seqs)
        want = np.array([buf.covers(int(k), int(s))
                         for k, s in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests "
                             "not collected")
    def test_staging_property_suite_requires_hypothesis():
        pass


def test_fixed_interleaving_parity():
    """A deterministic regression net under the property tests."""
    rng = np.random.default_rng(42)
    ops = []
    for _ in range(400):
        if rng.random() < 0.7:
            lo = int(rng.integers(0, 2000))
            hi = lo + int(rng.integers(1, 150))
            ops.append(("ins", lo, hi, 0, int(rng.integers(1, 500))))
        else:
            ops.append(("probe", int(rng.integers(0, 2200)),
                        int(rng.integers(0, 520))))
    _run_interleaving(ops, capacity=16)


def test_insert_batch_chunks_at_flush_boundaries():
    """Batch absorb must flush at exactly the per-record trigger points:
    level shapes and record counts end up identical."""
    cfg = LSMDRTreeConfig(buffer_capacity=32, size_ratio=3)
    one, batch = LSMDRTree(cfg), LSMDRTree(cfg)
    rng = np.random.default_rng(7)
    los = rng.integers(0, 50_000, size=500).astype(np.uint64)
    his = los + rng.integers(1, 400, size=500).astype(np.uint64)
    seqs = np.arange(1, 501, dtype=np.uint64)
    for lo, hi, s in zip(los.tolist(), his.tolist(), seqs.tolist()):
        one.insert(lo, hi, smax=s)
    batch.insert_batch(los, his, smaxs=seqs)
    assert one.buffer.size == batch.buffer.size
    assert one.records_inserted == batch.records_inserted
    assert len(one.levels) == len(batch.levels)
    for a, b in zip(one.levels, batch.levels):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(a.areas.to_records(),
                                          b.areas.to_records())
    assert one.io.snapshot() == batch.io.snapshot()


def test_insert_batch_larger_than_capacity():
    cfg = LSMDRTreeConfig(buffer_capacity=8, size_ratio=2)
    t = LSMDRTree(cfg)
    n = 100
    los = np.arange(n, dtype=np.uint64) * 10
    t.insert_batch(los, los + 5, smaxs=np.arange(1, n + 1, dtype=np.uint64))
    assert t.records_inserted == n
    assert t.buffer.size < cfg.buffer_capacity
    assert t.num_records == n  # fully disjoint input: nothing merged away


def test_columnar_entry_points():
    """The columnar bulk-load surface: flat arrays in, no tuples."""
    rng = np.random.default_rng(11)
    lo = rng.integers(0, 10_000, size=300).astype(np.uint64)
    hi = lo + rng.integers(1, 500, size=300).astype(np.uint64)
    smin = np.zeros(300, dtype=np.uint64)
    smax = rng.integers(1, 1000, size=300).astype(np.uint64)
    d1 = disjointize_arrays(lo, hi, smin, smax)
    d2 = disjointize(AreaSet.from_arrays(lo, hi, smin, smax))
    np.testing.assert_array_equal(d1.to_records(), d2.to_records())
    t = DRTree.from_arrays(d1.lo, d1.hi, d1.smin, d1.smax)
    keys = rng.integers(0, 11_000, size=200).astype(np.uint64)
    seqs = rng.integers(0, 1100, size=200).astype(np.uint64)
    np.testing.assert_array_equal(
        t.query_batch(keys, seqs), d1.covers_batch_bruteforce(keys, seqs))
    with pytest.raises(AssertionError):  # non-canonical arrays rejected
        DRTree.from_arrays(lo, hi, smin, smax)


def test_probe_view_reused_across_probes():
    """The disjointized view is built lazily and reused until the next
    append invalidates it (amortization contract)."""
    buf = StagingBuffer()
    buf.insert_batch(np.array([0, 100], np.uint64),
                     np.array([50, 200], np.uint64),
                     np.array([0, 0], np.uint64),
                     np.array([10, 20], np.uint64))
    v1 = buf.view
    assert buf.view is v1  # no rebuild without appends
    assert buf.covers(0, 5) and not buf.covers(60, 5)
    buf.insert(300, 400, 0, 30)
    v2 = buf.view
    assert v2 is not v1
    assert len(v2) == 3


def test_memory_bytes_counts_records_and_view():
    """GloranIndex accounting: resident raw records plus the disjoint
    probe view, all four key-sized fields each (paper model)."""
    cfg = GloranConfig(index=LSMDRTreeConfig(buffer_capacity=1024,
                                             key_size=16),
                       use_eve=False)
    g = GloranIndex(cfg)
    for seq in range(1, 101):
        g.range_delete(seq * 10, seq * 10 + 5, seq)
    assert g.index.buffer.size == 100
    # No probes yet: the lazy view is empty, only raw records resident.
    assert g.memory_bytes == 100 * 4 * cfg.index.key_size
    assert g.is_deleted(12, 0)  # forces the view build
    view_n = len(g.index.buffer.view)
    assert view_n == 100  # disjoint inserts: view == records
    assert g.memory_bytes == (100 + view_n) * 4 * cfg.index.key_size


def test_engine_stats_expose_staging_occupancy():
    from repro.engine import Engine, EngineConfig
    from repro.lsm import LSMConfig
    eng = Engine(num_shards=2, strategy="gloran",
                 lsm_config=LSMConfig(buffer_capacity=4096,
                                      key_universe=1 << 20),
                 config=EngineConfig(partition="range"))
    eng.range_delete_batch([(i * 100, i * 100 + 50) for i in range(40)])
    snap = eng.stats()["engine"]["staging_buffer"]
    assert snap["records"] == 40
    assert snap["capacity"] > 0
    assert 0 < snap["occupancy"] <= 1
    assert len(snap["per_shard"]) == 2
    assert sum(d["records"] for d in snap["per_shard"]) == 40
