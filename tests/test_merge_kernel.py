"""Merge-rank kernel parity: Pallas (interpret + compiled-XLA dispatch)
vs the host searchsorted oracle, and end-to-end through the LSM scan
merge — including duplicate keys within/across runs and tombstones at
range boundaries.

``interpret`` runs the Pallas kernel in interpreter mode (the only
Pallas mode off-TPU); ``compiled`` runs the jit'd XLA dispatch path so
every CI cell also exercises a compiled artifact (on TPU backends the
Pallas kernel itself compiles).
"""

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.merge import ops as merge_ops
from repro.kernels.merge.ops import merge_ranks
from repro.kernels.merge.ref import merge_ranks_np, merge_ranks_ref
from repro.lsm.format import PUT, TOMBSTONE
from repro.lsm.merge import merge_runs, merge_two, newest_wins

MODES = ("interpret", "compiled")


def _ranks(ka, kb, mode):
    if mode == "compiled":
        return merge_ranks(ka, kb, compiled=True)
    return merge_ranks(ka, kb, interpret=True)


@pytest.mark.parametrize("mode", MODES)
def test_ranks_match_oracle_random(mode):
    rng = np.random.default_rng(0)
    for _ in range(6):
        na, nb = rng.integers(1, 4000, size=2)
        ka = np.sort(rng.integers(0, 5000, na)).astype(np.uint32)
        kb = np.sort(rng.integers(0, 5000, nb)).astype(np.uint32)
        pa, pb = _ranks(ka, kb, mode)
        wa, wb = merge_ranks_np(ka, kb)
        np.testing.assert_array_equal(pa, wa)
        np.testing.assert_array_equal(pb, wb)


@pytest.mark.parametrize("mode", MODES)
def test_ranks_duplicate_heavy(mode):
    """Dense duplicates within AND across runs: every tie must place
    a-entries first, exactly like the host pair."""
    rng = np.random.default_rng(1)
    ka = np.sort(rng.integers(0, 8, 600)).astype(np.uint32)
    kb = np.sort(rng.integers(0, 8, 500)).astype(np.uint32)
    pa, pb = _ranks(ka, kb, mode)
    wa, wb = merge_ranks_np(ka, kb)
    np.testing.assert_array_equal(pa, wa)
    np.testing.assert_array_equal(pb, wb)
    # Positions form a permutation of the merged output slots.
    assert sorted(np.concatenate([pa, pb]).tolist()) == \
        list(range(len(ka) + len(kb)))


@pytest.mark.parametrize("mode", MODES)
def test_ranks_edge_shapes(mode):
    one = np.array([7], np.uint32)
    many = np.arange(100, dtype=np.uint32)
    for ka, kb in ((one, many), (many, one), (one, one.copy())):
        pa, pb = _ranks(ka, kb, mode)
        wa, wb = merge_ranks_np(ka, kb)
        np.testing.assert_array_equal(pa, wa)
        np.testing.assert_array_equal(pb, wb)


def test_chunked_resident_run(monkeypatch):
    """Oversized resident runs split into contiguous sorted chunks whose
    per-chunk counts add — verdicts identical to one big call."""
    monkeypatch.setattr(merge_ops, "MAX_KEYS_PER_CALL", 256)
    rng = np.random.default_rng(2)
    ka = np.sort(rng.integers(0, 3000, 1500)).astype(np.uint32)
    kb = np.sort(rng.integers(0, 3000, 900)).astype(np.uint32)
    pa, pb = merge_ranks(ka, kb, interpret=True)
    wa, wb = merge_ranks_np(ka, kb)
    np.testing.assert_array_equal(pa, wa)
    np.testing.assert_array_equal(pb, wb)


def test_jnp_ref_matches_np():
    rng = np.random.default_rng(3)
    ka = np.sort(rng.integers(0, 50, 200)).astype(np.uint32)
    kb = np.sort(rng.integers(0, 50, 300)).astype(np.uint32)
    pa, pb = merge_ranks_ref(ka, kb)
    wa, wb = merge_ranks_np(ka, kb)
    np.testing.assert_array_equal(np.asarray(pa), wa)
    np.testing.assert_array_equal(np.asarray(pb), wb)


def _run(keys, seqs, typs):
    keys = np.asarray(keys, np.uint64)
    return (keys, np.asarray(seqs, np.uint64),
            np.asarray(typs, np.uint8),
            keys + np.uint64(1))


@pytest.mark.parametrize("mode", MODES)
def test_scan_merge_with_tombstone_boundaries(mode):
    """End-to-end through ``lsm.merge``: duplicate keys across runs with
    TOMBSTONE entries exactly at the (shared) boundary keys — the
    newest-wins resolution must be bit-identical with and without the
    kernel rank path."""
    # Run A (older level): puts at 10..19; boundary keys 10 and 19 alive.
    a = _run(range(10, 20), range(1, 11), [PUT] * 10)
    # Run B (newer): tombstones at the boundary keys 10 and 19 plus a
    # duplicate put at 15, all with higher seqs.
    b = _run([10, 15, 19], [20, 21, 22], [TOMBSTONE, PUT, TOMBSTONE])

    def rank_fn(ka, kb):
        return _ranks(ka.astype(np.uint32), kb.astype(np.uint32), mode)

    host = newest_wins(*merge_two(a, b))
    kern = newest_wins(*merge_two(a, b, rank_fn=rank_fn))
    for x, y in zip(host, kern):
        np.testing.assert_array_equal(x, y)
    # Boundary keys resolve to the tombstones (newest), key 15 to seq 21.
    keys, seqs, typs, _ = kern
    assert typs[keys == 10][0] == TOMBSTONE
    assert typs[keys == 19][0] == TOMBSTONE
    assert seqs[keys == 15][0] == 21

    # Tournament over k runs with the kernel on every round.
    c = _run([12, 12, 30], [30, 31, 32], [PUT, TOMBSTONE, PUT])
    host_k = newest_wins(*merge_runs([a, b, c]))
    kern_k = newest_wins(*merge_runs([a, b, c], rank_fn=rank_fn))
    for x, y in zip(host_k, kern_k):
        np.testing.assert_array_equal(x, y)


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, 40), min_size=1, max_size=80),
           st.lists(st.integers(0, 40), min_size=1, max_size=80),
           st.sampled_from(MODES))
    def test_ranks_property(xs, ys, mode):
        ka = np.sort(np.asarray(xs, np.uint32))
        kb = np.sort(np.asarray(ys, np.uint32))
        pa, pb = _ranks(ka, kb, mode)
        wa, wb = merge_ranks_np(ka, kb)
        np.testing.assert_array_equal(pa, wa)
        np.testing.assert_array_equal(pb, wb)
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests "
                             "not collected")
    def test_merge_rank_property_suite_requires_hypothesis():
        pass
