"""Fused lookup-cascade parity suite.

The cascade replaces the engine read path's per-level kernel launches
(one bloom launch per SSTable level + one interval launch per DR-tree
level, each re-uploading filter state) with ONE launch over persistent
device arrays.  These tests pin the contract that makes that swap
invisible:

  * results AND simulated I/O charges are bit-identical cascade-on vs
    cascade-off, across all 5 range-delete strategies x shard counts,
    in both dispatch modes (interpret-mode Pallas and the jit'd XLA
    fallback CPU CI compiles);
  * exactly one cascade launch per ``get_batch`` regardless of how many
    levels the tree has (the whole point of the fusion);
  * compaction/flush invalidation: a stale device pack must never serve
    a post-compaction lookup;
  * the kernel agrees with an independent numpy oracle on random packed
    states;
  * the vectorized memtable/put/delete batch paths keep flush points
    and results identical to the historical per-record loops.
"""

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.core.eve import BloomBits, fold64to32
from repro.engine import Engine, EngineConfig
from repro.kernels.cascade.ops import CascadeState, cascade_lookup
from repro.kernels.cascade.ref import cascade_np
from repro.lsm import LSMConfig, LSMTree, STRATEGIES

UNIVERSE = 1 << 20
MODES = ("interpret", "compiled")


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512, key_universe=UNIVERSE)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran(index_buffer=16):
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=index_buffer,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def engine_cfg(*, cascade: bool, mode: str = "compiled", **kw):
    # procs pinned off: this suite reaches into eng.shards[s].tree for
    # registry/epoch assertions, which needs in-process shards.
    d = dict(cache_blocks=512, kernel_min_batch=1, kernel_min_areas=1,
             kernel_min_filter=1, use_cascade_kernel=cascade,
             cascade_compiled=(mode == "compiled"), procs=0)
    d.update(kw)
    return EngineConfig(**d)


def drive(store, rng, rounds=5, universe=2000):
    """A mixed put/delete/range-delete workload with plenty of flushes."""
    for _ in range(rounds):
        keys = rng.integers(0, universe, size=220).astype(np.uint64)
        store.put_batch(keys, keys * np.uint64(3) + np.uint64(1))
        store.delete_batch(rng.integers(0, universe, size=30)
                           .astype(np.uint64))
        for _ in range(6):
            lo = int(rng.integers(0, universe - 80))
            store.range_delete(lo, lo + int(rng.integers(1, 64)))


def build_engine(strategy, shards, cascade, mode, seed=42):
    g = small_gloran() if strategy == "gloran" else None
    eng = Engine(num_shards=shards, strategy=strategy,
                 lsm_config=small_cfg(), gloran_config=g,
                 config=engine_cfg(cascade=cascade, mode=mode))
    drive(eng, np.random.default_rng(seed))
    return eng


def io_snapshots(eng):
    return [sh.tree.io.snapshot() for sh in eng.shards]


# ---------------------------------------------------------------- parity
class TestEngineParity:
    """Cascade-on must be indistinguishable from cascade-off in results
    and in every I/O ledger entry."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("shards", (1, 2, 4))
    def test_results_and_io_identical(self, strategy, shards):
        rng = np.random.default_rng(9)
        probe = rng.integers(0, 2100, size=700).astype(np.uint64)
        on = build_engine(strategy, shards, True, "compiled")
        off = build_engine(strategy, shards, False, "compiled")
        f1, v1 = on.get_batch(probe)
        f0, v0 = off.get_batch(probe)
        np.testing.assert_array_equal(f1, f0)
        np.testing.assert_array_equal(v1[f1], v0[f0])
        assert io_snapshots(on) == io_snapshots(off), strategy
        assert on.kernel_counters.cascade_calls > 0

    @pytest.mark.parametrize("mode", MODES)
    def test_dispatch_modes_agree(self, mode):
        """Interpret-mode Pallas and the compiled XLA fallback both
        reproduce the per-level path exactly (gloran, the richest
        stack: bloom + fence + GLORAN interval columns)."""
        rng = np.random.default_rng(3)
        probe = rng.integers(0, 2100, size=500).astype(np.uint64)
        on = build_engine("gloran", 2, True, mode)
        off = build_engine("gloran", 2, False, mode)
        f1, v1 = on.get_batch(probe)
        f0, v0 = off.get_batch(probe)
        np.testing.assert_array_equal(f1, f0)
        np.testing.assert_array_equal(v1[f1], v0[f0])
        assert io_snapshots(on) == io_snapshots(off)

    def test_memtable_overlay_parity(self):
        """Unflushed memtable entries (wins over levels, tombstones,
        validity of memtable-resolved seqs) ride through the cascade."""
        probe = np.arange(0, 600, dtype=np.uint64)
        engines = []
        for cascade in (True, False):
            eng = build_engine("gloran", 1, cascade, "compiled")
            eng.put_batch(np.arange(100, 200, dtype=np.uint64),
                          np.full(100, 7, np.uint64))
            eng.delete_batch(np.arange(150, 170, dtype=np.uint64))
            engines.append(eng)
        (f1, v1), (f0, v0) = (e.get_batch(probe) for e in engines)
        np.testing.assert_array_equal(f1, f0)
        np.testing.assert_array_equal(v1[f1], v0[f0])
        assert io_snapshots(engines[0]) == io_snapshots(engines[1])


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000),
           nprobe=st.integers(1, 400),
           strategy=st.sampled_from(("gloran", "lrr")))
    def test_hypothesis_workload_parity(seed, nprobe, strategy):
        """Random workloads: found/vals/IO charges identical on/off."""
        rng = np.random.default_rng(seed)
        probe = rng.integers(0, 2100, size=nprobe).astype(np.uint64)
        on = build_engine(strategy, 2, True, "compiled", seed=seed)
        off = build_engine(strategy, 2, False, "compiled", seed=seed)
        f1, v1 = on.get_batch(probe)
        f0, v0 = off.get_batch(probe)
        np.testing.assert_array_equal(f1, f0)
        np.testing.assert_array_equal(v1[f1], v0[f0])
        assert io_snapshots(on) == io_snapshots(off)


# ------------------------------------------------------ launch counting
class TestLaunchFusion:
    def test_one_launch_per_get_batch_any_level_count(self):
        """The counter contract: one bloom-cascade launch per
        ``get_batch`` per shard, no matter how many levels exist, and
        zero per-level bloom/interval launches alongside it."""
        eng = Engine(num_shards=1, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=engine_cfg(cascade=True))
        rng = np.random.default_rng(1)
        drive(eng, rng, rounds=8)  # size-ratio-3 tree: several levels
        tree = eng.shards[0].tree
        levels = sum(1 for l in tree.levels if l is not None and len(l))
        assert levels >= 2, "workload must build a multi-level tree"
        probe = rng.integers(0, 2100, size=512).astype(np.uint64)
        for i in range(3):
            k0 = eng.kernel_counters
            eng.get_batch(probe)
            k1 = eng.kernel_counters
            assert k1.cascade_calls - k0.cascade_calls == 1, i
            assert k1.bloom_calls == k0.bloom_calls
            assert k1.interval_calls == k0.interval_calls
        assert eng.kernel_counters.cascade_queries >= 3 * 512

    def test_gating_declines_small_batches(self):
        eng = Engine(num_shards=1, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=engine_cfg(cascade=True, kernel_min_batch=4096))
        keys = np.arange(600, dtype=np.uint64)
        eng.put_batch(keys, keys)
        eng.flush()
        eng.get_batch(keys)
        assert eng.kernel_counters.cascade_calls == 0

    def test_steady_state_uploads_nothing(self):
        """Repeat lookups on an unchanged tree re-use the device pack:
        the upload ledger must not move."""
        eng = build_engine("gloran", 1, True, "compiled")
        probe = np.arange(0, 512, dtype=np.uint64)
        eng.get_batch(probe)
        up0 = eng.kernel_counters.upload_bytes
        packs0 = eng.kernel_counters.cascade_packs
        for _ in range(4):
            eng.get_batch(probe)
        assert eng.kernel_counters.upload_bytes == up0
        assert eng.kernel_counters.cascade_packs == packs0


# --------------------------------------------------------- invalidation
class TestInvalidation:
    def test_compaction_invalidates_device_pack(self):
        """Stale device arrays must never serve a post-compaction
        lookup: after writes/flushes/range deletes move the level set
        and the index epoch, the cascade answers from fresh state."""
        eng = build_engine("gloran", 1, True, "compiled")
        eng.get_batch(np.arange(0, 512, dtype=np.uint64))  # pack v1
        packs0 = eng.kernel_counters.cascade_packs
        keys = np.arange(3000, 3400, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(5))
        eng.range_delete(3000, 3100)
        eng.flush()
        probe = np.arange(2990, 3200, dtype=np.uint64)
        found, vals = eng.get_batch(probe)
        want = (probe >= 3100) & (probe < 3400)
        np.testing.assert_array_equal(found, want)
        np.testing.assert_array_equal(vals[found],
                                      probe[found] + np.uint64(5))
        assert eng.kernel_counters.cascade_packs > packs0

    def test_post_mutation_parity_stays_exact(self):
        """Interleaved lookups and mutations: every lookup round stays
        bit-identical (results + I/O) with the cascade-off twin."""
        rng = np.random.default_rng(77)
        engines = [build_engine("gloran", 2, c, "compiled", seed=77)
                   for c in (True, False)]
        for r in range(4):
            probe = rng.integers(0, 2400, size=300).astype(np.uint64)
            (f1, v1), (f0, v0) = (e.get_batch(probe) for e in engines)
            np.testing.assert_array_equal(f1, f0)
            np.testing.assert_array_equal(v1[f1], v0[f0])
            assert io_snapshots(engines[0]) == io_snapshots(engines[1]), r
            mut = np.random.default_rng(100 + r)
            for e in engines:
                drive(e, np.random.default_rng(100 + r), rounds=1)
            del mut


# ------------------------------------------------------- kernel oracle
def _pow2(n):
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


def random_pack(rng, n_levels, n_gl):
    """A random packed cascade state + its host-side mirror."""
    lk, ls, koff, kcnt, wds, woff, mb, sds = [], [], [], [], [], [], [], []
    at = wat = 0
    for l in range(n_levels):
        n = int(rng.integers(1, 400))
        keys = np.unique(rng.integers(0, 1 << 18, size=n)
                         .astype(np.uint64))
        n = len(keys)
        seqs = rng.integers(1, 1 << 18, size=n).astype(np.uint64)
        bb = BloomBits(max(64, n * 10), 6, seed=l + 3)
        bb.insert(keys)
        p = _pow2(n)
        lk.append(np.concatenate([keys.astype(np.uint32),
                                  np.full(p - n, 0xFFFFFFFF, np.uint32)]))
        ls.append(np.concatenate([seqs.astype(np.uint32),
                                  np.zeros(p - n, np.uint32)]))
        koff.append(at)
        kcnt.append(n)
        at += p
        wp = _pow2(len(bb.words))
        wds.append(np.concatenate([bb.words,
                                   np.zeros(wp - len(bb.words),
                                            np.uint32)]))
        woff.append(wat)
        wat += wp
        mb.append(bb.m_bits)
        sds.append(bb.seeds)
    glo = [[], [], [], []]
    goff, gcnt = [], []
    gat = 0
    for g in range(n_gl):
        n = int(rng.integers(0, 150))
        starts = np.sort(rng.choice(
            np.arange(0, 1 << 18, 5, dtype=np.uint64),
            size=n, replace=False)) if n else np.zeros(0, np.uint64)
        ends = starts + rng.integers(1, 5, size=n).astype(np.uint64) \
            if n else starts
        if n > 1:
            ends[:-1] = np.minimum(ends[:-1], starts[1:])
        p = max(64, _pow2(n))
        glo[0].append(np.concatenate(
            [starts.astype(np.uint32),
             np.full(p - n, 0xFFFFFFFF, np.uint32)]))
        glo[1].append(np.concatenate(
            [ends.astype(np.uint32),
             np.full(p - n, 0xFFFFFFFF, np.uint32)]))
        glo[2].append(np.zeros(p, np.uint32))
        glo[3].append(np.concatenate(
            [rng.integers(1, 1 << 18, size=n).astype(np.uint32),
             np.zeros(p - n, np.uint32)]))
        goff.append(gat)
        gcnt.append(n)
        gat += p
    import math
    host = dict(
        lkeys=np.concatenate(lk), lseqs=np.concatenate(ls),
        key_off=np.array(koff, np.int32),
        key_cnt=np.array(kcnt, np.int32),
        words=np.concatenate(wds), word_off=np.array(woff, np.int32),
        mbits=np.array(mb, np.uint32), seeds=np.stack(sds),
        glo_lo=(np.concatenate(glo[0]) if n_gl
                else np.zeros(1, np.uint32)),
        glo_hi=(np.concatenate(glo[1]) if n_gl
                else np.zeros(1, np.uint32)),
        glo_smin=(np.concatenate(glo[2]) if n_gl
                  else np.zeros(1, np.uint32)),
        glo_smax=(np.concatenate(glo[3]) if n_gl
                  else np.zeros(1, np.uint32)),
        gl_off=np.array(goff, np.int32), gl_cnt=np.array(gcnt, np.int32))
    state = CascadeState(
        **{k: jnp.asarray(v) for k, v in host.items()},
        L=n_levels, H=6, G=n_gl,
        steps_keys=int(math.ceil(math.log2(
            max(p.shape[0] for p in lk) + 1))) + 1,
        steps_gl=int(math.ceil(math.log2(
            (max(p.shape[0] for p in glo[0]) if n_gl else 1) + 1))) + 1,
        key_pad=tuple(p.shape[0] for p in lk),
        word_pad=tuple(p.shape[0] for p in wds),
        gl_pad=tuple(p.shape[0] for p in glo[0]))
    return state, host


class TestKernelOracle:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("n_levels,n_gl", [(1, 0), (2, 1), (4, 3)])
    def test_matches_numpy_oracle(self, mode, n_levels, n_gl):
        rng = np.random.default_rng(n_levels * 10 + n_gl)
        state, host = random_pack(rng, n_levels, n_gl)
        n = 333
        q = rng.integers(0, 1 << 18, size=n).astype(np.uint64)
        qh = fold64to32(q)
        qs = rng.integers(0, 1 << 18, size=n).astype(np.uint32)
        qr = (rng.random(n) < 0.2).astype(np.int32)
        bm, hm, gm, pos = cascade_np(q.astype(np.uint32), qh, qs, qr,
                                     **host)
        maybe, hit, gl, p2 = cascade_lookup(
            q.astype(np.uint32), qh, qs, qr, state,
            compiled=(mode == "compiled"), interpret=True)
        lbits = 1 << np.arange(n_levels)
        np.testing.assert_array_equal(
            (maybe * lbits).sum(1).astype(np.int32), bm)
        np.testing.assert_array_equal(
            (hit * lbits).sum(1).astype(np.int32), hm)
        if n_gl:
            gbits = 1 << np.arange(n_gl)
            np.testing.assert_array_equal(
                (gl * gbits).sum(1).astype(np.int32), gm)
        np.testing.assert_array_equal(p2, pos.T)


# ----------------------------------------- vectorized write/probe paths
class LoopTree(LSMTree):
    """The historical per-record write loops, as a parity reference."""

    def put_batch(self, keys, vals):
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.uint64)
        seqs = self._next_seqs(len(keys))
        for k, s, v in zip(keys.tolist(), seqs.tolist(), vals.tolist()):
            self.mem[k] = (s, 0, v)
            if len(self.mem) >= self.config.buffer_capacity:
                self.flush()

    def delete_batch(self, keys):
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = self._next_seqs(len(keys))
        for k, s in zip(keys.tolist(), seqs.tolist()):
            self.mem[k] = (s, 1, 0)
            if len(self.mem) >= self.config.buffer_capacity:
                self.flush()


class TestVectorizedWrites:
    @pytest.mark.parametrize("seed", (0, 1, 2))
    def test_flush_points_and_state_identical(self, seed):
        """Chunked dict-update inserts == per-record inserts: same
        flush points, level shapes, I/O charges, and lookup answers
        (duplicates inside a batch keep last-wins order)."""
        rng = np.random.default_rng(seed)
        a = LSMTree(small_cfg(), strategy="gloran",
                    gloran_config=small_gloran())
        b = LoopTree(small_cfg(), strategy="gloran",
                     gloran_config=small_gloran())
        for _ in range(6):
            keys = rng.integers(0, 500, size=150).astype(np.uint64)
            vals = rng.integers(0, 1 << 30, size=150).astype(np.uint64)
            a.put_batch(keys, vals)
            b.put_batch(keys, vals)
            dels = rng.integers(0, 500, size=40).astype(np.uint64)
            a.delete_batch(dels)
            b.delete_batch(dels)
            assert a.seq == b.seq
            assert a.mem == b.mem
            assert [len(l) if l is not None else 0 for l in a.levels] == \
                [len(l) if l is not None else 0 for l in b.levels]
        assert a.io.snapshot() == b.io.snapshot()
        probe = rng.integers(0, 600, size=400).astype(np.uint64)
        fa, va = a.get_batch(probe)
        fb, vb = b.get_batch(probe)
        np.testing.assert_array_equal(fa, fb)
        np.testing.assert_array_equal(va[fa], vb[fb])

    def test_memtable_probe_matches_scalar_get(self):
        """The sorted-snapshot memtable stage answers exactly what the
        per-key dict path (scalar ``get``) answers, tombstones
        included."""
        t = LSMTree(small_cfg(buffer_capacity=1 << 30), strategy="gloran",
                    gloran_config=small_gloran())
        rng = np.random.default_rng(8)
        keys = rng.integers(0, 300, size=200).astype(np.uint64)
        t.put_batch(keys, keys + np.uint64(1))
        t.delete_batch(rng.integers(0, 300, size=50).astype(np.uint64))
        assert t.mem  # everything still buffered
        probe = np.arange(0, 320, dtype=np.uint64)
        f, v = t.get_batch(probe)
        for j, k in enumerate(probe.tolist()):
            want = t.get(k)
            got = int(v[j]) if f[j] else None
            assert got == want, k
