"""Banded local attention == masked full attention with the same window."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import banded_local_attention, masked_attention


@pytest.mark.parametrize("b,s,hq,hkv,d,w", [
    (2, 128, 4, 2, 32, 32), (1, 256, 8, 1, 64, 64), (1, 96, 2, 2, 16, 16),
])
def test_banded_matches_masked(b, s, hq, hkv, d, w):
    rng = np.random.default_rng(s + w)
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    got = banded_local_attention(q, k, v, window=w)
    want = masked_attention(q, k, v, window=jnp.int32(w), q_offset=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_banded_first_block_has_no_phantom_prefix():
    # Padding band of block 0 must not contribute.
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    got = banded_local_attention(q, k, v, window=32)
    want = masked_attention(q, k, v, window=jnp.int32(32), q_offset=0)
    np.testing.assert_allclose(np.asarray(got[:, :32]),
                               np.asarray(want[:, :32]), atol=2e-5)
