"""Step-builder layer: input_specs / cache geometry / rule adjustment for
every (arch x shape) cell — fast (eval_shape only, no mesh, no compile)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.steps import input_specs, serve_cache_len
from repro.models import Transformer


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_all_cells(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        pytest.skip("full-attention arch skips long_500k (DESIGN.md)")
    model = Transformer(cfg)
    specs = input_specs(cfg, shape, model)
    if shape.kind == "train":
        key = "embeds" if cfg.stub_frontend else "tokens"
        assert specs[key].shape[:2] == (shape.global_batch, shape.seq_len)
        assert specs["labels"].shape == (shape.global_batch, shape.seq_len)
    elif shape.kind == "prefill":
        key = "embeds" if cfg.stub_frontend else "tokens"
        assert specs[key].shape[:2] == (shape.global_batch, shape.seq_len)
    else:
        cache_len, ring = serve_cache_len(cfg, shape)
        assert specs["token"].shape[0] == shape.global_batch
        assert specs["token"].shape[1] == 1
        leaves = jax.tree.leaves(specs["cache"])
        assert leaves, "decode needs a cache"
        if ring:
            assert cache_len < shape.seq_len  # window-bounded ring buffer
        # no allocation happened: everything is ShapeDtypeStruct
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in
                   jax.tree.leaves(specs))


def test_ring_cache_only_for_swa():
    for arch in ARCHS:
        cfg = get_config(arch)
        _, ring = serve_cache_len(cfg, SHAPES["decode_32k"])
        expect = cfg.window is not None and cfg.local_global is None \
            and cfg.family != "hybrid"
        assert ring == expect, arch


def test_hybrid_long_mode_windows_shared_attention():
    cfg = get_config("zamba2-7b")
    n, ring = serve_cache_len(cfg, SHAPES["long_500k"])
    assert ring and n == 4096
