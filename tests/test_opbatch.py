"""Plan/submit API: typed op batches, planner compilation, pipelined
vs serial result parity, op-stream ordering semantics, and
malformed-batch validation."""

import numpy as np
import pytest

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import (OP_GET, OP_PUT, OP_RANGE_DELETE, OP_RANGE_SCAN,
                          Engine, EngineConfig, OpBatch, Planner,
                          ShardRouter)
from repro.lsm import LSMConfig, STRATEGIES

UNIVERSE = 1 << 20


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512, key_universe=UNIVERSE)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran():
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=16,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def make_engine(strategy="gloran", num_shards=4, pipeline=None, **cfg_kw):
    g = small_gloran() if strategy == "gloran" else None
    cfg = EngineConfig(pipeline=pipeline, cache_blocks=256,
                       kernel_min_batch=1, kernel_min_areas=1,
                       kernel_min_filter=1, **cfg_kw)
    return Engine(num_shards=num_shards, strategy=strategy,
                  lsm_config=small_cfg(), gloran_config=g, config=cfg)


def mixed_stream(rng, n, universe=2000, max_len=40):
    """A mixed tuple op stream with every kind interleaved."""
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < 0.45:
            ops.append(("put", int(rng.integers(0, universe)),
                        int(rng.integers(1, 1 << 30))))
        elif r < 0.75:
            ops.append(("get", int(rng.integers(0, universe))))
        elif r < 0.83:
            ops.append(("delete", int(rng.integers(0, universe))))
        elif r < 0.92:
            lo = int(rng.integers(0, universe - 2))
            ops.append(("range_delete", lo,
                        lo + int(rng.integers(1, max_len))))
        else:
            lo = int(rng.integers(0, universe - 2))
            ops.append(("range_scan", lo,
                        lo + int(rng.integers(1, 200))))
    return ops


def assert_results_identical(a: list, b: list):
    assert len(a) == len(b)
    for i, (x, y) in enumerate(zip(a, b)):
        if isinstance(x, tuple):
            assert isinstance(y, tuple), i
            assert x[0].tobytes() == y[0].tobytes(), i
            assert x[1].tobytes() == y[1].tobytes(), i
        else:
            assert x == y, i


# ----------------------------------------------------------- construction
class TestOpBatchConstruction:
    def test_typed_constructors(self):
        b = OpBatch.gets([1, 2, 3])
        assert len(b) == 3 and b.kind_name == "get"
        assert b.get_ids.tolist() == [0, 1, 2]
        b = OpBatch.puts([1, 2], [10, 20])
        assert b.kind_name == "put" and b.vals.tolist() == [10, 20]
        b = OpBatch.range_scans([(0, 5), (9, 11)])
        assert b.kind_name == "range_scan"
        assert b.scan_ids.tolist() == [0, 1]
        assert OpBatch.deletes([7]).kind_name == "delete"
        assert OpBatch.range_deletes([(1, 2)]).kind_name == "range_delete"

    def test_from_ops_round_trip(self):
        ops = [("put", 1, 10), ("get", 1), ("delete", 2),
               ("range_delete", 0, 5), ("range_scan", 0, 9)]
        b = OpBatch.from_ops(ops)
        assert b.to_ops() == ops
        assert b.kind_name == "mixed"
        assert b.counts() == {"put": 1, "delete": 1, "get": 1,
                              "range_delete": 1, "range_scan": 1}

    def test_concat(self):
        b = OpBatch.concat([OpBatch.gets([1, 2]),
                            OpBatch.range_scans([(0, 4)])])
        assert len(b) == 3 and b.scan_ids.tolist() == [2]
        assert len(OpBatch.concat([])) == 0

    def test_validation_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown op kind"):
            OpBatch.from_ops([("upsert", 1, 2)])
        with pytest.raises(ValueError, match="unknown op kind"):
            OpBatch(np.array([9], np.uint8))

    def test_validation_bad_arity(self):
        with pytest.raises(ValueError, match="arguments"):
            OpBatch.from_ops([("put", 1)])
        with pytest.raises(ValueError, match="arguments"):
            OpBatch.from_ops([("get", 1, 2)])

    def test_validation_empty_range(self):
        with pytest.raises(ValueError, match="empty range"):
            OpBatch.range_deletes([(5, 5)])
        with pytest.raises(ValueError, match="empty range"):
            OpBatch.from_ops([("range_scan", 9, 3)])

    def test_validation_shape_mismatch(self):
        with pytest.raises(ValueError, match="keys vs"):
            OpBatch.puts([1, 2, 3], [1])
        with pytest.raises(ValueError, match="length"):
            OpBatch(np.zeros(3, np.uint8), keys=np.zeros(2, np.uint64))

    def test_malformed_batch_rejected_by_engine(self):
        eng = make_engine(num_shards=2)
        with pytest.raises(ValueError):
            eng.execute([("get",)])
        with pytest.raises(ValueError):
            eng.range_scan_batch([(10, 10)])


# ---------------------------------------------------------------- planner
class TestPlanner:
    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_plan_covers_every_op_once_per_owner(self, partition):
        router = ShardRouter(4, partition=partition, universe=UNIVERSE)
        planner = Planner(router)
        rng = np.random.default_rng(7)
        batch = OpBatch.from_ops(mixed_stream(rng, 300))
        plan = planner.plan(batch)
        seen_point: list = []
        seen_range: dict = {}
        for sp in plan.shard_plans:
            prev_write = -1
            for step in sp.steps:
                # Within a step op ids ascend (stream order); write
                # steps ascend across each other (reads may hoist).
                assert (np.diff(step.idx) > 0).all()
                if step.kind not in (OP_GET, OP_RANGE_SCAN):
                    assert step.idx.min() > prev_write
                    prev_write = int(step.idx.max())
                for i in step.idx.tolist():
                    if batch.kinds[i] >= OP_RANGE_DELETE:
                        seen_range[i] = seen_range.get(i, 0) + 1
                    else:
                        seen_point.append(i)
        # Every point op executes exactly once, on one shard.
        assert sorted(seen_point) == \
            np.flatnonzero(batch.kinds <= OP_GET).tolist()
        # Every range op visits each owning shard exactly once.
        for i in np.flatnonzero(batch.kinds >= OP_RANGE_DELETE).tolist():
            owners = router.shards_for_range(int(batch.los[i]),
                                             int(batch.his[i]))
            assert seen_range[i] == len(owners), i

    def test_same_kind_runs_are_grouped(self):
        router = ShardRouter(1, partition="hash", universe=UNIVERSE)
        batch = OpBatch.from_ops([
            ("put", 1, 1), ("put", 2, 2), ("get", 1), ("get", 2),
            ("range_delete", 0, 4), ("get", 1), ("put", 3, 3)])
        (sp,) = Planner(router).plan(batch).shard_plans
        assert [(s.kind, len(s)) for s in sp.steps] == [
            (OP_PUT, 2), (OP_GET, 2), (OP_RANGE_DELETE, 1), (OP_GET, 1),
            (OP_PUT, 1)]

    def test_reads_hoist_past_disjoint_writes(self):
        """Reads that cannot observe an intervening write merge into the
        open read slot; reads that overlap it stay behind it."""
        router = ShardRouter(1, partition="hash", universe=UNIVERSE)
        batch = OpBatch.from_ops([
            ("get", 100), ("range_delete", 0, 50), ("get", 200),
            ("range_scan", 60, 90), ("get", 10), ("range_scan", 40, 70)])
        (sp,) = Planner(router).plan(batch).shard_plans
        kinds = [(s.kind, s.idx.tolist()) for s in sp.steps]
        # get 200 hoists next to get 100; scan [60,90) hoists too; get 10
        # and scan [40,70) overlap the delete and execute after it.
        assert kinds == [(OP_GET, [0, 2]), (OP_RANGE_SCAN, [3]),
                         (OP_RANGE_DELETE, [1]), (OP_GET, [4]),
                         (OP_RANGE_SCAN, [5])]

    def test_hoisted_semantics_match_model(self):
        """Hoisting never changes what a read observes."""
        eng = make_engine(num_shards=2)
        res = eng.execute([
            ("put", 1, 10), ("put", 5, 50), ("put", 9, 90),
            ("get", 9),            # pre-delete
            ("range_delete", 0, 6),
            ("get", 9),            # disjoint: hoists, same verdict
            ("get", 5),            # covered: must see the delete
            ("range_scan", 0, 20),
        ])
        assert res[3] == 90 and res[5] == 90 and res[6] is None
        assert res[7][0].tolist() == [9]

    def test_range_partition_clips_per_shard(self):
        router = ShardRouter(4, partition="range", universe=1000)
        batch = OpBatch.range_scans([(200, 760)])
        plan = Planner(router).plan(batch)
        visits = [(sp.shard, int(st.los[0]), int(st.his[0]))
                  for sp in plan.shard_plans for st in sp.steps]
        assert visits == [(0, 200, 250), (1, 250, 500), (2, 500, 750),
                          (3, 750, 760)]

    def test_clip_ranges_matches_scalar_routing(self):
        rng = np.random.default_rng(11)
        router = ShardRouter(5, partition="range", universe=UNIVERSE)
        los = rng.integers(0, UNIVERSE + 5000, 200).astype(np.uint64)
        his = los + rng.integers(1, UNIVERSE // 2, 200).astype(np.uint64)
        rids, shards, clos, chis = router.clip_ranges(los, his)
        got: dict = {}
        for r, s, a, b in zip(rids.tolist(), shards.tolist(),
                              clos.tolist(), chis.tolist()):
            got.setdefault(r, []).append((s, a, b))
        for r in range(200):
            assert got[r] == router.shards_for_range(int(los[r]),
                                                     int(his[r]))


# ------------------------------------------------------ submit semantics
class TestSubmitSemantics:
    def test_interleaved_ordering_through_opbatch(self):
        """put/get/range_delete/range_scan interleavings observe strict
        request order: each op sees exactly the writes before it."""
        eng = make_engine(num_shards=4)
        res = eng.submit(OpBatch.from_ops([
            ("put", 10, 100), ("put", 11, 110), ("get", 10),
            ("range_scan", 0, 20),
            ("range_delete", 0, 11),
            ("get", 10), ("get", 11),
            ("range_scan", 0, 20),
            ("put", 10, 200), ("get", 10),
            ("delete", 11), ("get", 11),
            ("range_scan", 0, 20),
        ])).results()
        assert res[2] == 100
        assert res[3][0].tolist() == [10, 11]
        assert res[3][1].tolist() == [100, 110]
        assert res[5] is None and res[6] == 110
        assert res[7][0].tolist() == [11]
        assert res[9] == 200 and res[11] is None
        assert res[12][0].tolist() == [10]
        assert res[12][1].tolist() == [200]

    def test_typed_accessors(self):
        eng = make_engine(num_shards=2)
        eng.put_batch(np.arange(100, dtype=np.uint64),
                      np.arange(100, dtype=np.uint64) * np.uint64(3))
        pending = eng.submit(OpBatch.gets(np.arange(50, dtype=np.uint64)))
        found, vals = pending.get_results()
        assert found.all()
        np.testing.assert_array_equal(
            vals, np.arange(50, dtype=np.uint64) * np.uint64(3))
        pending = eng.submit(OpBatch.range_scans([(0, 10), (90, 200)]))
        (k0, v0), (k1, v1) = pending.scan_results()
        assert k0.tolist() == list(range(10))
        assert k1.tolist() == list(range(90, 100))
        # wait() is idempotent; accessors can be re-read.
        pending.wait().wait()
        assert pending.scan_results()[0][0].tolist() == list(range(10))

    def test_submit_overlaps_with_planning(self):
        """Pipelined submit returns a live handle; several batches can
        be in flight and collect in any order with correct results."""
        eng = make_engine(num_shards=4, pipeline=True)
        keys = np.arange(2000, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(5))
        eng.flush()
        pendings = [eng.submit(OpBatch.gets(keys[i::4]))
                    for i in range(4)]
        for i, p in reversed(list(enumerate(pendings))):
            found, vals = p.get_results()
            assert found.all()
            np.testing.assert_array_equal(vals, keys[i::4] + np.uint64(5))
        assert all(p.done() for p in pendings)
        eng.drain()

    def test_write_read_order_across_inflight_batches(self):
        """A later submit must observe an earlier in-flight submit's
        writes (per-shard FIFO)."""
        eng = make_engine(num_shards=4, pipeline=True)
        keys = np.arange(500, dtype=np.uint64)
        p1 = eng.submit(OpBatch.puts(keys, keys + np.uint64(1)))
        p2 = eng.submit(OpBatch.range_deletes([(100, 300)]))
        p3 = eng.submit(OpBatch.gets(keys))
        found, vals = p3.get_results()
        live = (keys < 100) | (keys >= 300)
        np.testing.assert_array_equal(found, live)
        np.testing.assert_array_equal(vals[found], keys[live] + np.uint64(1))
        p1.wait(), p2.wait()

    def test_shard_wall_and_stall_stats(self):
        eng = make_engine(num_shards=4, pipeline=True)
        keys = np.arange(3000, dtype=np.uint64)
        eng.put_batch(keys, keys)
        eng.flush()
        eng.get_batch(keys)
        snap = eng.stats()["engine"]
        assert snap["pipelined_batches"] > 0
        assert len(snap["shard_wall_seconds"]) == 4
        assert len(snap["shard_stall_seconds"]) == 4
        assert all(v >= 0 for v in snap["shard_stall_seconds"].values())

    def test_serial_engine_records_serial_batches(self):
        eng = make_engine(num_shards=2, pipeline=False)
        eng.put_batch(np.arange(10, dtype=np.uint64),
                      np.arange(10, dtype=np.uint64))
        snap = eng.stats()["engine"]
        assert snap["serial_batches"] > 0
        assert snap["pipelined_batches"] == 0

    def test_serial_submit_dropped_handle_still_lands_in_stats(self):
        """A serial submit collects inline: even if the caller drops
        the PendingBatch, the ops are recorded."""
        eng = make_engine(num_shards=2, pipeline=False)
        eng.submit(OpBatch.puts(np.arange(20, dtype=np.uint64),
                                np.arange(20, dtype=np.uint64)))
        snap = eng.stats()["engine"]
        assert snap["ops"].get("put") == 20
        assert snap["serial_batches"] == 1

    def test_pipeline_env_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_ENGINE_PIPELINE", "0")
        assert not make_engine(num_shards=2).pipeline_default
        monkeypatch.setenv("REPRO_ENGINE_PIPELINE", "1")
        assert make_engine(num_shards=2).pipeline_default
        # Explicit config wins over the environment.
        assert not make_engine(num_shards=2,
                               pipeline=False).pipeline_default


# ----------------------------------------------------- pipelined parity
class TestPipelinedParity:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("num_shards", [1, 2, 4])
    def test_pipelined_identical_to_serial(self, strategy, num_shards):
        """``submit(pipeline=True)`` returns byte-identical results to
        the serial path for mixed op streams, for every strategy and
        shard count."""
        rng = np.random.default_rng(61)
        stream = mixed_stream(rng, 260)
        engines = [make_engine(strategy=strategy, num_shards=num_shards,
                               pipeline=pl) for pl in (False, True)]
        # Several submits so pipelined batches genuinely overlap.
        for i in range(0, len(stream), 65):
            batch_ops = stream[i:i + 65]
            res = [eng.submit(OpBatch.from_ops(batch_ops)).results()
                   for eng in engines]
            assert_results_identical(res[0], res[1])
        probe = rng.integers(0, 2100, size=400).astype(np.uint64)
        f0, v0 = engines[0].get_batch(probe)
        f1, v1 = engines[1].get_batch(probe)
        assert f0.tobytes() == f1.tobytes()
        assert v0[f0].tobytes() == v1[f1].tobytes()

    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_parity_across_partitions_with_flush(self, partition):
        rng = np.random.default_rng(67)
        engines = [make_engine(num_shards=4, pipeline=pl,
                               partition=partition)
                   for pl in (False, True)]
        for round_ in range(3):
            stream = mixed_stream(rng, 150, universe=UNIVERSE)
            batch = OpBatch.from_ops(stream)
            res = [eng.submit(batch).results() for eng in engines]
            assert_results_identical(res[0], res[1])
            for eng in engines:
                eng.flush()
