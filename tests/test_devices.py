"""Multi-device shard-placement parity suite.

PR 7 gives each engine shard its own XLA device: registry packs are
committed per-device, kernel dispatches pin their launches, and the
pipelined shard workers stop serializing on the default device.  These
tests pin the contract that makes that placement invisible:

  * the device matrix — every range-delete strategy x shard count x
    device count returns byte-identical results AND exact IOStats
    snapshots vs the single-device fallback (``devices=0``, the ungated
    legacy path);
  * the per-device ``upload_bytes`` ledger: packs upload once per
    device in steady state (never once per batch), split across exactly
    the devices the shards were homed on;
  * concurrency — interleaved ``submit()`` streams of mixed OpBatches
    are deterministic across pipeline on/off x devices on/off (per-shard
    FIFO is the only ordering contract, and it is enough);
  * invalidation — a flush/compaction (index-epoch bump) mid-stream
    rebuilds the per-device packs on EVERY device, not just device 0.

The suite needs multiple host-platform devices; tests/conftest.py
forces 4 before jax initializes (cells needing more than the host has
skip).
"""

import os

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import Engine, EngineConfig, OpBatch
from repro.launch.mesh import (ensure_host_devices,
                               forced_host_device_count, shard_devices)
from repro.lsm import LSMConfig, STRATEGIES

UNIVERSE = 1 << 20
N_DEVICES = len(jax.devices())


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512, key_universe=UNIVERSE)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran():
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=16,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def engine_cfg(*, devices, pipeline=None, **kw):
    # procs pinned off: the device-matrix assertions read per-shard
    # trees/registries in-process (cross-process parity: test_procs.py).
    d = dict(cache_blocks=512, kernel_min_batch=1, kernel_min_areas=1,
             kernel_min_filter=1, cascade_compiled=True, devices=devices,
             pipeline=pipeline, procs=0)
    d.update(kw)
    return EngineConfig(**d)


def drive(store, rng, rounds=4, universe=2000):
    """A mixed put/delete/range-delete workload with plenty of flushes."""
    for _ in range(rounds):
        keys = rng.integers(0, universe, size=220).astype(np.uint64)
        store.put_batch(keys, keys * np.uint64(3) + np.uint64(1))
        store.delete_batch(rng.integers(0, universe, size=30)
                           .astype(np.uint64))
        for _ in range(5):
            lo = int(rng.integers(0, universe - 80))
            store.range_delete(lo, lo + int(rng.integers(1, 64)))


def build_engine(strategy, shards, devices, seed=42, pipeline=None):
    g = small_gloran() if strategy == "gloran" else None
    eng = Engine(num_shards=shards, strategy=strategy,
                 lsm_config=small_cfg(), gloran_config=g,
                 config=engine_cfg(devices=devices, pipeline=pipeline))
    drive(eng, np.random.default_rng(seed))
    return eng


def io_snapshots(eng):
    return [sh.tree.io.snapshot() for sh in eng.shards]


# --------------------------------------------------------- mesh helpers
class TestMeshHelpers:
    def test_forced_count_parses_xla_flags(self, monkeypatch):
        monkeypatch.setenv(
            "XLA_FLAGS",
            "--foo=1 --xla_force_host_platform_device_count=7 --bar=2")
        assert forced_host_device_count() == 7

    def test_ensure_respects_existing_force(self, monkeypatch):
        """The dryrun-vs-engine contract: whoever forced a count first
        wins; ensure never overwrites XLA_FLAGS (the PR-7 fix for
        dryrun's unconditional 512 overwrite)."""
        flags = "--xla_force_host_platform_device_count=7"
        monkeypatch.setenv("XLA_FLAGS", flags)
        assert ensure_host_devices(512) == 7
        assert os.environ["XLA_FLAGS"] == flags

    def test_ensure_after_backend_init_reports_reality(self, monkeypatch):
        """Backends are initialized in this process (conftest forced 4
        devices), so without a forced flag ensure cannot change the
        count — it must report the live one and leave flags alone."""
        monkeypatch.setenv("XLA_FLAGS", "--some_other_flag=1")
        assert ensure_host_devices(64) == N_DEVICES
        assert os.environ["XLA_FLAGS"] == "--some_other_flag=1"

    def test_shard_devices_round_robin_with_limit(self):
        devs = shard_devices(6, limit=2)
        assert len(devs) == 6
        assert len({d.id for d in devs}) == min(2, N_DEVICES)
        assert devs[0].id == devs[2].id == devs[4].id
        one = shard_devices(4, limit=1)
        assert {d.id for d in one} == {jax.devices()[0].id}


# -------------------------------------------------- device-matrix parity
class TestDeviceMatrixParity:
    """Results, I/O snapshots, and scan output must be byte-identical
    across device counts 1/2/4 vs the single-device fallback."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("shards", (1, 2, 4))
    def test_results_and_io_identical(self, strategy, shards):
        rng = np.random.default_rng(9)
        probe = rng.integers(0, 2100, size=600).astype(np.uint64)
        scan = [(0, 700), (900, 1600)]
        base = build_engine(strategy, shards, devices=0)
        io_drive = io_snapshots(base)  # post-drive, pre-probe charges
        f0, v0 = base.get_batch(probe)
        s0 = base.range_scan_batch(scan)
        assert base.devices is None  # the ungated fallback path
        for devcount in (1, 2, 4):
            if devcount > N_DEVICES:
                pytest.skip(f"host has {N_DEVICES} XLA devices")
            eng = build_engine(strategy, shards, devices=devcount)
            assert io_snapshots(eng) == io_drive, (devcount, "drive io")
            f1, v1 = eng.get_batch(probe)
            s1 = eng.range_scan_batch(scan)
            np.testing.assert_array_equal(f1, f0)
            np.testing.assert_array_equal(v1[f1], v0[f0])
            for (ka, va), (kb, vb) in zip(s1, s0):
                np.testing.assert_array_equal(ka, kb)
                np.testing.assert_array_equal(va, vb)
            assert io_snapshots(eng) == io_snapshots(base), devcount
            dv = eng.stats()["devices"]
            assert dv["enabled"]
            assert dv["distinct"] == min(devcount, shards)

    def test_device_map_round_robin(self):
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(),
                     gloran_config=small_gloran(),
                     config=engine_cfg(devices=2))
        assert eng.device_map() == {0: "cpu:0", 1: "cpu:1",
                                    2: "cpu:0", 3: "cpu:1"}


# ------------------------------------------------------ upload ledger
class TestPerDeviceLedger:
    def test_steady_state_uploads_once_per_device(self):
        """4 shards homed on 4 devices: the pack ledger lands once on
        each device and repeat batches move NOTHING — uploads are per
        device, never per batch."""
        if N_DEVICES < 4:
            pytest.skip(f"host has {N_DEVICES} XLA devices")
        eng = build_engine("gloran", 4, devices=4)
        probe = np.arange(0, 1024, dtype=np.uint64)
        eng.get_batch(probe)
        led0 = eng.kernel_counters.snapshot()["upload_bytes_by_device"]
        assert set(led0) == {f"cpu:{i}" for i in range(4)}
        assert all(v > 0 for v in led0.values())
        for _ in range(4):
            eng.get_batch(probe)
        led1 = eng.kernel_counters.snapshot()["upload_bytes_by_device"]
        assert led1 == led0
        assert sum(led1.values()) == eng.kernel_counters.upload_bytes

    def test_fallback_ledger_lands_on_host(self):
        eng = build_engine("gloran", 2, devices=0)
        eng.get_batch(np.arange(0, 512, dtype=np.uint64))
        led = eng.kernel_counters.snapshot()["upload_bytes_by_device"]
        assert set(led) == {"host"}
        assert led["host"] == eng.kernel_counters.upload_bytes


# --------------------------------------------------------- invalidation
class TestEpochInvalidation:
    def test_epoch_bump_invalidates_packs_on_every_device(self):
        """A mid-stream flush/compaction (index-epoch bump) must rebuild
        the per-device packs on EVERY device, not just device 0 — and
        the post-bump answers must still match the single-device twin
        exactly."""
        if N_DEVICES < 4:
            pytest.skip(f"host has {N_DEVICES} XLA devices")
        eng = build_engine("gloran", 4, devices=4, seed=7)
        twin = build_engine("gloran", 4, devices=0, seed=7)
        probe = np.arange(0, 2048, dtype=np.uint64)
        for e in (eng, twin):
            e.get_batch(probe)  # pack v1 on every shard's device
        packs0 = [sh.kernels.cascade_packs for sh in eng.shards]
        assert all(p >= 1 for p in packs0), "every shard must have packed"
        led0 = eng.kernel_counters.snapshot()["upload_bytes_by_device"]
        # Mid-stream epoch bump on every shard: broadcast range deletes
        # (hash partition) + writes, then flush.
        keys = np.arange(3000, 3800, dtype=np.uint64)
        for e in (eng, twin):
            e.put_batch(keys, keys + np.uint64(5))
            e.range_delete(3000, 3200)
            e.flush()
            e.range_delete(100, 400)  # staged post-flush state too
        f1, v1 = eng.get_batch(probe)
        f0, v0 = twin.get_batch(probe)
        np.testing.assert_array_equal(f1, f0)
        np.testing.assert_array_equal(v1[f1], v0[f0])
        assert io_snapshots(eng) == io_snapshots(twin)
        packs1 = [sh.kernels.cascade_packs for sh in eng.shards]
        assert all(b > a for a, b in zip(packs0, packs1)), \
            (packs0, packs1)
        led1 = eng.kernel_counters.snapshot()["upload_bytes_by_device"]
        assert all(led1[d] > led0[d] for d in led0), (led0, led1)


# ---------------------------------------------------------- concurrency
def op_stream(rng, n_ops, universe=2400):
    """One bursty mixed op stream (puts/gets/deletes/range ops)."""
    ops = []
    while len(ops) < n_ops:
        kind = int(rng.integers(0, 5))
        burst = min(int(rng.integers(1, 24)), n_ops - len(ops))
        if kind == 0:
            for k in rng.integers(0, universe, size=burst).tolist():
                ops.append(("put", k, k * 3 + 1))
        elif kind == 1:
            for k in rng.integers(0, universe, size=burst).tolist():
                ops.append(("get", k))
        elif kind == 2:
            for k in rng.integers(0, universe, size=burst).tolist():
                ops.append(("delete", k))
        elif kind == 3:
            for lo in rng.integers(0, universe - 70, size=burst).tolist():
                ops.append(("range_delete", lo, lo + 40))
        else:
            for lo in rng.integers(0, universe - 300,
                                   size=burst).tolist():
                ops.append(("range_scan", lo, lo + 220))
    return ops


def canon(results):
    """Hashable form of a results list (scan arrays -> bytes)."""
    out = []
    for r in results:
        if isinstance(r, tuple):
            out.append((r[0].tobytes(), r[1].tobytes()))
        else:
            out.append(r)
    return out


def run_interleaved(pipeline, devices, seed, n_batches=6, n_ops=160):
    """Submit a stream of mixed OpBatches ahead of collection and
    return every batch's results + the final I/O snapshots."""
    eng = build_engine("gloran", 4, devices=devices, seed=seed,
                       pipeline=pipeline)
    rng = np.random.default_rng(seed + 1)
    handles = [eng.submit(OpBatch.from_ops(op_stream(rng, n_ops)))
               for _ in range(n_batches)]
    results = [canon(h.results()) for h in handles]
    eng.drain()
    return results, io_snapshots(eng)


class TestConcurrentSubmission:
    @pytest.mark.parametrize("seed", (3, 11))
    def test_interleaved_submits_deterministic_across_modes(self, seed):
        """Pipeline on/off x devices on/off: identical per-batch results
        and I/O under submit-ahead interleaving — per-shard FIFO plus
        deterministic merge-back is the whole ordering contract."""
        configs = [(False, 0), (True, 0), (False, None), (True, None)]
        outs = [run_interleaved(pl, dv, seed) for pl, dv in configs]
        for (res, io), cfg in zip(outs[1:], configs[1:]):
            assert res == outs[0][0], cfg
            assert io == outs[0][1], cfg

    def test_pipelined_devices_fifo_under_jitter(self):
        """Many small batches racing through the shard pools with
        devices on: every collected batch matches the serial twin's
        answer batch-for-batch (thread scheduling cannot reorder a
        shard's work)."""
        a, io_a = run_interleaved(True, None, seed=23, n_batches=10,
                                  n_ops=96)
        b, io_b = run_interleaved(False, 0, seed=23, n_batches=10,
                                  n_ops=96)
        assert a == b
        assert io_a == io_b


if HAS_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 10_000), n_batches=st.integers(2, 6),
           n_ops=st.integers(40, 200))
    def test_hypothesis_interleaved_mixed_batches(seed, n_batches, n_ops):
        """Random interleaved OpBatch streams across shards: pipeline
        on/off x devices on/off all agree, results and I/O."""
        outs = [run_interleaved(pl, dv, seed, n_batches=n_batches,
                                n_ops=n_ops)
                for pl, dv in ((False, 0), (True, 0), (True, None))]
        for res, io in outs[1:]:
            assert res == outs[0][0]
            assert io == outs[0][1]
