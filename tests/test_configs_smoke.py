"""Per-architecture smoke tests on REDUCED same-family configs (CPU).

Full configs are exercised only by the dry-run (ShapeDtypeStruct, no
allocation).  Each smoke test: instantiate, one forward/train step, shape +
finiteness assertions; attention/SSM archs also verify decode-step
equivalence against the teacher-forced forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke
from repro.models import Transformer, count_params, tree_init
from repro.models.layers import cross_entropy_loss

B, S = 2, 32


def _inputs(cfg, rng):
    if cfg.stub_frontend is not None:
        return {"embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)}


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = smoke(get_config(arch))
        model = Transformer(cfg)
        params = tree_init(model.param_specs(), jax.random.key(0),
                           jnp.float32)
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, built):
    cfg, model, params = built[arch]
    rng = np.random.default_rng(1)
    logits = jax.jit(model.forward_train)(params, **_inputs(cfg, rng))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_decreases_grad_finite(arch, built):
    cfg, model, params = built[arch]
    rng = np.random.default_rng(2)
    inp = _inputs(cfg, rng)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)

    def loss_fn(p):
        return cross_entropy_loss(model.forward_train(p, **inp), labels)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # One SGD step reduces loss on the same batch.
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = jax.jit(loss_fn)(p2)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, built):
    """Step-by-step decode == teacher-forced forward (same tokens)."""
    cfg, model, params = built[arch]
    rng = np.random.default_rng(3)
    T = 12
    inp = _inputs(cfg, rng)
    full = jax.jit(model.forward_train)(params, **inp)

    cache = model.init_cache(B, T, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))
    outs = []
    for t in range(T):
        if cfg.stub_frontend is not None:
            tok = inp["embeds"][:, t:t + 1]
        else:
            tok = inp["tokens"][:, t:t + 1]
        logits, cache = step(params, tok, cache, t)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full[:, :T], np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_consistency(arch, built):
    cfg, model, params = built[arch]
    specs = model.param_specs()
    n = count_params(specs)
    assert n > 0
    got = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert got == n


def test_full_config_param_counts_sane():
    """Full (unreduced) configs match the public parameter scales."""
    approx = {
        "mixtral-8x7b": 46.7e9,
        "minitron-8b": 8.0e9,
        "h2o-danube-3-4b": 4.0e9,
        "chatglm3-6b": 6.2e9,
        "gemma3-1b": 1.0e9,
        "mamba2-130m": 130e6,
        "paligemma-3b": 2.6e9,  # LM backbone only (frontend stubbed)
        "zamba2-7b": 7.0e9,
        "musicgen-large": 3.3e9,
        "kimi-k2-1t-a32b": 1.0e12,
    }
    for arch, want in approx.items():
        n = get_config(arch).n_params()
        assert 0.4 * want < n < 2.1 * want, (arch, n, want)


def test_moe_capacity_drops_are_bounded():
    """Sanity: with cf=2.0 smoke config, top-k routing keeps most tokens."""
    cfg = smoke(get_config("mixtral-8x7b"))
    model = Transformer(cfg)
    params = tree_init(model.param_specs(), jax.random.key(1), jnp.float32)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    logits = jax.jit(model.forward_train)(params, tokens=toks)
    assert np.isfinite(np.asarray(logits)).all()


def test_window_vector_gemma_pattern():
    cfg = smoke(get_config("gemma3-1b"))
    model = Transformer(cfg)
    w = np.asarray(model._window_vector())
    per = cfg.local_global + 1
    assert (w[per - 1::per] == -1).all()  # globals
    locs = np.delete(w, np.s_[per - 1::per])
    assert (locs > 0).all()
