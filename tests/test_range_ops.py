"""Batched range ops through the engine: scan parity with the bare tree
across every strategy and shard count, batched scans/deletes, sorted-view
merge primitives, and per-op-class stats rollups."""

import numpy as np
import pytest

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import Engine, EngineConfig
from repro.lsm import LSMConfig, LSMTree, STRATEGIES
from repro.lsm.merge import merge_runs, merge_two, newest_wins

UNIVERSE = 1 << 20


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512, key_universe=UNIVERSE)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran():
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=16,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def kernel_cfg(**kw):
    d = dict(cache_blocks=512, kernel_min_batch=1, kernel_min_areas=1,
             kernel_min_filter=1)
    d.update(kw)
    return EngineConfig(**d)


def make_ops(rng, n, universe=2000, rdel_ratio=0.08, max_len=100):
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < rdel_ratio:
            lo = int(rng.integers(0, universe - 2))
            ops.append(("rdel", lo, lo + int(rng.integers(1, max_len))))
        elif r < rdel_ratio + 0.05:
            ops.append(("del", int(rng.integers(0, universe))))
        else:
            ops.append(("put", int(rng.integers(0, universe)),
                        int(rng.integers(1, 1 << 30))))
    return ops


def apply_ops(store, ops):
    for op in ops:
        if op[0] == "put":
            store.put(op[1], op[2])
        elif op[0] == "del":
            store.delete(op[1])
        else:
            store.range_delete(op[1], op[2])


def scan_ranges(rng, n=12, universe=2000):
    """Random scan ranges, always including shard-slab straddlers for
    every shard count under test (slab width = UNIVERSE / shards)."""
    out = []
    for shards in (2, 4):
        width = -(-UNIVERSE // shards)
        for s in range(1, shards):
            out.append((s * width - 40, s * width + 40))  # straddles slab s
    out.append((0, universe))  # everything
    for _ in range(n):
        lo = int(rng.integers(0, universe - 1))
        out.append((lo, lo + int(rng.integers(1, 300))))
    return out


# ----------------------------------------------------------- merge module
class TestSortedViewMerge:
    def test_merge_two_interleaves_sorted(self):
        rng = np.random.default_rng(0)
        a = np.sort(rng.integers(0, 1000, 500).astype(np.uint64))
        b = np.sort(rng.integers(0, 1000, 300).astype(np.uint64))
        (m,) = merge_two((a,), (b,))
        np.testing.assert_array_equal(m, np.sort(np.concatenate([a, b])))

    def test_merge_runs_equals_lexsort_path(self):
        rng = np.random.default_rng(1)
        parts = []
        for _ in range(5):
            k = np.unique(rng.integers(0, 400, 120).astype(np.uint64))
            s = rng.integers(1, 1 << 40, len(k)).astype(np.uint64)
            t = rng.integers(0, 2, len(k)).astype(np.uint8)
            v = rng.integers(0, 1 << 40, len(k)).astype(np.uint64)
            parts.append((k, s, t, v))
        keys, seqs, typs, vals = merge_runs(parts)
        cat = [np.concatenate([p[i] for p in parts]) for i in range(4)]
        order = np.lexsort((cat[1], cat[0]))
        np.testing.assert_array_equal(keys, cat[0][order])
        # seq order within duplicate-key groups is irrelevant: newest_wins
        # resolves by max seq, which lexsort's last-in-group also picks.
        mk, ms, mt, mv = newest_wins(keys, seqs, typs, vals)
        newest = np.ones(len(order), dtype=bool)
        sk = cat[0][order]
        newest[:-1] = sk[1:] != sk[:-1]
        np.testing.assert_array_equal(mk, sk[newest])
        np.testing.assert_array_equal(ms, cat[1][order][newest])
        np.testing.assert_array_equal(mv, cat[3][order][newest])

    def test_empty_parts(self):
        keys, seqs, typs, vals = merge_runs([])
        assert len(keys) == len(seqs) == len(typs) == len(vals) == 0


# ----------------------------------------------------- engine scan parity
class TestRangeScanParity:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("num_shards", [1, 2, 4])
    def test_engine_scan_identical_to_bare_tree(self, strategy,
                                                num_shards):
        """``Engine.range_scan`` is byte-identical to bare
        ``LSMTree.range_scan`` for every strategy x shard count,
        including scans straddling shard slab boundaries."""
        rng = np.random.default_rng(31)
        ops = make_ops(rng, 700)
        g = small_gloran() if strategy == "gloran" else None
        tree = LSMTree(small_cfg(), strategy=strategy, gloran_config=g)
        eng = Engine(num_shards=num_shards, strategy=strategy,
                     lsm_config=small_cfg(), gloran_config=g,
                     config=kernel_cfg(partition="range"))
        apply_ops(tree, ops)
        apply_ops(eng, ops)
        for lo, hi in scan_ranges(rng):
            tk, tv = tree.range_scan(lo, hi)
            ek, ev = eng.range_scan(lo, hi)
            assert ek.dtype == tk.dtype and ev.dtype == tv.dtype
            assert tk.tobytes() == ek.tobytes(), (strategy, num_shards,
                                                  lo, hi)
            assert tv.tobytes() == ev.tobytes(), (strategy, num_shards,
                                                  lo, hi)

    @pytest.mark.parametrize("num_shards", [2, 4])
    def test_full_universe_scan_crosses_populated_slabs(self, num_shards):
        """Data spread over the whole key universe: every shard owns
        entries, and scans straddling populated slab boundaries must
        come back as one globally sorted view (the multi-part slab
        concatenation in ``Engine._merge_scan_parts``)."""
        rng = np.random.default_rng(53)
        ops = make_ops(rng, 700, universe=UNIVERSE, max_len=3000)
        tree = LSMTree(small_cfg(), strategy="gloran",
                       gloran_config=small_gloran())
        eng = Engine(num_shards=num_shards, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg(partition="range"))
        apply_ops(tree, ops)
        apply_ops(eng, ops)
        width = -(-UNIVERSE // num_shards)
        ranges = [(s * width - 5000, s * width + 5000)
                  for s in range(1, num_shards)]
        ranges += [(0, UNIVERSE), (width // 2, UNIVERSE - width // 2)]
        for lo, hi in ranges:
            tk, tv = tree.range_scan(lo, hi)
            ek, ev = eng.range_scan(lo, hi)
            assert len(tk), (num_shards, lo, hi)  # scans hit real data
            assert tk.tobytes() == ek.tobytes(), (num_shards, lo, hi)
            assert tv.tobytes() == ev.tobytes(), (num_shards, lo, hi)
        # The wide scans really did visit every shard.
        multi = eng.router.shards_for_range(0, UNIVERSE)
        assert len(multi) == num_shards

    @pytest.mark.parametrize("num_shards", [1, 2, 4])
    def test_hash_partition_scan_parity(self, num_shards):
        rng = np.random.default_rng(37)
        ops = make_ops(rng, 700)
        tree = LSMTree(small_cfg(), strategy="gloran",
                       gloran_config=small_gloran())
        eng = Engine(num_shards=num_shards, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg(partition="hash"))
        apply_ops(tree, ops)
        apply_ops(eng, ops)
        for lo, hi in scan_ranges(rng):
            tk, tv = tree.range_scan(lo, hi)
            ek, ev = eng.range_scan(lo, hi)
            assert tk.tobytes() == ek.tobytes(), (num_shards, lo, hi)
            assert tv.tobytes() == ev.tobytes(), (num_shards, lo, hi)


# --------------------------------------------------------- batched paths
class TestBatchedRangeOps:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_tree_scan_batch_equals_per_call(self, strategy):
        rng = np.random.default_rng(41)
        g = small_gloran() if strategy == "gloran" else None
        tree = LSMTree(small_cfg(), strategy=strategy, gloran_config=g)
        apply_ops(tree, make_ops(rng, 600))
        ranges = scan_ranges(rng)
        batched = tree.range_scan_batch(ranges)
        for (lo, hi), (bk, bv) in zip(ranges, batched):
            k, v = tree.range_scan(lo, hi)
            np.testing.assert_array_equal(k, bk)
            np.testing.assert_array_equal(v, bv)

    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_engine_scan_batch_equals_per_call(self, partition):
        rng = np.random.default_rng(43)
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg(partition=partition))
        apply_ops(eng, make_ops(rng, 600))
        ranges = scan_ranges(rng)
        batched = eng.range_scan_batch(ranges)
        for (lo, hi), (bk, bv) in zip(ranges, batched):
            k, v = eng.range_scan(lo, hi)
            np.testing.assert_array_equal(k, bk)
            np.testing.assert_array_equal(v, bv)

    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_range_delete_batch_equals_sequential(self, partition):
        cfgs = dict(strategy="gloran", lsm_config=small_cfg(),
                    gloran_config=small_gloran(),
                    config=EngineConfig(partition=partition))
        a = Engine(num_shards=3, **cfgs)
        b = Engine(num_shards=3, **cfgs)
        keys = np.arange(0, 4000, dtype=np.uint64)
        for e in (a, b):
            e.put_batch(keys, keys + np.uint64(9))
        spans = [(100, 300), (250, 900), (3500, 4200), (50, 60)]
        a.range_delete_batch(spans)
        for lo, hi in spans:
            b.range_delete(lo, hi)
        fa, va = a.get_batch(keys)
        fb, vb = b.get_batch(keys)
        np.testing.assert_array_equal(fa, fb)
        np.testing.assert_array_equal(va[fa], vb[fb])

    def test_execute_routes_range_scans(self):
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran())
        res = eng.execute([
            ("put", 5, 50), ("put", 9, 90), ("put", 14, 140),
            ("range_scan", 0, 20),
            ("range_delete", 0, 10),
            ("range_scan", 0, 20), ("get", 14),
        ])
        k0, v0 = res[3]
        assert k0.tolist() == [5, 9, 14] and v0.tolist() == [50, 90, 140]
        k1, v1 = res[5]
        assert k1.tolist() == [14] and v1.tolist() == [140]
        assert res[6] == 140


# ------------------------------------------------------------ stats + io
class TestPerOpStats:
    def test_io_and_latency_rollup_per_op_class(self):
        eng = Engine(num_shards=2, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran())
        keys = np.arange(0, 2000, dtype=np.uint64)
        eng.put_batch(keys, keys)
        eng.flush()
        eng.range_delete(100, 400)
        eng.get_batch(keys[:500])
        eng.range_scan(0, 1500)
        snap = eng.stats()["engine"]
        for op in ("put", "get", "range_scan", "range_delete"):
            assert snap["ops"][op] > 0
            assert op in snap["io_reads"] and op in snap["io_writes"]
            assert op in snap["io_per_op"] and op in snap["us_per_op"]
        # Scans and gets charge reads; the flushed puts charged writes.
        assert snap["io_reads"]["range_scan"] > 0
        assert snap["io_reads"]["get"] > 0
        assert snap["io_writes"]["put"] > 0

    def test_scan_validity_goes_through_interval_kernel(self):
        eng = Engine(num_shards=1, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg())
        keys = np.arange(0, 3000, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(1))
        for lo in range(0, 2000, 40):
            eng.range_delete(lo, lo + 11)
        eng.flush()
        k0 = eng.kernel_counters.interval_calls
        ks, vs = eng.range_scan(0, 3000)
        assert eng.kernel_counters.interval_calls > k0
        live = np.ones(3000, dtype=bool)
        for lo in range(0, 2000, 40):
            live[lo:lo + 11] = False
        np.testing.assert_array_equal(ks, keys[live])
        np.testing.assert_array_equal(vs, keys[live] + np.uint64(1))


# ------------------------------------------------- scan-aware block cache
class TestScanCache:
    def test_repeated_scans_hit_cache(self):
        """Scans route block charges through the cache: a second pass
        over the same hot slabs charges (almost) no I/O."""
        eng = Engine(num_shards=2, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg(cache_blocks=4096))
        keys = np.arange(0, 4000, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(1))
        eng.flush()
        ranges = [(int(lo), int(lo) + 200) for lo in range(0, 3000, 400)]
        r0 = eng.io_reads
        cold_res = eng.range_scan_batch(ranges)
        cold = eng.io_reads - r0
        r0 = eng.io_reads
        warm_res = eng.range_scan_batch(ranges)
        warm = eng.io_reads - r0
        assert warm < cold
        assert eng.cache_snapshot()["hits"] > 0
        for (ck, cv), (wk, wv) in zip(cold_res, warm_res):
            np.testing.assert_array_equal(ck, wk)
            np.testing.assert_array_equal(cv, wv)

    def test_uncached_charges_unchanged(self):
        """Without a cache the sequential-read formula is untouched."""
        from repro.lsm.tree import LSMTree as Tree
        tree = Tree(small_cfg(), strategy="gloran",
                    gloran_config=small_gloran())
        keys = np.arange(0, 2000, dtype=np.uint64)
        tree.put_batch(keys, keys)
        tree.flush()
        lvl = max((l for l in tree.levels if l is not None and len(l)),
                  key=len)  # the bottommost run holds the bulk
        los = np.asarray([0, 500], np.uint64)
        his = np.asarray([300, 900], np.uint64)
        r0 = tree.io.reads
        lvl.range_slice_many(los, his, tree.io)
        cs = tree.io.reads - r0
        cnts = [int(np.searchsorted(lvl.keys, h)) -
                int(np.searchsorted(lvl.keys, l))
                for l, h in zip(los, his)]
        want = sum(1 + c * lvl.config.entry_size // lvl.config.block_size
                   for c in cnts if c > 0)
        assert any(c > 0 for c in cnts)  # the slices hit real data
        assert cs == want


# ----------------------------------------------- vectorized LRR probes
class TestRangeTombstoneProbe:
    def test_probe_batch_matches_bruteforce(self):
        from repro.lsm.sstable import RangeTombstoneBlock
        rng = np.random.default_rng(3)
        cfg = small_cfg()
        for _ in range(40):
            t = int(rng.integers(1, 50))
            starts = rng.integers(0, 1000, t).astype(np.uint64)
            ends = starts + rng.integers(1, 150, t).astype(np.uint64)
            seqs = rng.integers(1, 1 << 40, t).astype(np.uint64)
            rtb = RangeTombstoneBlock(starts, ends, seqs, cfg)
            keys = rng.integers(0, 1200, 200).astype(np.uint64)
            got = rtb.probe_batch(keys)
            cover = (rtb.starts[None, :] <= keys[:, None]) & \
                (rtb.ends[None, :] > keys[:, None])
            want = np.where(cover, rtb.seqs[None, :],
                            0).max(axis=1).astype(np.uint64)
            np.testing.assert_array_equal(got, want)
            for k in keys[:10].tolist():
                assert rtb.probe(k) == int(got[keys.tolist().index(k)])

    def test_probe_batch_io_charges_unchanged(self):
        from repro.core.iostats import IOStats
        from repro.lsm.sstable import RangeTombstoneBlock
        cfg = small_cfg()
        rtb = RangeTombstoneBlock(
            np.asarray([10, 50, 90], np.uint64),
            np.asarray([30, 80, 120], np.uint64),
            np.asarray([1, 2, 3], np.uint64), cfg)
        io = IOStats(block_size=cfg.block_size)
        keys = np.asarray([5, 20, 100], np.uint64)
        rtb.probe_batch(keys, io)
        cnts = np.searchsorted(rtb.starts, keys, side="right")
        want = int((1 + (cnts * cfg.range_tombstone_size) //
                    cfg.block_size).sum())
        assert io.reads == want


# --------------------------------------------------------- registry APIs
class TestRegistryRangeOps:
    def test_live_pages_and_expire_spans(self):
        from repro.runtime import SessionRegistry
        reg = SessionRegistry(strategy="gloran", num_shards=2)
        for sid in range(40):
            reg.register(sid, np.arange(4), np.arange(4) + sid * 10)
        reg.expire_spans([(0, 10), (20, 25)])
        pages, vals = reg.live_pages(12)
        assert pages.tolist() == [0, 1, 2, 3]
        assert vals.tolist() == [120, 121, 122, 123]
        out = reg.live_pages_batch([5, 12, 22])
        assert len(out[0][0]) == 0  # expired by (0, 10)
        assert out[1][0].tolist() == [0, 1, 2, 3]
        assert len(out[2][0]) == 0  # expired by (20, 25)
