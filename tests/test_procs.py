"""Process-parallel shard execution: cross-mode parity + transport.

The procpool contract is byte-identity: an engine whose shards live in
worker processes (``EngineConfig.procs`` / ``REPRO_ENGINE_PROCS``) must
return the same results AND charge the same I/O as the in-process path,
for every strategy, device pinning, and scheduler mode.  The matrix
here drives a mixed put/delete/range-delete/get/scan workload through
procs {2, 4} x devices {0, 4} x scheduler {off, on} x all 5 strategies
and diffs against a serial in-process reference.

Worker spawn costs ~1s each, so only a strategy/mode-covering subset of
the 40 cells runs by default; set ``REPRO_PROCS_FULL_MATRIX=1`` for all
of them.  The satellites live here too: EngineConfig/WorkerSpec pickle
round-trips (spawn safety), stats() idempotency under multi-worker
merge, WAL stream-lock collision fail-fast, mid-stream close draining,
and WAL recovery after a worker-mode run (both recovery modes).
"""

import os
import pickle

import numpy as np
import pytest

from repro.core.eve import RAEConfig
from repro.core.gloran import GloranConfig
from repro.core.lsm_drtree import LSMDRTreeConfig
from repro.engine import Engine, EngineConfig
from repro.engine.procpool import WorkerSpec
from repro.lsm import LSMConfig
from repro.lsm.tree import STRATEGIES

UNIVERSE = 1 << 20
FULL = os.environ.get("REPRO_PROCS_FULL_MATRIX", "0") not in ("0", "")


def small_lsm():
    return LSMConfig(buffer_capacity=64, size_ratio=3, key_size=16,
                     value_size=48, block_size=512,
                     key_universe=UNIVERSE)


def small_gloran():
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=16, size_ratio=3,
                              key_size=16, block_size=512),
        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def make_engine(*, strategy="gloran", shards=4, procs=0, devices=0,
                scheduler=False, pipeline=None, **kw):
    cfg = EngineConfig(procs=procs, devices=devices, scheduler=scheduler,
                       pipeline=bool(procs) if pipeline is None
                       else pipeline,
                       cache_blocks=256, kernel_min_batch=1,
                       kernel_min_areas=1, kernel_min_filter=1,
                       cascade_compiled=True, **kw)
    return Engine(shards, strategy=strategy, lsm_config=small_lsm(),
                  gloran_config=small_gloran(), config=cfg)


def drive(eng, rounds=2, universe=2000, seed=7):
    """Mixed workload with flushes; returns every result surface."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(rounds):
        keys = rng.integers(0, universe, size=220).astype(np.uint64)
        vals = rng.integers(1, 1 << 40, size=220, dtype=np.uint64)
        eng.put_batch(keys, vals)
        eng.delete_batch(keys[:30])
        lo = int(rng.integers(0, universe // 2))
        eng.range_delete_batch([(lo, lo + 400), (lo + 600, lo + 900)])
        probe = rng.integers(0, universe, size=300).astype(np.uint64)
        found, got = eng.get_batch(probe)
        out.append(("get", found, got))
        for k, v in eng.range_scan_batch([(0, universe // 3),
                                          (universe // 4, universe)]):
            out.append(("scan", k, v))
    return out


def assert_same_results(ref, got):
    assert len(ref) == len(got)
    for (tag_a, a1, a2), (tag_b, b1, b2) in zip(ref, got):
        assert tag_a == tag_b
        assert np.array_equal(a1, b1)
        if tag_a == "get":
            assert np.array_equal(a2[a1], b2[b1])  # values where found
        else:
            assert np.array_equal(a2, b2)


_REFS: dict = {}


def reference(strategy, scheduler=False):
    """Serial in-process reference results + IOStats, cached per
    (strategy, scheduler) — the background scheduler runs extra
    compactions at drain points, so its I/O ledger is compared against
    a scheduler-on in-process run, not the quiescent one."""
    key = (strategy, scheduler)
    if key not in _REFS:
        eng = make_engine(strategy=strategy, procs=0, pipeline=False,
                          scheduler=scheduler)
        res = drive(eng)
        _REFS[key] = (res, eng.stats()["io"], eng.num_entries)
        eng.close()
    return _REFS[key]


# One cell per strategy x {procs, devices, scheduler} combination, with
# every strategy and every mode axis covered in the always-on subset.
SUBSET = [
    ("gloran", 2, 0, False), ("gloran", 2, 0, True),
    ("gloran", 2, 4, False), ("gloran", 4, 4, True),
    ("decomp", 2, 0, False), ("lookup_delete", 2, 0, True),
    ("scan_delete", 2, 4, False), ("lrr", 4, 0, True),
]
MATRIX = [(s, p, d, b) for s in STRATEGIES for p in (2, 4)
          for d in (0, 4) for b in (False, True)]


@pytest.mark.parametrize("strategy,procs,devices,scheduler", MATRIX)
def test_parity_matrix(strategy, procs, devices, scheduler):
    if not FULL and (strategy, procs, devices, scheduler) not in SUBSET:
        pytest.skip("full matrix gated behind REPRO_PROCS_FULL_MATRIX=1")
    ref_res, ref_io, ref_entries = reference(strategy, scheduler)
    eng = make_engine(strategy=strategy, procs=procs, devices=devices,
                      scheduler=scheduler)
    try:
        assert eng.procs == procs
        res = drive(eng)
        assert_same_results(ref_res, res)
        st = eng.stats()
        assert st["io"] == ref_io
        assert st["entries"] == ref_entries
        assert st["proc"]["workers"] == procs
        assert st["proc"]["bytes_sent"] > 0
        assert st["proc"]["dequeue_latency_us"]["count"] > 0
    finally:
        eng.close()


def test_procs_env_resolution(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE_PROCS", "2")
    eng = make_engine(procs=None, shards=4)
    try:
        assert eng.procs == 2
        found, vals = eng.get_batch(np.arange(4, dtype=np.uint64))
        assert not found.any()
    finally:
        eng.close()
    monkeypatch.setenv("REPRO_ENGINE_PROCS", "0")
    eng = make_engine(procs=None, shards=4)
    try:
        assert eng.procs == 0 and eng._proc_pool is None
    finally:
        eng.close()


def test_procs_capped_at_num_shards():
    eng = make_engine(procs=8, shards=2)
    try:
        assert eng.procs == 2
        eng.put_batch(np.arange(10, dtype=np.uint64),
                      np.arange(10, dtype=np.uint64) + np.uint64(1))
        found, vals = eng.get_batch(np.arange(10, dtype=np.uint64))
        assert found.all()
    finally:
        eng.close()


# ------------------------------------------------- spawn-safety audit

def test_engineconfig_pickle_roundtrip():
    cfg = EngineConfig(procs=3, devices=2, cache_blocks=128,
                       wal_dir="/tmp/x", scheduler=True,
                       tombstone_trigger=0.5, io_wait_s=1e-5)
    assert pickle.loads(pickle.dumps(cfg)) == cfg


def test_workerspec_pickle_roundtrip():
    spec = WorkerSpec(worker_id=1, shard_ids=(1, 3), device_ids=(0, 2),
                      host_devices=4, strategy="gloran",
                      lsm_config=small_lsm(),
                      gloran_config=small_gloran(),
                      engine_config=EngineConfig(procs=0),
                      background=True, wal_dir=None, replay=False,
                      trace=False)
    back = pickle.loads(pickle.dumps(spec))
    assert back.shard_ids == (1, 3)
    assert back.lsm_config == small_lsm()
    assert back.gloran_config == small_gloran()


def test_spawn_smoke_single_worker():
    """Minimal end-to-end spawn: 1 worker, 1 shard, one round trip."""
    eng = make_engine(shards=1, procs=1, pipeline=False)
    try:
        eng.put(5, 55)
        assert eng.get(5) == 55
        assert eng.get(6) is None
    finally:
        eng.close()


def test_proc_shard_tree_access_raises():
    eng = make_engine(procs=2)
    try:
        with pytest.raises(RuntimeError, match="worker process"):
            _ = eng.shards[0].tree
    finally:
        eng.close()


def test_worker_error_propagates():
    eng = make_engine(procs=2)
    try:
        with pytest.raises(RuntimeError, match="shard worker"):
            # A malformed control message reaches the worker and its
            # error (not a hang) comes back with the traceback.
            eng.shards[0].worker.request(3, [b"not json"])
    finally:
        eng.close()


# --------------------------------------------- stats idempotency (sat)

def test_stats_idempotent_across_calls():
    """Regression: per-worker counters are merged from cumulative
    snapshots, so stats() twice with no work between must diff clean —
    no double-counted kernel/io/wal/transport ledgers."""
    eng = make_engine(procs=2, scheduler=True)
    try:
        drive(eng, rounds=1)
        s1 = eng.stats()
        s2 = eng.stats()
        for key in ("io", "kernels", "entries", "cache", "lsm",
                    "sched", "wal"):
            assert s1.get(key) == s2.get(key), key
        # Transport counters keep counting (the stats round trips are
        # requests themselves) but never double: strictly monotonic,
        # bounded by the control messages stats() sends (one scheduler
        # drain tick + one STATS per shard).
        assert s2["proc"]["requests"] > s1["proc"]["requests"]
        assert s2["proc"]["requests"] - s1["proc"]["requests"] <= \
            2 * len(eng.shards)
    finally:
        eng.close()


# ------------------------------------------------------- wal + locks

def test_wal_dir_collision_fails_fast(tmp_path):
    a = make_engine(procs=2, shards=2,
                    wal_dir=str(tmp_path), fsync="never")
    try:
        with pytest.raises(RuntimeError,
                           match="owned by live process|failed to start"):
            Engine(2, strategy="gloran", lsm_config=small_lsm(),
                   gloran_config=small_gloran(),
                   config=EngineConfig(procs=2, devices=0,
                                       wal_dir=str(tmp_path),
                                       fsync="never"))
    finally:
        a.close()
    # Locks release on clean close: the dir is claimable again once the
    # (empty) streams are gone.


def test_mid_stream_close_drains(tmp_path):
    """close() with pipelined batches in flight must collect them all
    (acked results complete) before tearing the workers down."""
    from repro.engine import OpBatch
    eng = make_engine(procs=2, wal_dir=str(tmp_path), fsync="never")
    try:
        keys = np.arange(500, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(1))
        pends = [eng.submit(OpBatch.gets(keys)) for _ in range(4)]
    finally:
        eng.close()
    for p in pends:
        found, vals = p.get_results()
        assert found.all()
        assert np.array_equal(vals, keys + np.uint64(1))


def test_wal_recovery_after_procs_run(tmp_path):
    """Acceptance: a worker-mode durable run recovers byte-identically
    — via the in-process recovery path AND the procs recovery path."""
    from repro.durable import recover
    ref = make_engine(procs=0, pipeline=False)
    ref_res = drive(ref)
    ref_io = ref.stats()["io"]
    ref.close()

    eng = make_engine(procs=2, wal_dir=str(tmp_path), fsync="never")
    res = drive(eng)
    assert_same_results(ref_res, res)
    eng.close()

    probe = np.arange(0, 2000, 3, dtype=np.uint64)
    expected = None
    for procs in (0, 2):
        rec = recover(str(tmp_path),
                      config=EngineConfig(procs=procs, devices=0,
                                          pipeline=procs > 0,
                                          cache_blocks=256,
                                          kernel_min_batch=1,
                                          kernel_min_areas=1,
                                          kernel_min_filter=1,
                                          cascade_compiled=True))
        try:
            assert rec.recovery["frames_replayed"] > 0
            found, vals = rec.get_batch(probe)
            k, v = rec.range_scan(0, UNIVERSE)
            if expected is None:
                expected = (found, vals, k, v)
            else:
                assert np.array_equal(expected[0], found)
                assert np.array_equal(expected[1][found], vals[found])
                assert np.array_equal(expected[2], k)
                assert np.array_equal(expected[3], v)
        finally:
            rec.close()


def test_snapshot_refused_in_procs_mode(tmp_path):
    from repro.durable import take_snapshot
    eng = make_engine(procs=2, wal_dir=str(tmp_path), fsync="never")
    try:
        eng.put(1, 2)
        with pytest.raises(RuntimeError, match="procs"):
            take_snapshot(eng)
    finally:
        eng.close()


# ------------------------------------------------------------ tracing

def test_worker_spans_merge_into_one_trace():
    from repro import obs
    with obs.enabled() as tr:
        eng = make_engine(procs=2, shards=2)
        try:
            keys = np.arange(64, dtype=np.uint64)
            eng.put_batch(keys, keys + np.uint64(1))
            eng.get_batch(keys)
        finally:
            eng.close()
    ev = tr.chrome_events()
    pnames = {e["args"]["name"] for e in ev if e["name"] == "process_name"}
    assert "repro-engine" in pnames
    assert sum(n.startswith("shard-worker-") for n in pnames) == 2
    worker_spans = [e for e in ev if e.get("ph") == "X" and e["pid"] != 1]
    assert any(e["name"].startswith("shard.") for e in worker_spans)
