"""Durability subsystem: WAL, manifest, snapshots, crash recovery.

The centerpiece is the crash-consistency property: truncate the WAL at
an ARBITRARY byte offset (any record boundary or mid-record), recover,
and the store's get/scan results and level shapes must be byte-identical
to a never-crashed reference store built from exactly the surviving
frames — across all 5 range-delete strategies and 1/2/4 shards.
"""

from __future__ import annotations

import glob
import os

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.gloran import GloranConfig
from repro.core.eve import RAEConfig
from repro.core.lsm_drtree import LSMDRTreeConfig
from repro.durable import (FRAME_BATCH, LevelManifest, WalReader,
                           WalWriter, atomic_write_json, keep_last_k,
                           list_versions, recover, replay_frame,
                           take_snapshot, wal_has_frames)
from repro.durable.wal import _seg_path, shard_dir
from repro.engine import Engine, EngineConfig
from repro.lsm.format import LSMConfig
from repro.lsm.tree import STRATEGIES

UNIVERSE = 1 << 16


def small_lsm():
    # Tiny capacities so short workloads cross flush/compaction points.
    return LSMConfig(buffer_capacity=32, size_ratio=4, key_size=16,
                     value_size=16, key_universe=UNIVERSE)


def small_gloran():
    return GloranConfig(
        index=LSMDRTreeConfig(buffer_capacity=16, size_ratio=4,
                              key_size=16),
        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def make_engine(tmp, *, shards=2, strategy="gloran", fsync="batch",
                wal=True, segment_bytes=4 << 20):
    # procs pinned off: replay/snapshot assertions need direct tree
    # access; procs-mode durability has its own suite in test_procs.py.
    cfg = EngineConfig(wal_dir=str(tmp) if wal else None, fsync=fsync,
                       wal_segment_bytes=segment_bytes, devices=0,
                       pipeline=False, procs=0)
    return Engine(shards, strategy=strategy, lsm_config=small_lsm(),
                  gloran_config=small_gloran(), config=cfg)


def apply_workload(eng, ops):
    """ops: list of ("put", keys, vals) / ("del", keys) /
    ("rdel", lo, hi) / ("flush",) tuples."""
    for op in ops:
        if op[0] == "put":
            eng.put_batch(op[1], op[2])
        elif op[0] == "del":
            eng.delete_batch(op[1])
        elif op[0] == "rdel":
            eng.range_delete(op[1], op[2])
        else:
            eng.flush()


def mixed_ops(seed, n_batches=6, batch=48):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_batches):
        keys = rng.integers(1, UNIVERSE - 1, batch).astype(np.uint64)
        ops.append(("put", keys, keys * np.uint64(2 + i)))
        if i % 2 == 0:
            ops.append(("del", keys[: batch // 4]))
        if i % 2 == 1:
            lo = int(rng.integers(1, UNIVERSE // 2))
            ops.append(("rdel", lo, lo + int(rng.integers(1, 2000))))
        if i == n_batches // 2:
            ops.append(("flush",))
    return ops


def assert_same_store(a, b):
    """Byte-identical visible state AND structure between two engines."""
    probes = np.arange(1, UNIVERSE, 37, dtype=np.uint64)
    fa, va = a.get_batch(probes)
    fb, vb = b.get_batch(probes)
    np.testing.assert_array_equal(fa, fb)
    np.testing.assert_array_equal(va[fa], vb[fb])
    sa = a.range_scan(0, UNIVERSE)
    sb = b.range_scan(0, UNIVERSE)
    np.testing.assert_array_equal(sa[0], sb[0])
    np.testing.assert_array_equal(sa[1], sb[1])
    for sha, shb in zip(a.shards, b.shards):
        assert sha.tree.stats()["levels"] == shb.tree.stats()["levels"]
        assert sha.tree.seq == shb.tree.seq
        assert sha.tree.num_entries == shb.tree.num_entries


# --------------------------------------------------------------- atomic
def test_atomic_versioned_keep_last_k(tmp_path):
    d = str(tmp_path)
    for v in range(1, 6):
        atomic_write_json(os.path.join(d, f"M-{v:08d}.json"), {"v": v},
                          fsync=False)
    assert list_versions(d, "M-", ".json") == [1, 2, 3, 4, 5]
    dropped = keep_last_k(d, "M-", 2, ".json")
    assert dropped == [1, 2, 3]
    assert list_versions(d, "M-", ".json") == [4, 5]
    # tmp siblings and foreign names are ignored
    open(os.path.join(d, "M-00000009.json.tmp"), "w").close()
    open(os.path.join(d, "other.json"), "w").close()
    assert list_versions(d, "M-", ".json") == [4, 5]


# ------------------------------------------------------------------ wal
def test_wal_roundtrip_and_rotation(tmp_path):
    d = str(tmp_path)
    w = WalWriter(d, 0, segment_bytes=512, fsync="batch")
    frames_in = []
    for i in range(10):
        kinds = np.full(8, i % 3, np.uint8)
        keys = np.arange(8, dtype=np.uint64) + i
        w.append(FRAME_BATCH, i, kinds, keys, keys * 2, keys * 3,
                 keys * 4)
        frames_in.append((kinds, keys))
    w.close()
    w.close()  # idempotent
    assert w.segments_rotated > 0
    frames = WalReader(d, 0).read_frames()
    assert len(frames) == 10
    for fr, (kinds, keys) in zip(frames, frames_in):
        np.testing.assert_array_equal(fr.kinds, kinds)
        np.testing.assert_array_equal(fr.keys, keys)
        np.testing.assert_array_equal(fr.vals, keys * 2)
        np.testing.assert_array_equal(fr.los, keys * 3)
        np.testing.assert_array_equal(fr.his, keys * 4)
    assert [fr.plan_seq for fr in frames] == list(range(10))
    assert wal_has_frames(d)


def test_wal_reopen_appends_after_tail(tmp_path):
    d = str(tmp_path)
    w = WalWriter(d, 0, fsync="never")
    w.append(FRAME_BATCH, 0, np.zeros(4, np.uint8),
             np.arange(4, dtype=np.uint64), np.zeros(4, np.uint64),
             np.zeros(4, np.uint64), np.zeros(4, np.uint64))
    w.close()
    w2 = WalWriter(d, 0, fsync="never")
    w2.append(FRAME_BATCH, 1, np.ones(2, np.uint8),
              np.arange(2, dtype=np.uint64), np.zeros(2, np.uint64),
              np.zeros(2, np.uint64), np.zeros(2, np.uint64))
    w2.close()
    frames = WalReader(d, 0).read_frames()
    assert [fr.plan_seq for fr in frames] == [0, 1]
    assert [len(fr) for fr in frames] == [4, 2]


def test_wal_torn_tail_every_offset(tmp_path):
    """Truncating the single segment at EVERY byte offset yields exactly
    the frames whose bytes fully survived — never garbage, never a
    crash."""
    d = str(tmp_path)
    w = WalWriter(d, 0, fsync="never")
    ends = []
    at = 16  # segment header
    for i in range(4):
        at += w.append(FRAME_BATCH, i, np.full(3, 1, np.uint8),
                       np.arange(3, dtype=np.uint64),
                       np.zeros(3, np.uint64), np.zeros(3, np.uint64),
                       np.zeros(3, np.uint64))
        ends.append(at)
    w.close()
    path = _seg_path(shard_dir(d, 0), 0)
    blob = open(path, "rb").read()
    assert len(blob) == ends[-1]
    for cut in range(len(blob) + 1):
        with open(path, "wb") as f:
            f.write(blob[:cut])
        r = WalReader(d, 0)
        frames = r.read_frames()
        expect = sum(1 for e in ends if e <= cut)
        assert len(frames) == expect, f"cut={cut}"
        r.truncate_torn_tail()
        # After truncation the stream is clean and re-appendable.
        assert len(WalReader(d, 0).read_frames()) == expect
    with open(path, "wb") as f:
        f.write(blob)


def test_wal_crc_corruption_stops_reader(tmp_path):
    d = str(tmp_path)
    w = WalWriter(d, 0, fsync="never")
    for i in range(3):
        w.append(FRAME_BATCH, i, np.full(4, 1, np.uint8),
                 np.arange(4, dtype=np.uint64), np.zeros(4, np.uint64),
                 np.zeros(4, np.uint64), np.zeros(4, np.uint64))
    w.close()
    path = _seg_path(shard_dir(d, 0), 0)
    blob = bytearray(open(path, "rb").read())
    blob[-5] ^= 0xFF  # scribble inside the last frame's payload
    open(path, "wb").write(bytes(blob))
    r = WalReader(d, 0)
    assert len(r.read_frames()) == 2
    assert r.torn


# ------------------------------------------------------------- manifest
def test_manifest_versioned_commits_and_fallback(tmp_path):
    d = str(tmp_path)
    m = LevelManifest(d, keep=3, config={"x": 1}, fsync=False)
    v1 = m.commit()
    m.doc["shards"]["0"] = {"levels": []}
    v2 = m.commit()
    assert (v1, v2) == (1, 2)
    loaded = LevelManifest.load(d, fsync=False)
    assert loaded.version == 2
    assert loaded.config == {"x": 1}
    assert loaded.shard_record(0) == {"levels": []}
    # Damage the newest file: load falls back to the previous version.
    newest = sorted(glob.glob(os.path.join(d, "MANIFEST-*.json")))[-1]
    open(newest, "w").write("{not json")
    assert LevelManifest.load(d, fsync=False).version == 1


def test_manifest_records_structure_on_flush(tmp_path):
    eng = make_engine(tmp_path / "w", shards=1)
    keys = np.arange(1, 200, dtype=np.uint64)
    eng.put_batch(keys, keys)
    eng.flush()
    eng.close()
    m = LevelManifest.load(str(tmp_path / "w" / "manifest"))
    rec = m.shard_record(0)
    assert rec is not None and any(lv for lv in rec["levels"])
    assert rec["seq"] == len(keys)
    assert any(e.get("reason") in ("plan", "flush") for e in
               m.doc["edits"])


# ---------------------------------------------------- engine round trip
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_recover_full_log_matches_original(tmp_path, strategy):
    wdir = tmp_path / "wal"
    eng = make_engine(wdir, shards=2, strategy=strategy)
    apply_workload(eng, mixed_ops(seed=7))
    eng.close()
    rec = recover(str(wdir), config=EngineConfig(procs=0, devices=0,
                                                 pipeline=False))
    assert_same_store(eng, rec)
    rec.close()


def test_engine_refuses_dirty_wal_dir(tmp_path):
    eng = make_engine(tmp_path, shards=1)
    eng.put_batch(np.arange(1, 10, dtype=np.uint64),
                  np.arange(1, 10, dtype=np.uint64))
    eng.close()
    with pytest.raises(RuntimeError, match="recover"):
        make_engine(tmp_path, shards=1)


def test_engine_context_manager_and_close_idempotent(tmp_path):
    with make_engine(tmp_path, shards=2) as eng:
        eng.put_batch(np.arange(1, 50, dtype=np.uint64),
                      np.arange(1, 50, dtype=np.uint64))
    eng.close()  # second close is a no-op
    assert eng._pools is None
    for sh in eng.shards:
        assert sh.wal._closed


def test_wal_metrics_exposed(tmp_path):
    eng = make_engine(tmp_path, shards=2)
    keys = np.arange(1, 300, dtype=np.uint64)
    eng.put_batch(keys, keys)
    m = eng.stats()["metrics"]
    assert m["wal.bytes"] > 0
    assert m["wal.fsyncs"] > 0
    assert m["wal.frames"] >= 1
    assert m["recovery.wall_s"] == 0.0
    eng.close()
    rec = recover(str(tmp_path), config=EngineConfig(procs=0, devices=0,
                                                     pipeline=False))
    m2 = rec.stats()["metrics"]
    assert m2["recovery.wall_s"] > 0.0
    assert m2["recovery.frames_replayed"] >= 1
    rec.close()


def test_replay_after_explicit_flush_keeps_level_shapes(tmp_path):
    eng = make_engine(tmp_path, shards=1, strategy="gloran")
    keys = np.arange(1, 40, dtype=np.uint64)  # below buffer capacity
    eng.put_batch(keys[:20], keys[:20])
    eng.flush()  # structure change outside any plan
    eng.put_batch(keys[20:], keys[20:])
    eng.close()
    rec = recover(str(tmp_path), config=EngineConfig(procs=0, devices=0,
                                                     pipeline=False))
    assert_same_store(eng, rec)
    rec.close()


# ------------------------------------------------------------ snapshots
@pytest.mark.parametrize("strategy", ["gloran", "lrr", "decomp"])
def test_snapshot_tail_restart(tmp_path, strategy):
    eng = make_engine(tmp_path, shards=2, strategy=strategy)
    apply_workload(eng, mixed_ops(seed=11))
    take_snapshot(eng)
    tail_keys = np.arange(30000, 30020, dtype=np.uint64)
    eng.put_batch(tail_keys, tail_keys * 5)
    eng.close()
    rec = recover(str(tmp_path), config=EngineConfig(procs=0, devices=0,
                                                     pipeline=False))
    assert rec.recovery["snapshot_loaded"] == 1
    # Only the two post-snapshot frames replayed (WAL-tail restart).
    assert rec.recovery["frames_replayed"] <= 4
    assert_same_store(eng, rec)
    rec.close()
    # A second recovery ignores nothing new and still matches.
    rec2 = recover(str(tmp_path), config=EngineConfig(procs=0, devices=0,
                                                      pipeline=False))
    assert_same_store(eng, rec2)
    rec2.close()


def test_snapshot_ignored_when_ahead_of_wal(tmp_path):
    """A snapshot recorded past the durable prefix (possible under
    fsync='never' + power loss) is discarded; full replay still wins."""
    eng = make_engine(tmp_path, shards=1)
    keys = np.arange(1, 64, dtype=np.uint64)
    eng.put_batch(keys, keys)
    take_snapshot(eng)
    eng.close()
    # Simulate the snapshot's WAL foundation vanishing.
    for seg in glob.glob(str(tmp_path / "shard-000" / "*.wal")):
        os.remove(seg)
    rec = recover(str(tmp_path), config=EngineConfig(procs=0, devices=0,
                                                     pipeline=False))
    assert rec.recovery["snapshot_loaded"] == 0
    found, _ = rec.get_batch(keys)
    assert not found.any()  # only the (empty) durable prefix survives
    rec.close()


# ----------------------------------------------- crash consistency (HP)
def crash_oracle(frames_per_shard, router):
    """Strategy-independent visible state implied by surviving frames.

    Applied PER SHARD: a shard's ops only ever touch keys it owns, and
    after a crash one shard's stream may hold a range delete another
    shard's truncated stream lost — the survivors must not leak across.
    """
    from repro.engine.plan import (OP_DELETE, OP_PUT, OP_RANGE_DELETE)
    state: dict[int, int] = {}
    for s, frames in frames_per_shard.items():
        shard_state: dict[int, int] = {}
        for fr in frames:
            for i in range(len(fr)):
                k = int(fr.kinds[i])
                if k == OP_PUT:
                    shard_state[int(fr.keys[i])] = int(fr.vals[i])
                elif k == OP_DELETE:
                    shard_state.pop(int(fr.keys[i]), None)
                elif k == OP_RANGE_DELETE:
                    lo, hi = int(fr.los[i]), int(fr.his[i])
                    for kk in [kk for kk in shard_state
                               if lo <= kk < hi]:
                        del shard_state[kk]
        state.update(shard_state)
    return state


def truncate_wal_at(wal_dir, shard, cut):
    """Chop shard 0's stream to its first `cut` bytes (across segments,
    in listing order) — the simulated crash point."""
    sdir = shard_dir(str(wal_dir), shard)
    segs = sorted(glob.glob(os.path.join(sdir, "*.wal")))
    remaining = cut
    for seg in segs:
        size = os.path.getsize(seg)
        if remaining >= size:
            remaining -= size
            continue
        with open(seg, "r+b") as f:
            f.truncate(remaining)
        remaining = 0


def run_crash_case(tmp, strategy, shards, seed, cut_frac):
    """Truncate shard 0's WAL at an arbitrary byte offset; recovery must
    equal a never-crashed reference store built from exactly the
    surviving frames, and match the strategy-independent oracle."""
    wdir = tmp / "wal"
    eng = make_engine(wdir, shards=shards, strategy=strategy,
                      segment_bytes=2048)
    apply_workload(eng, mixed_ops(seed=seed, n_batches=4, batch=32))
    eng.close()

    # Crash: chop shard 0's stream at an arbitrary byte offset.
    sdir = shard_dir(str(wdir), 0)
    total = sum(os.path.getsize(s)
                for s in glob.glob(os.path.join(sdir, "*.wal")))
    truncate_wal_at(wdir, 0, int(cut_frac * total))

    # The durable prefix after the crash.
    surviving = {s: WalReader(str(wdir), s).read_frames()
                 for s in range(shards)}

    rec = recover(str(wdir), config=EngineConfig(procs=0, devices=0,
                                                 pipeline=False))

    # Reference: a never-crashed store fed exactly the surviving frames.
    ref = make_engine(tmp / "ref", shards=shards, strategy=strategy,
                      wal=False)
    for s in range(shards):
        for fr in surviving[s]:
            replay_frame(ref.shards[s], fr)

    assert_same_store(ref, rec)

    # Oracle cross-check: visible key->val state is exactly what the
    # surviving frames imply, independent of strategy internals.
    oracle = crash_oracle(surviving, rec.router)
    keys = np.array(sorted(oracle), dtype=np.uint64)
    if len(keys):
        found, vals = rec.get_batch(keys)
        assert found.all()
        np.testing.assert_array_equal(
            vals, np.array([oracle[int(k)] for k in keys], np.uint64))
    sk, sv = rec.range_scan(0, UNIVERSE)
    np.testing.assert_array_equal(sk, keys)
    rec.close()
    ref.close()


# Deterministic sweep: the crash-consistency property across all 5
# strategies x shards 1/2/4 at boundary and mid-record cut points —
# always collected, hypothesis or not.
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("cut_frac", [0.33, 0.87])
def test_crash_consistency_sweep(tmp_path, strategy, shards, cut_frac):
    run_crash_case(tmp_path, strategy, shards,
                   seed=hash((strategy, shards)) % 1000, cut_frac=cut_frac)


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(strategy=st.sampled_from(STRATEGIES),
           shards=st.sampled_from([1, 2, 4]),
           seed=st.integers(0, 2 ** 16),
           cut_frac=st.floats(0.0, 1.0))
    def test_crash_consistency_property(tmp_path_factory, strategy,
                                        shards, seed, cut_frac):
        run_crash_case(tmp_path_factory.mktemp("crash"), strategy,
                       shards, seed, cut_frac)
else:
    @pytest.mark.skip(reason="hypothesis not installed; randomized "
                             "crash property not collected (the "
                             "deterministic sweep above still runs)")
    def test_crash_consistency_property():
        pass
