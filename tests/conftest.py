"""Shared test bootstrap: force 4 host-platform XLA devices.

pytest imports conftest before any test module, so this runs before
jax's backends initialize — the only window in which the CPU device
count can be set (XLA locks it at first client creation).  Routed
through ``ensure_host_devices`` so an XLA_FLAGS count already forced by
the environment (the CI matrix exports one explicitly) is respected,
never overwritten.

With 4 devices available, the device-matrix parity suite
(tests/test_devices.py) can pin engines to 1/2/4 distinct devices in
one process, and every multi-shard engine test exercises per-shard
device placement by default (REPRO_ENGINE_DEVICES=0 in the environment
still forces the single-device fallback — one CI axis does exactly
that).
"""

import os

from repro.launch.mesh import ensure_host_devices

TEST_HOST_DEVICES = int(os.environ.get("REPRO_TEST_HOST_DEVICES", "4"))
ensure_host_devices(TEST_HOST_DEVICES)
