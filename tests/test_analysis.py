"""Roofline analysis unit tests: HLO collective parsing with while-loop
trip counts, term math, and report generation over the results dir."""

import numpy as np

from repro.analysis.roofline import (RooflineReport, _while_trip_counts,
                                     _split_computations, collective_bytes)

HLO = """
%add_f32 (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add_f32
  %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={0}
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %limit = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %limit), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
  %rs = f32[32,128]{1,0} reduce-scatter(%z), dimensions={0}
}
"""


def test_collective_bytes_loop_aware():
    total, by_op = collective_bytes(HLO)
    ar = 128 * 256 * 4 * 12  # f32 all-reduce x 12 trips
    ag = 64 * 512 * 2 * 12  # bf16 all-gather x 12 trips
    rs = 32 * 128 * 4  # outside the loop: x1
    assert by_op["all-reduce"] == ar
    assert by_op["all-gather"] == ag
    assert by_op["reduce-scatter"] == rs
    assert total == ar + ag + rs


def test_trip_count_parse():
    comps = _split_computations(HLO)
    trips = _while_trip_counts(HLO, comps)
    assert trips == {"body": 12}


def test_roofline_terms_and_bottleneck():
    r = RooflineReport(arch="a", shape="s", mesh="single", chips=256,
                      hlo_flops=256 * 197e12 * 2.0,  # 2 s of compute
                      hlo_bytes=256 * 819e9 * 1.0,  # 1 s of memory
                      coll_bytes=256 * 50e9 * 0.5,  # 0.5 s of collective
                      model_flops=256 * 197e12 * 1.0)
    assert abs(r.t_compute - 2.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "compute"
    assert abs(r.roofline_fraction - 1.0) < 1e-9
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9


def test_report_loads_results_dir():
    import os
    import pytest
    if not os.path.isdir("results") or not os.listdir("results"):
        pytest.skip("no dry-run results present")
    from repro.analysis.report import dryrun_table, load, roofline_table
    rows = load("results")
    assert len(rows) >= 1
    t1 = dryrun_table(rows[:5])
    t2 = roofline_table(rows)
    assert "| arch |" in t1 and "bottleneck" in t2
