"""Sharded batched query engine: routing, caching, kernel filter stage,
and end-to-end equivalence with the scalar LSM-tree read path."""

import numpy as np
import pytest

from repro.core import GloranConfig, LSMDRTreeConfig, RAEConfig
from repro.engine import BlockCache, Engine, EngineConfig, ShardRouter
from repro.lsm import LSMConfig, LSMTree, STRATEGIES

UNIVERSE = 1 << 20


def small_cfg(**kw):
    d = dict(buffer_capacity=64, size_ratio=3, key_size=16, value_size=48,
             block_size=512, key_universe=UNIVERSE)
    d.update(kw)
    return LSMConfig(**d)


def small_gloran(index_buffer=16):
    return GloranConfig(index=LSMDRTreeConfig(buffer_capacity=index_buffer,
                                              size_ratio=3, key_size=16,
                                              block_size=512),
                        eve=RAEConfig(capacity=64, key_universe=UNIVERSE))


def kernel_cfg(**kw):
    d = dict(cache_blocks=512, kernel_min_batch=1, kernel_min_areas=1,
             kernel_min_filter=1)
    d.update(kw)
    return EngineConfig(**d)


class Model:
    def __init__(self):
        self.d = {}

    def apply(self, op):
        if op[0] == "put":
            self.d[op[1]] = op[2]
        elif op[0] == "del":
            self.d.pop(op[1], None)
        else:
            for k in [k for k in self.d if op[1] <= k < op[2]]:
                del self.d[k]

    def get(self, k):
        return self.d.get(k)


def make_ops(rng, n, universe=2000, rdel_ratio=0.06, max_len=100):
    ops = []
    for _ in range(n):
        r = rng.random()
        if r < rdel_ratio:
            lo = int(rng.integers(0, universe - 2))
            ops.append(("rdel", lo, lo + int(rng.integers(1, max_len))))
        elif r < rdel_ratio + 0.05:
            ops.append(("del", int(rng.integers(0, universe))))
        else:
            ops.append(("put", int(rng.integers(0, universe)),
                        int(rng.integers(1, 1 << 30))))
    return ops


def drive(engine, model, ops):
    for op in ops:
        if op[0] == "put":
            engine.put(op[1], op[2])
        elif op[0] == "del":
            engine.delete(op[1])
        else:
            engine.range_delete(op[1], op[2])
        model.apply(op)


# ------------------------------------------------------------- routing
class TestRouter:
    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_every_key_owns_one_shard(self, partition):
        r = ShardRouter(4, partition=partition, universe=UNIVERSE)
        keys = np.random.default_rng(0).integers(
            0, UNIVERSE, size=2000).astype(np.uint64)
        sid = r.shard_of(keys)
        assert sid.min() >= 0 and sid.max() < 4
        # split covers every request index exactly once
        idxs = np.concatenate(r.split(keys))
        assert sorted(idxs.tolist()) == list(range(len(keys)))

    def test_hash_spreads_uniformly(self):
        r = ShardRouter(8, partition="hash", universe=UNIVERSE)
        keys = np.arange(80_000, dtype=np.uint64)  # adversarially dense
        counts = np.bincount(r.shard_of(keys), minlength=8)
        assert counts.min() > 0.8 * counts.mean()

    def test_range_clips_range_ops(self):
        r = ShardRouter(4, partition="range", universe=1000)
        parts = r.shards_for_range(200, 760)
        assert parts == [(0, 200, 250), (1, 250, 500), (2, 500, 750),
                         (3, 750, 760)]

    def test_range_partition_out_of_universe_keys(self):
        """shard_of clamps keys >= universe into the last shard; range
        ops must reach them there (the last slab is unbounded above)."""
        r = ShardRouter(4, partition="range", universe=1000)
        assert r.shards_for_range(4000, 6000) == [(3, 4000, 6000)]
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=EngineConfig(partition="range"))
        key = UNIVERSE + 123
        eng.put(key, 7)
        assert eng.get(key) == 7
        eng.range_delete(UNIVERSE, UNIVERSE + 1000)
        assert eng.get(key) is None

    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_round_trip_request_order(self, partition):
        """Batched results come back in request order across shards."""
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=EngineConfig(partition=partition))
        keys = np.random.default_rng(1).permutation(
            np.arange(3000, dtype=np.uint64))
        vals = keys * np.uint64(7) + np.uint64(13)
        eng.put_batch(keys, vals)
        probe = np.random.default_rng(2).permutation(keys)[:1200]
        found, got = eng.get_batch(probe)
        assert found.all()
        np.testing.assert_array_equal(got,
                                      probe * np.uint64(7) + np.uint64(13))

    def test_execute_mixed_ops_in_order(self):
        eng = Engine(num_shards=4, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran())
        res = eng.execute([
            ("put", 10, 100), ("put", 11, 110), ("get", 10),
            ("range_delete", 0, 11), ("get", 10), ("get", 11),
            ("put", 10, 200), ("get", 10), ("delete", 11), ("get", 11),
        ])
        assert res == [None, None, 100, None, None, 110, None, 200,
                       None, None]


# -------------------------------------------------------------- caching
class TestBlockCache:
    def test_lru_hit_miss_accounting(self):
        c = BlockCache(capacity_blocks=2)
        hit = c.probe_many(1, np.array([0, 1, 0]))
        assert hit.tolist() == [False, False, True]
        assert (c.hits, c.misses) == (1, 2)
        # The duplicate hit made block 0 most-recent, so admitting block 2
        # evicts block 1 (the LRU entry).
        c.probe_many(1, np.array([2]))
        assert c.probe_many(1, np.array([0]))[0]  # still resident
        assert not c.probe_many(1, np.array([1]))[0]  # evicted

    def test_disabled_cache_never_hits(self):
        c = BlockCache(0)
        assert not c.probe_many(1, np.array([0, 0, 0])).any()
        assert c.hits == 0

    def test_engine_repeated_lookups_skip_io(self):
        """Read-through cache: the second identical lookup batch charges
        (almost) no data-block I/O."""
        eng = Engine(num_shards=2, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=kernel_cfg())
        keys = np.arange(0, 3000, dtype=np.uint64)
        eng.put_batch(keys, keys + np.uint64(1))
        eng.flush()
        probe = keys[::3]
        r0 = eng.io_reads
        eng.get_batch(probe)
        cold = eng.io_reads - r0
        r0 = eng.io_reads
        eng.get_batch(probe)
        warm = eng.io_reads - r0
        assert warm < cold
        snap = eng.cache_snapshot()
        assert snap["hits"] > 0
        assert snap["hit_rate"] > 0.4


# ------------------------------------------------------- kernel filters
class TestKernelPath:
    def test_interval_and_bloom_kernels_are_hit(self):
        """Batched lookups on a DR-tree level execute through the Pallas
        interval kernel (and SSTable filters through the bloom kernel).
        The fused cascade (which supersedes both with one launch, see
        tests/test_cascade.py) is pinned off: this covers the per-level
        fallback path."""
        eng = Engine(num_shards=2, strategy="gloran",
                     lsm_config=small_cfg(),
                     gloran_config=small_gloran(index_buffer=8),
                     config=kernel_cfg(use_cascade_kernel=False))
        rng = np.random.default_rng(3)
        model = Model()
        drive(eng, model, make_ops(rng, 1500, rdel_ratio=0.15))
        eng.flush()
        probe = rng.integers(0, 2100, size=600).astype(np.uint64)
        found, vals = eng.get_batch(probe)
        kc = eng.kernel_counters
        assert kc.interval_calls > 0 and kc.interval_queries > 0
        assert kc.bloom_calls > 0 and kc.bloom_queries > 0
        for j, k in enumerate(probe.tolist()):
            want = model.get(k)
            assert bool(found[j]) == (want is not None), k
            if want is not None:
                assert vals[j] == want

    def test_kernel_gating_thresholds(self):
        """Small batches stay on the numpy filters (no kernel launches)."""
        eng = Engine(num_shards=1, strategy="gloran",
                     lsm_config=small_cfg(), gloran_config=small_gloran(),
                     config=EngineConfig(kernel_min_batch=4096))
        keys = np.arange(500, dtype=np.uint64)
        eng.put_batch(keys, keys)
        eng.range_delete(0, 100)
        eng.flush()
        eng.get_batch(keys)
        kc = eng.kernel_counters
        assert kc.interval_calls == 0 and kc.bloom_calls == 0


# --------------------------------------------------------- equivalence
class TestEquivalence:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_engine_matches_scalar_tree(self, strategy):
        """The Pallas-backed batched read path returns exactly what the
        scalar ``LSMTree.get`` path returns, for every strategy, under a
        randomized put/delete/range-delete workload."""
        rng = np.random.default_rng(17)
        ops = make_ops(rng, 1200, rdel_ratio=0.08)
        g = small_gloran() if strategy == "gloran" else None
        eng = Engine(num_shards=4, strategy=strategy,
                     lsm_config=small_cfg(), gloran_config=g,
                     config=kernel_cfg())
        tree = LSMTree(small_cfg(), strategy=strategy, gloran_config=g)
        model = Model()
        drive(eng, model, ops)
        for op in ops:
            if op[0] == "put":
                tree.put(op[1], op[2])
            elif op[0] == "del":
                tree.delete(op[1])
            else:
                tree.range_delete(op[1], op[2])
        probe = rng.integers(0, 2100, size=800).astype(np.uint64)
        found, vals = eng.get_batch(probe)
        for j, k in enumerate(probe.tolist()):
            scalar = tree.get(k)
            batched = int(vals[j]) if found[j] else None
            assert batched == scalar == model.get(k), (strategy, k)

    @pytest.mark.parametrize("partition", ["hash", "range"])
    def test_range_scan_matches_scalar(self, partition):
        rng = np.random.default_rng(23)
        ops = make_ops(rng, 900, rdel_ratio=0.08)
        eng = Engine(num_shards=3, strategy="gloran",
                     lsm_config=small_cfg(),
                     gloran_config=small_gloran(),
                     config=EngineConfig(partition=partition))
        model = Model()
        drive(eng, model, ops)
        for _ in range(10):
            lo = int(rng.integers(0, 1900))
            hi = lo + int(rng.integers(1, 300))
            ks, vs = eng.range_scan(lo, hi)
            got = sorted(zip(ks.tolist(), vs.tolist()))
            want = sorted((k, v) for k, v in model.d.items()
                          if lo <= k < hi)
            assert got == want, (partition, lo, hi)

    def test_sharded_registry_equivalent_to_unsharded(self):
        from repro.runtime import SessionRegistry
        regs = [SessionRegistry(strategy="gloran", num_shards=s,
                                engine_config=kernel_cfg() if s > 1
                                else None)
                for s in (1, 4)]
        for reg in regs:
            for sid in range(800):
                reg.register(sid, np.arange(4), np.arange(4) + sid)
            for lo in range(0, 600, 50):
                reg.expire_range(lo, lo + 30)
            reg.flush()
        sids = np.repeat(np.arange(800, dtype=np.uint64), 2)
        pages = np.tile(np.arange(2, dtype=np.uint64), 800)
        f1, v1 = regs[0].lookup(sids, pages)
        f4, v4 = regs[1].lookup(sids, pages)
        np.testing.assert_array_equal(f1, f4)
        np.testing.assert_array_equal(v1[f1], v4[f4])
