"""DR-tree, R-tree buffer, LSM-DRtree, EVE, and GloranIndex tests."""

import numpy as np
import pytest

from repro.core import (AreaSet, DRTree, EVE, GloranConfig, GloranIndex,
                        IOStats, LSMDRTree, LSMDRTreeConfig, LSMRTree,
                        RAEConfig, RTree, disjointize)


def areas_from(recs):
    return AreaSet.from_records(recs)


class TestRTree:
    def test_insert_query(self):
        t = RTree(max_entries=4)
        rng = np.random.default_rng(1)
        recs = []
        for _ in range(200):
            lo = int(rng.integers(0, 1000))
            hi = lo + int(rng.integers(1, 50))
            smax = int(rng.integers(1, 100))
            recs.append((lo, hi, 0, smax))
            t.insert(lo, hi, 0, smax)
        s = areas_from(recs)
        for _ in range(200):
            k = int(rng.integers(0, 1050))
            q = int(rng.integers(0, 110))
            assert t.covers(k, q) == s.covers_point_bruteforce(k, q)

    def test_extract_roundtrip(self):
        t = RTree(max_entries=4)
        recs = [(i * 10, i * 10 + 5, 0, i + 1) for i in range(50)]
        for r in recs:
            t.insert(*r)
        got = t.extract_all()
        assert sorted(map(tuple, got.to_records().tolist())) == sorted(recs)


class TestDRTree:
    def _tree(self, n=1000, key_size=16, block_size=4096):
        lo = np.arange(n, dtype=np.uint64) * 10
        hi = lo + 5
        smin = np.zeros(n, dtype=np.uint64)
        smax = (np.arange(n, dtype=np.uint64) % 50) + 1
        return DRTree(AreaSet(lo, hi, smin, smax), key_size=key_size,
                      block_size=block_size)

    def test_probe_cost_is_logarithmic(self):
        t = self._tree(n=100_000)
        # leaf_cap = 4096 // 32 = 128 -> 782 leaves -> height 1 + ceil(log_128 782)=3
        assert t.leaf_cap == 128
        assert t.height == 3
        assert t.probe_cost() == 3

    def test_query_correct(self):
        t = self._tree(n=500)
        io = IOStats()
        assert t.query(10, 0, io)  # area [10,15) x [0,2)
        assert not t.query(10, 2, io)
        assert not t.query(7, 0, io)  # gap
        assert io.reads == 3 * t.probe_cost()

    def test_query_batch_matches_scalar(self):
        t = self._tree(n=300)
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 3100, size=500).astype(np.uint64)
        seqs = rng.integers(0, 60, size=500).astype(np.uint64)
        got = t.query_batch(keys, seqs)
        want = np.array([t.query(int(k), int(s)) for k, s in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)

    def test_gc(self):
        t = self._tree(n=100)
        g = t.gc(watermark=25)
        assert np.all(g.areas.smax > 25)
        assert np.all(g.areas.smin >= 25)


class TestLSMDRTree:
    def test_flush_and_compaction_levels(self):
        cfg = LSMDRTreeConfig(buffer_capacity=64, size_ratio=4)
        t = LSMDRTree(cfg)
        rng = np.random.default_rng(2)
        seq = 1
        for _ in range(2000):
            lo = int(rng.integers(0, 100_000))
            t.insert(lo, lo + int(rng.integers(1, 100)), smax=seq)
            seq += 1
        assert t.num_records > 0
        assert len([l for l in t.levels if l is not None]) >= 1
        assert t.io.writes > 0

    def test_query_matches_bruteforce(self):
        cfg = LSMDRTreeConfig(buffer_capacity=32, size_ratio=3)
        t = LSMDRTree(cfg)
        rng = np.random.default_rng(3)
        recs = []
        for seq in range(1, 600):
            lo = int(rng.integers(0, 5000))
            hi = lo + int(rng.integers(1, 200))
            t.insert(lo, hi, smax=seq)
            recs.append((lo, hi, 0, seq))
        s = areas_from(recs)
        keys = rng.integers(0, 5300, size=400).astype(np.uint64)
        seqs = rng.integers(0, 650, size=400).astype(np.uint64)
        want = s.covers_batch_bruteforce(keys, seqs)
        got = np.array([t.covers(int(k), int(q)) for k, q in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(t.covers_batch(keys, seqs), want)

    def test_probe_cost_polylog(self):
        cfg = LSMDRTreeConfig(buffer_capacity=128, size_ratio=10)
        t = LSMDRTree(cfg)
        rng = np.random.default_rng(4)
        for seq in range(1, 20_001):
            lo = int(rng.integers(0, 10_000_000))
            t.insert(lo, lo + 10, smax=seq)
        # Worst-case probe cost must stay far below the linear record count.
        assert t.probe_cost() <= 20
        assert t.num_records >= 15_000  # disjointization may merge a few

    def test_gc_drops_bottom(self):
        cfg = LSMDRTreeConfig(buffer_capacity=16, size_ratio=2)
        t = LSMDRTree(cfg)
        for seq in range(1, 200):
            t.insert(seq * 100, seq * 100 + 10, smax=seq)
        before = t.num_records
        t.gc(watermark=150)
        assert t.num_records < before


class TestLSMRTreeBaseline:
    def test_query_correct_and_costlier(self):
        cfg = LSMDRTreeConfig(buffer_capacity=32, size_ratio=3)
        dr = LSMDRTree(cfg)
        r = LSMRTree(cfg)
        rng = np.random.default_rng(5)
        recs = []
        # Heavily overlapping areas: the R-tree pathology case.
        for seq in range(1, 400):
            lo = int(rng.integers(0, 500))
            hi = lo + int(rng.integers(50, 300))
            dr.insert(lo, hi, smax=seq)
            r.insert(lo, hi, smax=seq)
            recs.append((lo, hi, 0, seq))
        s = areas_from(recs)
        keys = rng.integers(0, 900, size=200).astype(np.uint64)
        seqs = rng.integers(0, 420, size=200).astype(np.uint64)
        want = s.covers_batch_bruteforce(keys, seqs)
        got = np.array([r.covers(int(k), int(q)) for k, q in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)

    def test_covers_batch_matches_scalar_and_charges_io(self):
        cfg = LSMDRTreeConfig(buffer_capacity=16, size_ratio=3)
        r = LSMRTree(cfg)
        rng = np.random.default_rng(15)
        for seq in range(1, 300):
            lo = int(rng.integers(0, 800))
            r.insert(lo, lo + int(rng.integers(20, 200)), smax=seq)
        keys = rng.integers(0, 1100, size=300).astype(np.uint64)
        seqs = rng.integers(0, 320, size=300).astype(np.uint64)
        want = np.array([r.covers(int(k), int(q))
                         for k, q in zip(keys, seqs)])
        r0 = r.io.reads
        got = r.covers_batch(keys, seqs)
        np.testing.assert_array_equal(got, want)
        assert r.io.reads > r0  # descents are charged

    def test_rtree_covers_batch_matches_scalar(self):
        t = RTree(max_entries=4)
        rng = np.random.default_rng(16)
        for _ in range(250):
            lo = int(rng.integers(0, 1000))
            t.insert(lo, lo + int(rng.integers(1, 80)),
                     0, int(rng.integers(1, 90)))
        keys = rng.integers(0, 1100, size=400).astype(np.uint64)
        seqs = rng.integers(0, 100, size=400).astype(np.uint64)
        want = np.array([t.covers(int(k), int(q))
                         for k, q in zip(keys, seqs)])
        np.testing.assert_array_equal(t.covers_batch(keys, seqs), want)

    def test_gloran0_batch_path_avoids_per_key_fallback(self):
        """GLORAN0 (use_drtree=False) exposes covers_batch, so
        ``is_deleted_batch`` never falls into the per-key Python loop."""
        g = GloranIndex(GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=16, size_ratio=3),
            eve=RAEConfig(capacity=64, key_universe=1 << 20),
            use_drtree=False))
        assert hasattr(g.index, "covers_batch")
        rng = np.random.default_rng(18)
        recs = []
        for seq in range(1, 250):
            lo = int(rng.integers(0, 5000))
            hi = lo + int(rng.integers(1, 300))
            g.range_delete(lo, hi, seq)
            recs.append((lo, hi, 0, seq))
        s = areas_from(recs)
        keys = rng.integers(0, 5400, size=400).astype(np.uint64)
        seqs = rng.integers(0, 270, size=400).astype(np.uint64)
        np.testing.assert_array_equal(
            g.is_deleted_batch(keys, seqs),
            s.covers_batch_bruteforce(keys, seqs))


class TestEVE:
    def test_no_false_negatives(self):
        eve = EVE(RAEConfig(capacity=128, key_universe=1 << 20))
        rng = np.random.default_rng(6)
        ranges = []
        for seq in range(1, 500):  # forces chain growth past 128
            lo = int(rng.integers(0, (1 << 20) - 200))
            hi = lo + int(rng.integers(1, 200))
            eve.insert_range(lo, hi, seq)
            ranges.append((lo, hi, seq))
        assert len(eve.chain) > 1
        for lo, hi, seq in ranges[::7]:
            k = (lo + hi) // 2
            # entry written before the delete (entry_seq < seq) MUST flag.
            assert eve.maybe_deleted(k, seq - 1)

    def test_entries_after_delete_can_skip(self):
        eve = EVE(RAEConfig(capacity=64, key_universe=1 << 20))
        eve.insert_range(100, 200, seq=10)
        # An entry written after every recorded delete cannot be deleted.
        assert not eve.maybe_deleted(150, entry_seq=10)
        assert not eve.maybe_deleted(150, entry_seq=999)

    def test_batch_matches_scalar(self):
        eve = EVE(RAEConfig(capacity=64, key_universe=1 << 16))
        rng = np.random.default_rng(7)
        for seq in range(1, 150):
            lo = int(rng.integers(0, (1 << 16) - 64))
            eve.insert_range(lo, lo + int(rng.integers(1, 64)), seq)
        keys = rng.integers(0, 1 << 16, size=300).astype(np.uint64)
        seqs = rng.integers(0, 160, size=300).astype(np.uint64)
        got = eve.maybe_deleted_batch(keys, seqs)
        want = np.array(
            [eve.maybe_deleted(int(k), int(s)) for k, s in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)

    def test_fpr_reasonable(self):
        # Keys far from any deleted range should mostly probe negative.
        eve = EVE(RAEConfig(capacity=4096, bits_per_record=10,
                            key_universe=1 << 30))
        rng = np.random.default_rng(8)
        for seq in range(1, 2000):
            lo = int(rng.integers(0, 1 << 29))
            eve.insert_range(lo, lo + 100, seq)
        probes = rng.integers(1 << 29, 1 << 30, size=4000).astype(np.uint64)
        fp = eve.maybe_deleted_batch(probes, np.zeros(4000, dtype=np.uint64))
        assert fp.mean() < 0.25

    def test_gc_drops_old_raes(self):
        eve = EVE(RAEConfig(capacity=8, key_universe=1 << 16))
        for seq in range(1, 40):
            eve.insert_range(seq * 10, seq * 10 + 5, seq)
        n0 = len(eve.chain)
        eve.gc(watermark=39)
        assert len(eve.chain) < n0


class TestGloranIndex:
    def test_end_to_end_validity(self):
        g = GloranIndex(GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=32, size_ratio=3),
            eve=RAEConfig(capacity=64, key_universe=1 << 20)))
        rng = np.random.default_rng(9)
        deletes = []
        seq = 0
        for _ in range(300):
            seq += 1
            lo = int(rng.integers(0, 50_000))
            hi = lo + int(rng.integers(1, 500))
            g.range_delete(lo, hi, seq)
            deletes.append((lo, hi, 0, seq))
        s = areas_from(deletes)
        keys = rng.integers(0, 51_000, size=500).astype(np.uint64)
        seqs = rng.integers(0, 320, size=500).astype(np.uint64)
        want = s.covers_batch_bruteforce(keys, seqs)
        got = np.array([g.is_deleted(int(k), int(q))
                        for k, q in zip(keys, seqs)])
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(g.is_deleted_batch(keys, seqs), want)

    def test_eve_saves_index_probes(self):
        g_eve = GloranIndex(GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=64),
            eve=RAEConfig(capacity=4096, key_universe=1 << 30)))
        g_raw = GloranIndex(GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=64), use_eve=False))
        rng = np.random.default_rng(10)
        for seq in range(1, 1000):
            lo = int(rng.integers(0, 1 << 29))
            g_eve.range_delete(lo, lo + 50, seq)
            g_raw.range_delete(lo, lo + 50, seq)
        r0_eve, r0_raw = g_eve.io.reads, g_raw.io.reads
        # Valid lookups far away from deletes: EVE should skip the index.
        for k in rng.integers(1 << 29, 1 << 30, size=200):
            g_eve.is_deleted(int(k), 2000)
            g_raw.is_deleted(int(k), 2000)
        assert (g_eve.io.reads - r0_eve) < (g_raw.io.reads - r0_raw)

    def test_memory_bytes_charges_all_four_buffer_fields(self):
        """The staging write buffer holds (lo, hi, smin, smax) per
        record: 4 key-sized fields, not 2 (the lazy disjoint probe view
        is empty until the first probe — see test_staging for the view
        accounting)."""
        cfg = GloranConfig(index=LSMDRTreeConfig(buffer_capacity=1024,
                                                 key_size=16),
                           use_eve=False)
        g = GloranIndex(cfg)
        for seq in range(1, 101):
            g.range_delete(seq * 10, seq * 10 + 5, seq)
        assert g.index.buffer.size == 100
        assert g.memory_bytes == 100 * 4 * cfg.index.key_size

    def test_gc_floor_correctness_after_update(self):
        """The paper's §4.1 hazard: key updated after a range delete must
        stay visible."""
        g = GloranIndex(GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=8),
            eve=RAEConfig(capacity=16, key_universe=1 << 16)))
        g.range_delete(5, 15, seq=8)
        assert g.is_deleted(8, entry_seq=5)  # old entry: dead
        assert not g.is_deleted(8, entry_seq=9)  # re-inserted after: live
