"""Disjointization: unit + property tests against brute-force oracles."""

import numpy as np
import pytest

try:  # optional dev dependency: property tests only run when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import (AreaSet, disjointize, disjointize_oracle,
                        merge_disjoint)


def areas_from(recs):
    return AreaSet.from_records(recs)


class TestMergeDisjoint:
    def test_empty(self):
        e = AreaSet.empty()
        a = areas_from([(0, 10, 0, 5)])
        assert len(merge_disjoint(e, e)) == 0
        assert merge_disjoint(a, e) is a
        assert merge_disjoint(e, a) is a

    def test_case_a_full_containment(self):
        # Fig 5(a): beta fully dominated by alpha -> alpha only.
        alpha = areas_from([(0, 100, 0, 50)])
        beta = areas_from([(10, 20, 0, 30)])
        out = merge_disjoint(alpha, beta)
        assert out.is_disjoint_sorted()
        rec = out.to_records()
        assert rec.shape[0] == 1
        assert tuple(rec[0]) == (0, 100, 0, 50)

    def test_case_b_key_containment_splits(self):
        # Fig 5(b): beta's key range inside alpha, beta more recent ->
        # alpha split in two, beta's interval carries the union coverage.
        alpha = areas_from([(0, 100, 0, 50)])
        beta = areas_from([(10, 20, 0, 80)])
        out = merge_disjoint(alpha, beta)
        assert out.is_disjoint_sorted()
        recs = [tuple(r) for r in out.to_records()]
        assert recs == [(0, 10, 0, 50), (10, 20, 0, 80), (20, 100, 0, 50)]

    def test_case_c_partial_overlap_trims(self):
        # Fig 5(c): partial key overlap, beta more recent -> alpha trimmed.
        alpha = areas_from([(0, 50, 0, 40)])
        beta = areas_from([(30, 90, 0, 70)])
        out = merge_disjoint(alpha, beta)
        recs = [tuple(r) for r in out.to_records()]
        assert recs == [(0, 30, 0, 40), (30, 90, 0, 70)]

    def test_seq_gap_keeps_winner_only(self):
        # Old area entirely below the newer one's floor: vacuous, dropped in
        # the overlap (paper's winner-only rule).
        alpha = areas_from([(0, 100, 0, 10)])
        beta = areas_from([(0, 100, 15, 30)])
        out = merge_disjoint(alpha, beta)
        recs = [tuple(r) for r in out.to_records()]
        assert recs == [(0, 100, 15, 30)]

    def test_adjacent_same_rect_coalesce(self):
        a = areas_from([(0, 5, 0, 7)])
        b = areas_from([(5, 10, 0, 7)])
        out = merge_disjoint(a, b)
        assert [tuple(r) for r in out.to_records()] == [(0, 10, 0, 7)]


# ---------------------------------------------------------------- property
if HAS_HYPOTHESIS:
    @st.composite
    def invariant_area_sets(draw, max_n=24, universe=200, max_seq=100):
        """Areas under the system invariant: smin at a common GC floor."""
        n = draw(st.integers(1, max_n))
        floor = draw(st.integers(0, 5))
        recs = []
        for _ in range(n):
            lo = draw(st.integers(0, universe - 2))
            hi = draw(st.integers(lo + 1, universe))
            smax = draw(st.integers(floor + 1, max_seq))
            recs.append((lo, hi, floor, smax))
        return AreaSet.from_records(recs)

    @settings(max_examples=120, deadline=None)
    @given(invariant_area_sets())
    def test_disjointize_matches_oracle(s):
        got = disjointize(s)
        want = disjointize_oracle(s)
        np.testing.assert_array_equal(got.to_records(), want.to_records())

    @settings(max_examples=120, deadline=None)
    @given(invariant_area_sets(), st.data())
    def test_disjointize_coverage_equivalence(s, data):
        """Point coverage is preserved exactly (Lemma 4.2 correctness)."""
        d = disjointize(s)
        assert d.is_disjoint_sorted()
        assert len(d) <= 2 * len(s)  # paper's 2x bound
        keys = np.array([data.draw(st.integers(0, 201)) for _ in range(32)],
                        dtype=np.uint64)
        seqs = np.array([data.draw(st.integers(0, 101)) for _ in range(32)],
                        dtype=np.uint64)
        np.testing.assert_array_equal(
            d.covers_batch_bruteforce(keys, seqs),
            s.covers_batch_bruteforce(keys, seqs))

    @settings(max_examples=60, deadline=None)
    @given(invariant_area_sets(), invariant_area_sets())
    def test_merge_of_disjoint_sets_coverage(s1, s2):
        a, b = disjointize(s1), disjointize(s2)
        m = merge_disjoint(a, b)
        assert m.is_disjoint_sorted()
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 202, size=64).astype(np.uint64)
        seqs = rng.integers(0, 102, size=64).astype(np.uint64)
        both = s1.concat(s2)
        np.testing.assert_array_equal(
            m.covers_batch_bruteforce(keys, seqs),
            both.covers_batch_bruteforce(keys, seqs))
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests "
                             "not collected")
    def test_disjointize_property_suite_requires_hypothesis():
        pass


def test_disjointize_idempotent():
    s = areas_from([(0, 50, 0, 10), (25, 75, 0, 20), (60, 90, 0, 5)])
    d1 = disjointize(s)
    d2 = disjointize(d1)
    np.testing.assert_array_equal(d1.to_records(), d2.to_records())
