"""Effective areas: the paper's 2-D representation of range deletes.

A range delete over keys ``[lo, hi)`` issued at sequence number ``s``
invalidates every entry whose key lies in ``[lo, hi)`` and whose sequence
number lies in ``[smin, smax)`` with ``smax = s`` (entries written *before*
the delete) and ``smin`` the GC floor at issue time (entries below the floor
are guaranteed already purged from the LSM-tree, so coverage below it is
vacuous).  That rectangle in (key x seqno) *working space* is the record's
**effective area** (paper §4.1, Lemma 4.1).

Areas are stored struct-of-arrays: four equal-length uint64 numpy arrays
``(lo, hi, smin, smax)``.  Intervals are half-open on both axes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

UKEY = np.uint64


@dataclass(frozen=True)
class AreaSet:
    """A set of effective areas (not necessarily disjoint)."""

    lo: np.ndarray
    hi: np.ndarray
    smin: np.ndarray
    smax: np.ndarray

    def __post_init__(self):
        n = len(self.lo)
        assert len(self.hi) == len(self.smin) == len(self.smax) == n

    def __len__(self) -> int:
        return len(self.lo)

    @staticmethod
    def empty() -> "AreaSet":
        z = np.zeros(0, dtype=UKEY)
        return AreaSet(z, z.copy(), z.copy(), z.copy())

    @staticmethod
    def from_records(records) -> "AreaSet":
        """records: iterable of (lo, hi, smin, smax)."""
        arr = np.asarray(list(records), dtype=np.uint64)
        if arr.size == 0:
            return AreaSet.empty()
        return AreaSet(arr[:, 0].copy(), arr[:, 1].copy(),
                       arr[:, 2].copy(), arr[:, 3].copy())

    @staticmethod
    def from_arrays(lo, hi, smin, smax) -> "AreaSet":
        """Columnar constructor: four flat arrays, no per-record tuples
        (the staging-buffer / engine-batch shape)."""
        return AreaSet(np.asarray(lo, dtype=UKEY), np.asarray(hi, dtype=UKEY),
                       np.asarray(smin, dtype=UKEY),
                       np.asarray(smax, dtype=UKEY))

    def to_records(self) -> np.ndarray:
        return np.stack([self.lo, self.hi, self.smin, self.smax], axis=1)

    def nbytes(self, key_size: int) -> int:
        """On-disk footprint per the paper's model: one record ~= 2 keys
        (sequence numbers are 'much smaller than the keys')."""
        return len(self) * 2 * key_size

    def covers_point_bruteforce(self, key: int, seq: int) -> bool:
        """O(n) oracle: is (key, seq) inside any rectangle?"""
        key = UKEY(key)
        seq = UKEY(seq)
        return bool(
            np.any((self.lo <= key) & (key < self.hi)
                   & (self.smin <= seq) & (seq < self.smax)))

    def covers_batch_bruteforce(self, keys: np.ndarray,
                                seqs: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=UKEY)[:, None]
        seqs = np.asarray(seqs, dtype=UKEY)[:, None]
        if len(self) == 0:
            return np.zeros(keys.shape[0], dtype=bool)
        return np.any((self.lo[None, :] <= keys) & (keys < self.hi[None, :])
                      & (self.smin[None, :] <= seqs)
                      & (seqs < self.smax[None, :]), axis=1)

    def sorted_by_lo(self) -> "AreaSet":
        order = np.argsort(self.lo, kind="stable")
        return AreaSet(self.lo[order], self.hi[order], self.smin[order],
                       self.smax[order])

    def concat(self, other: "AreaSet") -> "AreaSet":
        return AreaSet(np.concatenate([self.lo, other.lo]),
                       np.concatenate([self.hi, other.hi]),
                       np.concatenate([self.smin, other.smin]),
                       np.concatenate([self.smax, other.smax]))

    def is_disjoint_sorted(self) -> bool:
        """Canonical DR-tree level form: sorted by lo, key-disjoint."""
        if len(self) <= 1:
            return bool(np.all(self.lo < self.hi)) if len(self) else True
        ok = np.all(self.lo < self.hi)
        ok &= np.all(self.hi[:-1] <= self.lo[1:])
        return bool(ok)


def make_area(lo: int, hi: int, seq: int, floor: int = 0) -> tuple:
    """Effective area of a range delete [lo, hi) issued at sequence ``seq``.

    It kills entries with seq' < ``seq`` (strictly earlier writes), i.e. the
    half-open seq interval [floor, seq).
    """
    assert lo < hi, "empty key range"
    assert floor < seq, "range delete must postdate the GC floor"
    return (int(lo), int(hi), int(floor), int(seq))
