"""Columnar staging buffer for range-record inserts (LSM-DRtree buffer).

The paper's Lemma 4.3 update cost assumes the write buffer absorbs
range-record inserts *cheaply* before disjointize-on-flush — and the
buffer only ever needs three operations: append (absorb a range delete),
point stabbing (is (key, seq) covered?), and full drain (flush).  A
general R-tree (``core.rtree``) pays a per-record Python descent for
each of those; this buffer instead keeps the records as four flat
``uint64`` arrays ``(lo, hi, smin, smax)`` with geometric growth, so

  insert / insert_batch   amortized O(1) per record, vectorized —
                          a whole engine plan step lands as one append,
  covers / covers_batch   ``searchsorted`` over a lazily maintained
                          **disjointized view** (``core.disjointize``):
                          appends since the last probe are disjointized
                          as one chunk and two-way merged into the view
                          (the same streaming primitive compaction
                          uses), so probe cost is O(log n) per query and
                          the disjointize work is amortized over bursts,
  drain_disjoint          the flush path: the fully-merged view, equal
                          to ``disjointize(extract_all())`` under the
                          system invariant (all live ``smin`` at the GC
                          floor — what ``GloranIndex.range_delete``
                          always inserts).

The raw insertion-order records stay resident (``extract_all``), so the
buffer is also a drop-in for the R-tree's extract/clear protocol and
flush trigger points are unchanged (``size`` counts raw records).
"""

from __future__ import annotations

import numpy as np

from .areas import AreaSet, UKEY
from .disjointize import disjointize, merge_disjoint

_MIN_ALLOC = 64


class StagingBuffer:
    """Vectorized write buffer over effective areas (working-space rects)."""

    def __init__(self, capacity_hint: int = 0):
        m = max(_MIN_ALLOC, int(capacity_hint))
        self._lo = np.empty(m, dtype=UKEY)
        self._hi = np.empty(m, dtype=UKEY)
        self._smin = np.empty(m, dtype=UKEY)
        self._smax = np.empty(m, dtype=UKEY)
        self.size = 0
        self._view = AreaSet.empty()  # disjointized probe view
        self._view_n = 0  # raw records already folded into the view

    # ------------------------------------------------------------- insert
    def _grow(self, need: int) -> None:
        cap = len(self._lo)
        if self.size + need <= cap:
            return
        new = max(cap * 2, self.size + need)
        for name in ("_lo", "_hi", "_smin", "_smax"):
            arr = np.empty(new, dtype=UKEY)
            arr[:self.size] = getattr(self, name)[:self.size]
            setattr(self, name, arr)

    def insert(self, lo: int, hi: int, smin: int, smax: int) -> None:
        """Append one effective area (same signature as ``RTree.insert``)."""
        assert lo < hi and smin < smax
        self._grow(1)
        i = self.size
        self._lo[i] = lo
        self._hi[i] = hi
        self._smin[i] = smin
        self._smax[i] = smax
        self.size = i + 1

    def insert_batch(self, los, his, smins, smaxs) -> None:
        """Append a batch of effective areas as one vectorized copy."""
        los = np.asarray(los, dtype=UKEY)
        his = np.asarray(his, dtype=UKEY)
        smins = np.asarray(smins, dtype=UKEY)
        smaxs = np.asarray(smaxs, dtype=UKEY)
        n = len(los)
        if n == 0:
            return
        assert (los < his).all() and (smins < smaxs).all()
        self._grow(n)
        i = self.size
        self._lo[i:i + n] = los
        self._hi[i:i + n] = his
        self._smin[i:i + n] = smins
        self._smax[i:i + n] = smaxs
        self.size = i + n

    # -------------------------------------------------------------- query
    def _refresh_view(self) -> None:
        """Fold records appended since the last probe into the disjoint
        view: one ``disjointize`` over the pending chunk, one streaming
        two-way ``merge_disjoint`` with the existing view."""
        if self._view_n == self.size:
            return
        pend = AreaSet(self._lo[self._view_n:self.size].copy(),
                       self._hi[self._view_n:self.size].copy(),
                       self._smin[self._view_n:self.size].copy(),
                       self._smax[self._view_n:self.size].copy())
        d = disjointize(pend)
        self._view = merge_disjoint(self._view, d) if len(self._view) else d
        self._view_n = self.size

    @property
    def view(self) -> AreaSet:
        """The up-to-date disjointized probe view (canonical AreaSet)."""
        self._refresh_view()
        return self._view

    @property
    def view_records(self) -> int:
        """Records currently resident in the probe view (no build)."""
        return len(self._view)

    def covers(self, key: int, seq: int) -> bool:
        """Is (key, seq) inside any buffered rectangle?"""
        if self.size == 0:
            return False
        v = self.view
        key = UKEY(key)
        idx = int(np.searchsorted(v.lo, key, side="right")) - 1
        if idx < 0:
            return False
        return bool(key < v.hi[idx]
                    and v.smin[idx] <= UKEY(seq) < v.smax[idx])

    def covers_batch(self, keys: np.ndarray, seqs: np.ndarray) -> np.ndarray:
        """Vectorized point stabbing: one ``searchsorted`` over the
        disjoint view for the whole batch (vs. the R-tree's per-query
        multi-child descents)."""
        keys = np.asarray(keys, dtype=UKEY)
        seqs = np.asarray(seqs, dtype=UKEY)
        if self.size == 0 or len(keys) == 0:
            return np.zeros(len(keys), dtype=bool)
        v = self.view
        idx = np.searchsorted(v.lo, keys, side="right").astype(np.int64) - 1
        idxc = np.maximum(idx, 0)
        return ((idx >= 0) & (keys < v.hi[idxc]) & (v.smin[idxc] <= seqs)
                & (seqs < v.smax[idxc]))

    # ------------------------------------------------------------ extract
    def extract_all(self) -> AreaSet:
        """Raw records in insertion order (the R-tree extract protocol)."""
        return AreaSet(self._lo[:self.size].copy(),
                       self._hi[:self.size].copy(),
                       self._smin[:self.size].copy(),
                       self._smax[:self.size].copy())

    def drain_disjoint(self) -> AreaSet:
        """The flush product: every buffered record, disjointized.

        Equal to ``disjointize(self.extract_all())`` under the system
        invariant (unique canonical form of the union coverage), but
        reuses whatever part of the view probes already paid for.
        """
        return self.view

    def clear(self) -> None:
        self.size = 0
        self._view = AreaSet.empty()
        self._view_n = 0

    # ---------------------------------------------------------------- misc
    def model_bytes(self, key_size: int) -> int:
        """Resident footprint per the paper's model: each record keeps
        all four key-sized fields in memory, and the disjointized probe
        view (at most 2x records) is resident alongside them."""
        return (self.size + len(self._view)) * 4 * key_size

    @property
    def nbytes(self) -> int:
        """Actual allocated bytes (flat arrays + probe view)."""
        arrs = (self._lo, self._hi, self._smin, self._smax)
        view = (self._view.lo, self._view.hi, self._view.smin,
                self._view.smax)
        return sum(a.nbytes for a in arrs) + sum(a.nbytes for a in view)

    def __len__(self) -> int:
        return self.size
