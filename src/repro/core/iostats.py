"""I/O cost accounting.

The paper (like Monkey/Dostoevsky/Lethe) evaluates every operation as a count
of disk-block I/Os with block size ``B``.  This container has no disk and no
TPU, so the framework carries an explicit I/O ledger: every structure that
"lives on disk" charges reads/writes here.  Benchmarks report these counts —
they are the paper's own metric — alongside wall time.

Sequential access over ``nbytes`` is charged ``ceil(nbytes / B)`` I/Os;
random block access is charged 1 I/O per block touched.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class IOStats:
    """Mutable ledger of simulated block I/Os, split by cause."""

    block_size: int = 4096
    reads: int = 0
    writes: int = 0
    by_tag: dict = field(default_factory=dict)
    # Opt-in charging lock (``enable_locking``): plain ``+=`` is enough
    # on the per-shard FIFO, but with a background compaction scheduler
    # attached an engine-level drain point may charge from a caller
    # thread while the shard worker idles between plans — the lock
    # makes those interleavings count-exact.  None (the default) keeps
    # the hot path branch-cheap.
    _lock: object = field(default=None, repr=False, compare=False)

    def enable_locking(self) -> None:
        if self._lock is None:
            import threading
            self._lock = threading.Lock()

    def read_blocks(self, n: int, tag: str = "") -> None:
        # A zero charge is a no-op: it must not materialize a tag entry,
        # so ledgers stay comparable between paths that skip zero-work
        # stages entirely and paths that charge them as 0.
        n = int(n)
        if n == 0:
            return
        if self._lock is not None:
            with self._lock:
                self.reads += n
                if tag:
                    self.by_tag[tag] = self.by_tag.get(tag, 0) + n
            return
        self.reads += n
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + n

    def write_blocks(self, n: int, tag: str = "") -> None:
        n = int(n)
        if n == 0:
            return
        if self._lock is not None:
            with self._lock:
                self.writes += n
                if tag:
                    self.by_tag[tag] = self.by_tag.get(tag, 0) + n
            return
        self.writes += n
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + n

    def read_sequential(self, nbytes: int, tag: str = "") -> None:
        if nbytes > 0:
            self.read_blocks(math.ceil(nbytes / self.block_size), tag)

    def write_sequential(self, nbytes: int, tag: str = "") -> None:
        if nbytes > 0:
            self.write_blocks(math.ceil(nbytes / self.block_size), tag)

    @property
    def total(self) -> int:
        return self.reads + self.writes

    def snapshot(self) -> dict:
        return {
            "reads": self.reads,
            "writes": self.writes,
            "total": self.total,
            "by_tag": dict(self.by_tag),
        }

    def reset(self) -> None:
        self.reads = 0
        self.writes = 0
        self.by_tag.clear()


class ScopedIO:
    """Context manager measuring the I/O delta of a code region."""

    def __init__(self, stats: IOStats):
        self.stats = stats
        self.reads = 0
        self.writes = 0

    def __enter__(self) -> "ScopedIO":
        self._r0, self._w0 = self.stats.reads, self.stats.writes
        return self

    def __exit__(self, *exc) -> None:
        self.reads = self.stats.reads - self._r0
        self.writes = self.stats.writes - self._w0

    @property
    def total(self) -> int:
        return self.reads + self.writes
