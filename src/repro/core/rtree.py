"""In-memory R-tree over effective areas (working space rectangles).

Two roles (paper §4.2):
  1. Write buffer of the LSM-DRtree: absorbs range-record inserts cheaply
     before a flush disjointizes its contents into a DR-tree.
  2. The GLORAN0 / LSM-Rtree baseline (Fig. 13a): levels store *raw*,
     possibly-overlapping areas in R-trees, so a point query may descend
     multiple children per node — the node-visit counter exposes exactly the
     tail-latency pathology the paper attributes to MBR overlap.

Classic Guttman R-tree with quadratic split.  Rectangles are half-open
[lo, hi) x [smin, smax) in (key x seqno) working space.
"""

from __future__ import annotations

import numpy as np

from .areas import AreaSet


class _Node:
    __slots__ = ("leaf", "entries", "mbr")

    def __init__(self, leaf: bool):
        self.leaf = leaf
        self.entries = []  # leaf: [(rect)], internal: [_Node]
        self.mbr = None  # (lo, hi, smin, smax)


def _rect_of(e):
    return e.mbr if isinstance(e, _Node) else e


def _union(r1, r2):
    if r1 is None:
        return r2
    if r2 is None:
        return r1
    return (min(r1[0], r2[0]), max(r1[1], r2[1]), min(r1[2], r2[2]),
            max(r1[3], r2[3]))


def _area(r):
    return (r[1] - r[0]) * (r[3] - r[2])


def _enlargement(mbr, r):
    u = _union(mbr, r)
    return _area(u) - _area(mbr)


def _contains_point(r, key: int, seq: int) -> bool:
    return r[0] <= key < r[1] and r[2] <= seq < r[3]


class RTree:
    """Point-stabbing R-tree with a node-visit counter."""

    def __init__(self, max_entries: int = 16):
        self.max_entries = max_entries
        self.min_entries = max(2, max_entries // 3)
        self.root = _Node(leaf=True)
        self.size = 0
        self.node_visits = 0  # cumulative, for I/O accounting of GLORAN0

    # ------------------------------------------------------------- insert
    def insert(self, lo: int, hi: int, smin: int, smax: int) -> None:
        rect = (int(lo), int(hi), int(smin), int(smax))
        split = self._insert(self.root, rect)
        if split is not None:
            old_root = self.root
            self.root = _Node(leaf=False)
            self.root.entries = [old_root, split]
            self.root.mbr = _union(old_root.mbr, split.mbr)
        self.size += 1

    def _insert(self, node: _Node, rect):
        node.mbr = _union(node.mbr, rect)
        if node.leaf:
            node.entries.append(rect)
        else:
            best = min(node.entries,
                       key=lambda c: (_enlargement(c.mbr, rect), _area(c.mbr)))
            split = self._insert(best, rect)
            if split is not None:
                node.entries.append(split)
        if len(node.entries) > self.max_entries:
            return self._split(node)
        return None

    def _split(self, node: _Node):
        entries = node.entries
        rects = [_rect_of(e) for e in entries]
        # Quadratic pick-seeds: pair wasting the most area.
        worst, seeds = -1.0, (0, 1)
        for i in range(len(rects)):
            for j in range(i + 1, len(rects)):
                waste = _area(_union(rects[i], rects[j])) - _area(
                    rects[i]) - _area(rects[j])
                if waste > worst:
                    worst, seeds = waste, (i, j)
        i, j = seeds
        g1, g2 = [entries[i]], [entries[j]]
        m1, m2 = rects[i], rects[j]
        rest = [e for k, e in enumerate(entries) if k not in (i, j)]
        for e in rest:
            r = _rect_of(e)
            need1 = self.min_entries - len(g1)
            need2 = self.min_entries - len(g2)
            remaining = len(rest) - (len(g1) + len(g2) - 2)
            if need1 >= remaining:
                g1.append(e)
                m1 = _union(m1, r)
            elif need2 >= remaining:
                g2.append(e)
                m2 = _union(m2, r)
            elif _enlargement(m1, r) <= _enlargement(m2, r):
                g1.append(e)
                m1 = _union(m1, r)
            else:
                g2.append(e)
                m2 = _union(m2, r)
        node.entries, node.mbr = g1, m1
        sib = _Node(leaf=node.leaf)
        sib.entries, sib.mbr = g2, m2
        return sib

    # -------------------------------------------------------------- query
    def covers(self, key: int, seq: int) -> bool:
        """Is (key, seq) inside any stored rectangle?  Counts node visits."""
        return self._covers(self.root, int(key), int(seq))

    def _covers(self, node: _Node, key: int, seq: int) -> bool:
        self.node_visits += 1
        if node.mbr is None or not _contains_point(node.mbr, key, seq):
            return False
        if node.leaf:
            return any(_contains_point(r, key, seq) for r in node.entries)
        return any(self._covers(c, key, seq) for c in node.entries
                   if _contains_point(c.mbr, key, seq))

    def covers_batch(self, keys: np.ndarray, seqs: np.ndarray) -> np.ndarray:
        """Vectorized point stabbing for a batch of (key, seq) queries.

        Descends the tree once with index masks instead of once per query;
        ``node_visits`` advances by the number of still-undecided queries
        reaching each node, mirroring the per-query descent cost that the
        GLORAN0 I/O accounting is built on.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        out = np.zeros(len(keys), dtype=bool)
        if len(keys) == 0:
            return out
        stack = [(self.root, np.arange(len(keys)))]
        while stack:
            node, idx = stack.pop()
            idx = idx[~out[idx]]  # short-circuit queries already covered
            if len(idx) == 0:
                continue
            self.node_visits += len(idx)
            if node.mbr is None:
                continue
            lo, hi, smin, smax = node.mbr
            k, s = keys[idx], seqs[idx]
            inside = (k >= lo) & (k < hi) & (s >= smin) & (s < smax)
            idx = idx[inside]
            if len(idx) == 0:
                continue
            k, s = keys[idx], seqs[idx]
            if node.leaf:
                for r in node.entries:
                    hit = (k >= r[0]) & (k < r[1]) & (s >= r[2]) & (s < r[3])
                    out[idx[hit]] = True
            else:
                for child in node.entries:
                    clo, chi, csmin, csmax = child.mbr
                    m = (k >= clo) & (k < chi) & (s >= csmin) & (s < csmax)
                    if m.any():
                        stack.append((child, idx[m]))
        return out

    def visits_for(self, key: int, seq: int) -> int:
        """Node visits for a single query (the Fig. 13a metric)."""
        before = self.node_visits
        self._covers(self.root, int(key), int(seq))
        return self.node_visits - before

    # ------------------------------------------------------------ extract
    def extract_all(self) -> AreaSet:
        recs = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.leaf:
                recs.extend(n.entries)
            else:
                stack.extend(n.entries)
        return AreaSet.from_records(recs) if recs else AreaSet.empty()

    def clear(self) -> None:
        self.root = _Node(leaf=True)
        self.size = 0

    @staticmethod
    def bulk_load(areas: AreaSet, max_entries: int = 16) -> "RTree":
        """Sort-Tile-Recursive-ish bulk load by lo key (used by GLORAN0)."""
        t = RTree(max_entries)
        order = np.argsort(areas.lo, kind="stable")
        for i in order:
            t.insert(int(areas.lo[i]), int(areas.hi[i]), int(areas.smin[i]),
                     int(areas.smax[i]))
        return t
