"""GLORAN facade: global range-delete manager = LSM-DRtree + EVE + GC.

This is what an LSM key-value store (``repro.lsm.tree.LSMTree``) plugs in as
its range-delete strategy, and what the serving runtime uses for session
KV-state expiry.  Sequence numbers are supplied by the host store; the GC
floor is advanced by bottom-level compaction watermarks.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .eve import EVE, RAEConfig
from .iostats import IOStats
from .lsm_drtree import LSMDRTree, LSMDRTreeConfig, LSMRTree


@dataclass
class GloranConfig:
    index: LSMDRTreeConfig = field(default_factory=LSMDRTreeConfig)
    eve: RAEConfig | None = field(default_factory=RAEConfig)
    use_eve: bool = True
    use_drtree: bool = True  # False => GLORAN0 (LSM-Rtree levels)


class GloranIndex:
    """Global range-record index with the EVE predictive shortcut."""

    def __init__(self, config: GloranConfig | None = None,
                 io: IOStats | None = None):
        self.config = config or GloranConfig()
        self.io = io if io is not None else IOStats(
            block_size=self.config.index.block_size)
        if self.config.use_drtree:
            self.index = LSMDRTree(self.config.index, io=self.io)
        else:
            self.index = LSMRTree(self.config.index, io=self.io)
        self.eve = EVE(self.config.eve) if self.config.use_eve else None
        self.gc_floor = 0
        self.num_range_deletes = 0

    # ------------------------------------------------------------- writes
    def range_delete(self, lo: int, hi: int, seq: int) -> None:
        """Record a range delete over keys [lo, hi) issued at ``seq``.

        Its effective area is [lo, hi) x [0, seq): it invalidates ALL
        strictly older live entries (even ones below the GC floor — the
        floor only proves *already-applied* records' low coverage vacuous;
        a fresh delete must still kill old survivors).  GC later trims the
        floor up once this record has been applied by a bottom compaction.
        """
        assert lo < hi, "empty range"
        self.index.insert(lo, hi, smax=seq, smin=0)
        if self.eve is not None:
            self.eve.insert_range(lo, hi, seq)
        self.num_range_deletes += 1

    def range_delete_batch(self, los, his, seqs) -> None:
        """Record a batch of range deletes (one engine plan step).

        The whole batch lands columnar: the index's staging buffer
        absorbs it in vectorized appends chunked at the flush boundaries
        (``LSMDRTree.insert_batch`` — flush points, level shapes, and
        I/O charges identical to per-call inserts), and the EVE
        estimator absorbs it in chunked vectorized inserts (estimator
        bits and chain growth identical to issuing one by one).
        """
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        assert (los < his).all(), "empty range"
        self.index.insert_batch(los, his, smaxs=seqs)
        if self.eve is not None:
            self.eve.insert_range_batch(los, his, seqs)
        self.num_range_deletes += len(los)

    # ------------------------------------------------------------- reads
    def is_deleted(self, key: int, entry_seq: int) -> bool:
        """Is the entry (key, entry_seq) invalidated by a range delete?

        EVE fast path first: a negative estimator probe proves validity
        without touching the on-disk index (no false negatives).
        """
        if self.eve is not None and not self.eve.maybe_deleted(key,
                                                               entry_seq):
            return False
        return self.index.covers(key, entry_seq)

    def is_deleted_batch(self, keys: np.ndarray,
                         entry_seqs: np.ndarray,
                         query_fn=None, level_cov=None) -> np.ndarray:
        """Batched validity probe.  ``query_fn`` optionally replaces how
        individual LSM-DRtree levels are probed (see
        ``LSMDRTree.covers_batch``); ``level_cov`` optionally supplies
        the per-level verdicts wholesale — an (n, G) bool matrix from
        the fused cascade kernel, one column per non-None index level
        in order — and the index only replays charging/early-exit around
        them (``LSMDRTree.covers_batch_cov``).  Other index kinds ignore
        both.  The EVE fast path always runs first: proven-valid entries
        never touch the on-disk index either way."""
        keys = np.asarray(keys, dtype=np.uint64)
        entry_seqs = np.asarray(entry_seqs, dtype=np.uint64)
        if self.eve is not None:
            maybe = self.eve.maybe_deleted_batch(keys, entry_seqs)
        else:
            maybe = np.ones(len(keys), dtype=bool)
        out = np.zeros(len(keys), dtype=bool)
        if maybe.any():
            if level_cov is not None and isinstance(self.index, LSMDRTree):
                out[maybe] = self.index.covers_batch_cov(
                    keys[maybe], entry_seqs[maybe], level_cov[maybe])
            elif query_fn is not None and isinstance(self.index, LSMDRTree):
                out[maybe] = self.index.covers_batch(
                    keys[maybe], entry_seqs[maybe], query_fn=query_fn)
            elif hasattr(self.index, "covers_batch"):
                out[maybe] = self.index.covers_batch(keys[maybe],
                                                     entry_seqs[maybe])
            else:
                out[maybe] = [self.index.covers(int(k), int(s))
                              for k, s in zip(keys[maybe],
                                              entry_seqs[maybe])]
        return out

    # ---------------------------------------------------- device views
    @property
    def index_epoch(self) -> int | None:
        """Level-structure version of the on-disk index (None when the
        index kind keeps no epoch, e.g. the GLORAN0 R-tree baseline).
        Device-resident packed views of the disjoint interval levels
        cache on this value and rebuild whenever it moves."""
        return getattr(self.index, "epoch", None)

    def level_views(self) -> list | None:
        """The non-None on-disk index levels, newest -> oldest, or None
        when the index has no disjoint levels to export (GLORAN0).  Each
        entry is a ``DRTree`` whose canonical (lo, hi, smin, smax)
        arrays ARE the disjoint interval view the cascade kernel packs;
        order here defines the column order of ``level_cov``."""
        if not isinstance(self.index, LSMDRTree):
            return None
        return [lvl for lvl in self.index.levels if lvl is not None]

    def charge_range_scan(self, lo: int, hi: int,
                          block_size: int | None = None) -> None:
        """Charge the I/O of iterating the index for one range scan.

        A scan over [lo, hi) opens one iterator per on-disk index level
        and streams the (sorted, sequential) records overlapping the
        range: 1 seek plus ``cnt * 2k / B`` sequential block reads per
        level.  ``block_size`` defaults to the index's own block size;
        the host store passes its data block size so both ledgers use
        one unit.
        """
        bs = int(block_size) if block_size else self.config.index.block_size
        for lvl in getattr(self.index, "levels", []):
            if lvl is None:
                continue
            a = lvl.areas if hasattr(lvl, "areas") else None
            if a is None or len(a) == 0:
                continue
            i0 = int(np.searchsorted(a.hi, np.uint64(lo), side="right"))
            i1 = int(np.searchsorted(a.lo, np.uint64(hi)))
            cnt = max(0, i1 - i0)
            self.io.read_blocks(
                1 + (cnt * 2 * self.config.index.key_size) // bs,
                tag="gloran_scan")

    # ----------------------------------------------------------------- gc
    def on_bottom_compaction(self, watermark: int) -> None:
        """Event-listener hook (§4.4): a bottommost-level data compaction
        finished; every obsolete entry with seq < watermark is purged, so
        records/RAEs living entirely below it are vacuous."""
        if watermark <= self.gc_floor:
            return
        self.gc_floor = watermark
        if hasattr(self.index, "gc"):
            self.index.gc(watermark)
        if self.eve is not None:
            self.eve.gc(watermark)

    # ---------------------------------------------------------------- misc
    @property
    def memory_bytes(self) -> int:
        eve = self.eve.nbytes if self.eve is not None else 0
        buf = self.index.buffer
        if hasattr(buf, "model_bytes"):
            # Columnar staging buffer: raw records (all four key-sized
            # fields resident) plus its disjointized probe view.
            b = buf.model_bytes(self.config.index.key_size)
        else:
            # R-tree write buffer (GLORAN0 baseline): four key-sized
            # fields per record.
            b = buf.size * 4 * self.config.index.key_size
        return eve + b

    def buffer_snapshot(self) -> dict:
        """Staging-buffer occupancy (surfaced through ``EngineStats``)."""
        buf = self.index.buffer
        cap = self.config.index.buffer_capacity
        return {
            "records": int(buf.size),
            "capacity": int(cap),
            "occupancy": buf.size / cap if cap else 0.0,
            "view_records": int(getattr(buf, "view_records", 0)),
        }

    @property
    def disk_bytes(self) -> int:
        return getattr(self.index, "nbytes", 0)

    def stats(self) -> dict:
        return {
            "range_deletes": self.num_range_deletes,
            "records": self.index.num_records,
            "gc_floor": self.gc_floor,
            "memory_bytes": self.memory_bytes,
            "io": self.io.snapshot(),
        }
