"""LSM-DRtree: the global range-record index (paper §4.2).

An in-memory **columnar staging buffer** (``core.staging``) absorbs
range-record inserts — one vectorized append per engine plan step, point
stabbing via searchsorted over a lazily disjointized view; a flush
disjointizes the buffer into a DR-tree pushed to level 1; level overflows
trigger streaming two-way merge compactions (``merge_disjoint``) into the
next level.  Level capacities grow by the size ratio T', so with buffer
capacity F' the structure holds Q records in O(log_T'(Q/F')) levels —
giving Lemma 4.3's update cost and Lemma 4.4's point-probe cost.  Flush
trigger points are identical to the historical per-record R-tree buffer
(flush fires exactly when ``size`` reaches F'), so level shapes and I/O
charges are unchanged by the columnar refactor.

``LSMRTree`` is the GLORAN0 baseline (Fig. 13a): identical level scheduling
but levels keep *raw* overlapping areas in bulk-loaded R-trees (and keep
the classic R-tree write buffer), so probes pay overlap-induced multi-node
descents.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .areas import AreaSet, UKEY
from .disjointize import disjointize, merge_disjoint
from .drtree import DRTree
from .iostats import IOStats
from .rtree import RTree
from .staging import StagingBuffer


@dataclass
class LSMDRTreeConfig:
    buffer_capacity: int = 8192  # F' records (4 MB / 512 B in the paper)
    size_ratio: int = 10  # T'
    key_size: int = 16  # k bytes (record = 2k)
    block_size: int = 4096  # B bytes
    fanout: int | None = None  # D; defaults to B // 2k


class LSMDRTree:
    """Global index over effective areas with LSM-style levels of DR-trees."""

    def __init__(self, config: LSMDRTreeConfig | None = None,
                 io: IOStats | None = None):
        self.config = config or LSMDRTreeConfig()
        self.io = io if io is not None else IOStats(
            block_size=self.config.block_size)
        self.buffer = StagingBuffer(self.config.buffer_capacity)
        self.levels: list[DRTree | None] = []
        self.records_inserted = 0
        # Monotonic level-structure version: bumped whenever the on-disk
        # level set changes (flush, compaction cascade, GC), so device-
        # resident packed views of the levels can invalidate by epoch
        # instead of re-hashing level identities every probe.
        self.epoch = 0

    # ------------------------------------------------------------ helpers
    def _level_capacity(self, i: int) -> int:
        # Level i (0-based on-disk) holds up to F' * T'^(i+1) records.
        return self.config.buffer_capacity * self.config.size_ratio**(i + 1)

    def _make_drtree(self, areas: AreaSet) -> DRTree:
        return DRTree(areas, key_size=self.config.key_size,
                      block_size=self.config.block_size,
                      fanout=self.config.fanout)

    # ------------------------------------------------------------- insert
    def insert(self, lo: int, hi: int, smax: int, smin: int = 0) -> None:
        """Insert the effective area of one range delete."""
        assert lo < hi and smin < smax
        self.buffer.insert(lo, hi, smin, smax)
        self.records_inserted += 1
        if self.buffer.size >= self.config.buffer_capacity:
            self.flush()

    def insert_batch(self, los, his, smaxs, smins=None) -> None:
        """Absorb a batch of range-delete records in one vectorized call.

        Chunked at the buffer-capacity boundaries so flushes fire at
        exactly the points a per-record insert loop would hit — level
        shapes, disjointize inputs, and I/O charges are identical.
        """
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        smaxs = np.asarray(smaxs, dtype=np.uint64)
        smins = (np.zeros(len(los), dtype=np.uint64) if smins is None
                 else np.asarray(smins, dtype=np.uint64))
        n = len(los)
        at = 0
        while at < n:
            room = self.config.buffer_capacity - self.buffer.size
            take = min(max(room, 1), n - at)
            self.buffer.insert_batch(los[at:at + take], his[at:at + take],
                                     smins[at:at + take],
                                     smaxs[at:at + take])
            at += take
            if self.buffer.size >= self.config.buffer_capacity:
                self.flush()
        self.records_inserted += n

    def flush(self) -> None:
        if self.buffer.size == 0:
            return
        areas = self.buffer.drain_disjoint()
        self.buffer.clear()
        tree = self._make_drtree(areas)
        self.io.write_sequential(len(areas) * 2 * self.config.key_size,
                                 tag="index_flush")
        self._push(0, tree)
        self.epoch += 1

    def _push(self, i: int, tree: DRTree) -> None:
        while len(self.levels) <= i:
            self.levels.append(None)
        if self.levels[i] is None:
            self.levels[i] = tree
        else:
            merged = merge_disjoint(self.levels[i].areas, tree.areas)
            self.io.read_blocks(self.levels[i].scan_io() + tree.scan_io(),
                                tag="index_compaction")
            self.io.write_sequential(len(merged) * 2 * self.config.key_size,
                                     tag="index_compaction")
            self.levels[i] = self._make_drtree(merged)
        if len(self.levels[i].areas) > self._level_capacity(i):
            overflow = self.levels[i]
            self.levels[i] = None
            self._push(i + 1, overflow)

    # -------------------------------------------------------------- query
    def covers(self, key: int, seq: int) -> bool:
        """Has (key, seq) been invalidated by any range delete?"""
        if self.buffer.size and self.buffer.covers(key, seq):
            return True
        for lvl in self.levels:
            if lvl is not None and lvl.query(key, seq, io=self.io):
                return True
        return False

    def covers_batch(self, keys: np.ndarray, seqs: np.ndarray,
                     query_fn=None) -> np.ndarray:
        """Batched point stabbing.  ``query_fn(level, keys, seqs, io)``
        optionally replaces HOW a level is probed (e.g. the Pallas
        interval kernel); charging stays the level's responsibility."""
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        out = np.zeros(len(keys), dtype=bool)
        if self.buffer.size:
            out |= self.buffer.covers_batch(keys, seqs)
        for lvl in self.levels:
            if lvl is not None:
                todo = ~out
                if not todo.any():
                    break
                if query_fn is not None:
                    out[todo] = query_fn(lvl, keys[todo], seqs[todo],
                                         self.io)
                else:
                    out[todo] = lvl.query_batch(keys[todo], seqs[todo],
                                                io=self.io)
        return out

    def covers_batch_cov(self, keys: np.ndarray, seqs: np.ndarray,
                         level_cov: np.ndarray) -> np.ndarray:
        """Batched point stabbing from precomputed per-level verdicts.

        ``level_cov`` is (n, G) bool — column g answers "does the g-th
        non-None level cover (key, seq)" (the fused cascade kernel's
        output, bit-exact with ``DRTree.query_batch``).  This replays
        ``covers_batch``'s control flow — in-memory buffer first, then
        levels newest->oldest with covered keys early-exiting — so the
        per-level probe I/O charges are identical; only the verdict
        computation moved to the device.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        out = np.zeros(len(keys), dtype=bool)
        if self.buffer.size:
            out |= self.buffer.covers_batch(keys, seqs)
        col = 0
        for lvl in self.levels:
            if lvl is not None:
                todo = ~out
                if not todo.any():
                    break
                assert col < level_cov.shape[1], "stale cascade view"
                self.io.read_blocks(lvl.probe_cost() * int(todo.sum()),
                                    tag="drtree_probe")
                out[todo] = level_cov[todo, col]
                col += 1
        return out

    def probe_cost(self) -> int:
        """Worst-case I/Os for one point probe (Lemma 4.4 / Eq. 2)."""
        return sum(l.probe_cost() for l in self.levels if l is not None)

    # ----------------------------------------------------------------- gc
    def gc(self, watermark: int) -> int:
        """Purge records vacuous below the bottom-compaction watermark.

        Per §4.4 GC is confined to the bottommost level, where outdated
        records concentrate.  Returns the number of records dropped.
        """
        for i in range(len(self.levels) - 1, -1, -1):
            lvl = self.levels[i]
            if lvl is not None:
                before = len(lvl)
                self.io.read_blocks(lvl.scan_io(), tag="index_gc")
                newlvl = lvl.gc(watermark)
                self.io.write_sequential(
                    len(newlvl) * 2 * self.config.key_size, tag="index_gc")
                self.levels[i] = newlvl
                self.epoch += 1
                return before - len(newlvl)
        return 0

    # ---------------------------------------------------------------- misc
    @property
    def num_records(self) -> int:
        return self.buffer.size + sum(
            len(l) for l in self.levels if l is not None)

    @property
    def nbytes(self) -> int:
        """On-disk footprint: serialized levels only (2k per record, the
        paper's model).  The in-memory write buffer is charged — at its
        full four-field in-memory width — by ``GloranIndex.memory_bytes``,
        never as disk."""
        return sum(l.nbytes for l in self.levels if l is not None)

    def all_areas(self) -> AreaSet:
        out = self.buffer.extract_all()
        for lvl in self.levels:
            if lvl is not None:
                out = out.concat(lvl.areas)
        return out


class LSMRTree:
    """GLORAN0 baseline: LSM of plain R-trees (no disjointization).

    Same buffering/level scheduling as LSMDRTree, but each on-disk level is
    a bulk-loaded R-tree over raw areas; probes are charged one I/O per
    visited node, exposing the overlap pathology of Fig. 13a.
    """

    def __init__(self, config: LSMDRTreeConfig | None = None,
                 io: IOStats | None = None):
        self.config = config or LSMDRTreeConfig()
        self.io = io if io is not None else IOStats(
            block_size=self.config.block_size)
        self.buffer = RTree()
        self.levels: list[tuple[RTree, AreaSet] | None] = []

    def _level_capacity(self, i: int) -> int:
        return self.config.buffer_capacity * self.config.size_ratio**(i + 1)

    def insert(self, lo: int, hi: int, smax: int, smin: int = 0) -> None:
        self.buffer.insert(lo, hi, smin, smax)
        if self.buffer.size >= self.config.buffer_capacity:
            self.flush()

    def insert_batch(self, los, his, smaxs, smins=None) -> None:
        """Batch absorb (API parity with ``LSMDRTree.insert_batch``).

        The baseline's R-tree buffer has no vectorized path — each
        record still pays its Python descent, which is exactly the cost
        the GLORAN0 comparison exists to expose.
        """
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        smaxs = np.asarray(smaxs, dtype=np.uint64)
        smins = (np.zeros(len(los), dtype=np.uint64) if smins is None
                 else np.asarray(smins, dtype=np.uint64))
        for lo, hi, smax, smin in zip(los.tolist(), his.tolist(),
                                      smaxs.tolist(), smins.tolist()):
            self.insert(lo, hi, smax=smax, smin=smin)

    def flush(self) -> None:
        if self.buffer.size == 0:
            return
        areas = self.buffer.extract_all().sorted_by_lo()
        self.buffer.clear()
        self.io.write_sequential(len(areas) * 2 * self.config.key_size,
                                 tag="index_flush")
        self._push(0, areas)

    def _push(self, i: int, areas: AreaSet) -> None:
        while len(self.levels) <= i:
            self.levels.append(None)
        if self.levels[i] is None:
            self.levels[i] = (RTree.bulk_load(areas), areas)
        else:
            merged = self.levels[i][1].concat(areas).sorted_by_lo()
            self.io.read_sequential(
                (len(self.levels[i][1]) + len(areas)) * 2 *
                self.config.key_size, tag="index_compaction")
            self.io.write_sequential(len(merged) * 2 * self.config.key_size,
                                     tag="index_compaction")
            self.levels[i] = (RTree.bulk_load(merged), merged)
        if len(self.levels[i][1]) > self._level_capacity(i):
            _, overflow = self.levels[i]
            self.levels[i] = None
            self._push(i + 1, overflow)

    def covers(self, key: int, seq: int) -> bool:
        if self.buffer.size and self.buffer.covers(key, seq):
            return True
        hit = False
        for lvl in self.levels:
            if lvl is None:
                continue
            tree, _ = lvl
            v0 = tree.node_visits
            if tree.covers(key, seq):
                hit = True
            self.io.read_blocks(tree.node_visits - v0, tag="rtree_probe")
            if hit:
                break
        return hit

    def covers_batch(self, keys: np.ndarray, seqs: np.ndarray) -> np.ndarray:
        """Batched point stabbing across the buffer and every R-tree level.

        Each level descends once for the still-undecided queries (newest
        levels first, early-exiting covered queries like ``covers``), and
        charges the descent's node visits as random block I/Os — the
        overlap pathology stays visible in the ledger.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        out = np.zeros(len(keys), dtype=bool)
        if len(keys) == 0:
            return out
        if self.buffer.size:
            out |= self.buffer.covers_batch(keys, seqs)
        for lvl in self.levels:
            if lvl is None:
                continue
            todo = ~out
            if not todo.any():
                break
            tree, _ = lvl
            v0 = tree.node_visits
            out[todo] = tree.covers_batch(keys[todo], seqs[todo])
            self.io.read_blocks(tree.node_visits - v0, tag="rtree_probe")
        return out

    @property
    def num_records(self) -> int:
        return self.buffer.size + sum(
            len(l[1]) for l in self.levels if l is not None)
