"""DR-tree: static disjoint R-tree over disjointized effective areas.

Because leaves are key-disjoint and key-sorted (Lemma 4.2), at most ONE node
per tree level can cover a query key, so a point probe touches exactly
``height`` nodes: O(log_D n) worst case — the paper's core improvement over
the R-tree's overlap-induced multi-child descents.

Serialized form ("on disk"): the four sorted leaf arrays ``(lo, hi, smin,
smax)`` packed into B-byte blocks, plus fanout-D internal levels of fence
keys.  The data path is a batched binary search (`searchsorted`, and the
Pallas `interval_query` kernel on device); the node structure exists for
faithful I/O accounting per Eq. (2).
"""

from __future__ import annotations

import math

import numpy as np

from .areas import AreaSet, UKEY
from .iostats import IOStats


class DRTree:
    """Immutable disjoint R-tree level of an LSM-DRtree."""

    def __init__(self, areas: AreaSet, *, key_size: int = 16,
                 block_size: int = 4096, fanout: int | None = None):
        assert areas.is_disjoint_sorted(), "DR-tree needs canonical areas"
        self.areas = areas
        self.key_size = key_size
        self.block_size = block_size
        # One record ~= 2 keys (paper: seqnos are much smaller than keys).
        record = 2 * key_size
        self.leaf_cap = max(2, block_size // record)
        self.fanout = int(fanout) if fanout else self.leaf_cap
        assert self.fanout >= 2
        n = len(areas)
        self.n_leaves = max(1, math.ceil(n / self.leaf_cap))
        # Height counts node levels root..leaf (>=1); internal levels shrink
        # by D.
        h = 1
        m = self.n_leaves
        while m > 1:
            m = math.ceil(m / self.fanout)
            h += 1
        self.height = h

    @classmethod
    def from_arrays(cls, lo, hi, smin, smax, **kwargs) -> "DRTree":
        """Columnar bulk load: four flat canonical arrays (sorted by lo,
        key-disjoint) straight into a level — no per-record loop."""
        return cls(AreaSet.from_arrays(lo, hi, smin, smax), **kwargs)

    def __len__(self) -> int:
        return len(self.areas)

    @property
    def nbytes(self) -> int:
        # Leaves + geometric internal overhead D/(D-1) (paper Eq. 3).
        leaf_bytes = len(self.areas) * 2 * self.key_size
        return int(leaf_bytes * self.fanout / max(1, self.fanout - 1))

    # ------------------------------------------------------------- probes
    def probe_cost(self) -> int:
        """I/Os for one point probe: one node per level (Lemma 4.4)."""
        return self.height

    def query(self, key: int, seq: int, io: IOStats | None = None) -> bool:
        if io is not None:
            io.read_blocks(self.probe_cost(), tag="drtree_probe")
        a = self.areas
        if len(a) == 0:
            return False
        key = UKEY(key)
        idx = int(np.searchsorted(a.lo, key, side="right")) - 1
        if idx < 0:
            return False
        return bool(key < a.hi[idx] and a.smin[idx] <= UKEY(seq) < a.smax[idx])

    def query_batch(self, keys: np.ndarray, seqs: np.ndarray,
                    io: IOStats | None = None) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        if io is not None:
            io.read_blocks(self.probe_cost() * len(keys), tag="drtree_probe")
        a = self.areas
        if len(a) == 0:
            return np.zeros(len(keys), dtype=bool)
        idx = np.searchsorted(a.lo, keys, side="right").astype(np.int64) - 1
        idxc = np.maximum(idx, 0)
        return ((idx >= 0) & (keys < a.hi[idxc]) & (a.smin[idxc] <= seqs)
                & (seqs < a.smax[idxc]))

    # --------------------------------------------------------------- scan
    def scan_io(self) -> int:
        """Sequential I/Os to stream the whole level (compaction/iterators)."""
        return math.ceil(len(self.areas) * 2 * self.key_size /
                         self.block_size) if len(self.areas) else 0

    def gc(self, watermark: int) -> "DRTree":
        """Drop areas fully below the watermark; raise floors to it.

        An area with smax <= watermark only covers sequence numbers whose
        matching entries are guaranteed purged (bottom-compaction watermark),
        so it is vacuous for live entries (paper §4.4).
        """
        a = self.areas
        keep = a.smax > UKEY(watermark)
        sm = np.maximum(a.smin[keep], UKEY(watermark))
        return DRTree(AreaSet(a.lo[keep], a.hi[keep], sm, a.smax[keep]),
                      key_size=self.key_size, block_size=self.block_size,
                      fanout=self.fanout)
