"""GLORAN core: effective areas, disjointization, DR-tree / LSM-DRtree,
EVE validity estimator, and I/O accounting (the paper's primary
contribution, §4)."""

from .areas import AreaSet, make_area
from .disjointize import (disjointize, disjointize_arrays,
                          disjointize_oracle, merge_disjoint)
from .drtree import DRTree
from .eve import EVE, RAE, BloomBits, RAEConfig
from .gloran import GloranConfig, GloranIndex
from .iostats import IOStats, ScopedIO
from .lsm_drtree import LSMDRTree, LSMDRTreeConfig, LSMRTree
from .rtree import RTree
from .staging import StagingBuffer

__all__ = [
    "AreaSet", "make_area", "disjointize", "disjointize_arrays",
    "disjointize_oracle", "merge_disjoint", "DRTree", "EVE", "RAE",
    "BloomBits", "RAEConfig", "GloranConfig", "GloranIndex", "IOStats",
    "ScopedIO", "LSMDRTree", "LSMDRTreeConfig", "LSMRTree", "RTree",
    "StagingBuffer",
]
