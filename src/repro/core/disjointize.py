"""Disjointization of effective areas (paper §4.2, Fig. 5-6, Lemma 4.2).

Overlapping effective areas are reorganized so that each key interval is
covered by at most one rectangle.  Where two areas overlap in key space, the
*more recent* one (larger ``smax``) dominates; when their sequence intervals
overlap or touch, their coverage union is itself an interval and the output
rectangle carries ``[min(smin), max(smax))`` — exactly the paper's cases
(a)/(b)/(c) with the trimming-safety argument of §4.2.  When the sequence
intervals have a gap (which, under the system invariant, only happens when
the older area lies entirely below the GC floor and is therefore vacuous for
live entries), the dominated area's coverage is dropped, matching the
paper's winner-only rule.

The paper builds the disjoint set with a heap sweep.  On TPU-style hardware
a data-parallel formulation is preferable, so we implement disjointization
as divide-and-conquer over a **vectorized two-way streaming merge** — the
same primitive the LSM-DRtree compaction uses (§4.2 "Construction of
LSM-DRtree").  Output size is at most 2n-1 rectangles, matching the paper's
"no more than twice the original set" bound.
"""

from __future__ import annotations

import numpy as np

from .areas import AreaSet, UKEY

UMAX = np.iinfo(np.uint64).max


def _coalesce(lo, hi, smin, smax) -> AreaSet:
    """Coalesce contiguous segments of a disjoint sorted run that carry
    identical seq rectangles — the canonicalization step shared by the
    two-way merge and the sorted-run fast path of ``disjointize``."""
    brk = np.ones(len(lo), dtype=bool)
    brk[1:] = ((lo[1:] != hi[:-1]) | (smin[1:] != smin[:-1])
               | (smax[1:] != smax[:-1]))
    starts = np.flatnonzero(brk)
    ends = np.append(starts[1:], len(lo))
    return AreaSet(lo[starts], hi[ends - 1], smin[starts], smax[starts])


def merge_disjoint(a: AreaSet, b: AreaSet) -> AreaSet:
    """Merge two canonical (sorted, key-disjoint) area sets into one.

    This is the LSM-DRtree compaction primitive: a streaming two-way merge
    with pairwise disjointization, vectorized over elementary key intervals.
    Cost is O((n+m) log(n+m)) host work and — when charged by the caller —
    sequential I/O over both inputs and the output.
    """
    if len(a) == 0:
        return b
    if len(b) == 0:
        return a

    bounds = np.unique(
        np.concatenate([a.lo, a.hi, b.lo, b.hi]).astype(np.uint64))
    seg_lo = bounds[:-1]
    seg_hi = bounds[1:]

    def cover(s: AreaSet):
        idx = np.searchsorted(s.lo, seg_lo, side="right").astype(np.int64) - 1
        idxc = np.maximum(idx, 0)
        cov = (idx >= 0) & (seg_lo < s.hi[idxc])
        return cov, idxc

    cov_a, ia = cover(a)
    cov_b, ib = cover(b)

    smax_a = np.where(cov_a, a.smax[ia], UKEY(0))
    smax_b = np.where(cov_b, b.smax[ib], UKEY(0))
    smin_a = np.where(cov_a, a.smin[ia], UKEY(UMAX))
    smin_b = np.where(cov_b, b.smin[ib], UKEY(UMAX))

    a_wins = smax_a >= smax_b
    w_smax = np.maximum(smax_a, smax_b)
    w_smin = np.where(a_wins, smin_a, smin_b)
    l_smax = np.where(a_wins, smax_b, smax_a)

    both = cov_a & cov_b
    # Sequence intervals chain into one interval iff winner.smin <= loser.smax
    union_ok = both & (w_smin <= l_smax)
    out_smin = np.where(union_ok, np.minimum(smin_a, smin_b), w_smin)
    out_smax = w_smax
    keep = cov_a | cov_b

    lo_k = seg_lo[keep]
    hi_k = seg_hi[keep]
    smin_k = out_smin[keep]
    smax_k = out_smax[keep]

    if len(lo_k) == 0:
        return AreaSet.empty()

    # Coalesce contiguous segments with identical seq rectangles.
    return _coalesce(lo_k, hi_k, smin_k, smax_k)


def disjointize(s: AreaSet) -> AreaSet:
    """Disjointize an arbitrary set of effective areas (flush path).

    Columnar and loop-free per record: the set is sorted by ``lo`` once,
    split at the overlap points into maximal runs that are *already*
    key-disjoint (vectorized break detection — a fully disjoint input
    needs zero merges), each run is canonicalized, and the runs are then
    reduced bottom-up with the vectorized two-way streaming merge.
    Output is canonical (sorted by lo, key-disjoint, coalesced) —
    equivalent to the paper's heap sweep under the system invariant
    (all live ``smin`` at the GC floor).
    """
    n = len(s)
    if n == 0:
        return s
    assert bool(np.all(s.lo < s.hi)) and bool(np.all(s.smin < s.smax))
    srt = s.sorted_by_lo()
    brk = np.flatnonzero(srt.hi[:-1] > srt.lo[1:]) + 1
    bounds = np.concatenate([[0], brk, [n]])
    parts = [_coalesce(srt.lo[a:b], srt.hi[a:b], srt.smin[a:b],
                       srt.smax[a:b])
             for a, b in zip(bounds[:-1].tolist(), bounds[1:].tolist())]
    while len(parts) > 1:
        nxt = [merge_disjoint(parts[i], parts[i + 1])
               for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def disjointize_arrays(lo, hi, smin, smax) -> AreaSet:
    """Columnar entry point: disjointize four flat record arrays
    directly (no per-record tuples — the staging-buffer flush shape)."""
    return disjointize(AreaSet.from_arrays(lo, hi, smin, smax))


def disjointize_oracle(s: AreaSet) -> AreaSet:
    """Brute-force reference: elementary segments x O(n) coverage.

    Only used by tests.  Implements the ideal union semantics
    (per-segment seq coverage = [min smin, max smax) over covering areas),
    which is exact under the system invariant.
    """
    if len(s) == 0:
        return s
    bounds = np.unique(np.concatenate([s.lo, s.hi]).astype(np.uint64))
    seg_lo = bounds[:-1]
    seg_hi = bounds[1:]
    cov = (s.lo[None, :] <= seg_lo[:, None]) & (seg_lo[:, None] < s.hi[None, :])
    any_cov = cov.any(axis=1)
    smax = np.where(cov, s.smax[None, :], UKEY(0)).max(axis=1)
    smin = np.where(cov, s.smin[None, :], UKEY(UMAX)).min(axis=1)
    lo_k, hi_k = seg_lo[any_cov], seg_hi[any_cov]
    smin_k, smax_k = smin[any_cov], smax[any_cov]
    if len(lo_k) == 0:
        return AreaSet.empty()
    brk = np.ones(len(lo_k), dtype=bool)
    brk[1:] = ((lo_k[1:] != hi_k[:-1]) | (smin_k[1:] != smin_k[:-1])
               | (smax_k[1:] != smax_k[:-1]))
    starts = np.flatnonzero(brk)
    ends = np.append(starts[1:], len(lo_k))
    return AreaSet(lo_k[starts], hi_k[ends - 1], smin_k[starts],
                   smax_k[starts])
