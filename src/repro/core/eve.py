"""Entry Validity Estimator (EVE) and Range-Aware Estimator (RAE), §4.3.

RAE = a Bloom filter over a *virtual bit array*: a linear scaling function
maps the key universe [0, U) onto ``m_virt`` positions; a deleted key range
[a, b) occupies the position segment [p(a), p(b)] and only those positions
are inserted into the Bloom filter.  A negative probe of the position of a
looked-up key proves the key is covered by NO range delete (no false
negatives), letting point lookups skip the global index entirely.

EVE chains RAEs with doubling capacities; each RAE records the min/max
deletion sequence numbers it holds, so a probe for an entry with sequence
``s`` walks newest -> oldest and stops once ``rae.max_seq <= s`` (records
there can only kill strictly older entries).  GC drops RAEs entirely below
the bottom-compaction watermark.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_MIX64_1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX64_2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(30)
    x *= _MIX64_1
    x ^= x >> np.uint64(27)
    x *= _MIX64_2
    x ^= x >> np.uint64(31)
    return x


def fold64to32(x: np.ndarray) -> np.ndarray:
    """Fold uint64 items to uint32 (xor-fold after a 64-bit mix)."""
    h = _splitmix64(np.asarray(x, dtype=np.uint64))
    return (h ^ (h >> np.uint64(32))).astype(np.uint32)


def mix32(x: np.ndarray, seed: np.ndarray) -> np.ndarray:
    """murmur3-style 32-bit finalizer; identical math in numpy / jnp /
    Pallas so host filters and the TPU `bloom_probe` kernel agree
    bit-exactly (TPU has no 64-bit integer ops)."""
    x = np.asarray(x, dtype=np.uint32).copy()
    x ^= np.asarray(seed, dtype=np.uint32)
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x7FEB352D)
    x ^= x >> np.uint32(15)
    x *= np.uint32(0x846CA68B)
    x ^= x >> np.uint32(16)
    return x


class BloomBits:
    """Plain Bloom filter over uint64 items, vectorized with numpy.

    Bit positions come from 32-bit mixing (``fold64to32`` + ``mix32``);
    the batched probe path has a Pallas TPU kernel counterpart in
    ``repro.kernels.bloom`` that reproduces this math bit-exactly."""

    def __init__(self, m_bits: int, n_hashes: int, seed: int = 0x5EED):
        self.m_bits = max(64, int(m_bits))
        self.n_hashes = int(n_hashes)
        self.words = np.zeros((self.m_bits + 31) // 32, dtype=np.uint32)
        self.seeds = mix32(
            np.arange(1, self.n_hashes + 1, dtype=np.uint32),
            np.uint32(seed & 0xFFFFFFFF))

    def _positions(self, items: np.ndarray) -> np.ndarray:
        # (n_items, n_hashes) bit positions.
        x32 = fold64to32(np.asarray(items, dtype=np.uint64))
        h = mix32(np.broadcast_to(x32[:, None],
                                  (len(x32), self.n_hashes)).copy(),
                  self.seeds[None, :])
        return h % np.uint32(self.m_bits)

    def insert(self, items: np.ndarray) -> None:
        pos = self._positions(np.atleast_1d(items)).ravel()
        np.bitwise_or.at(self.words, (pos >> np.uint32(5)).astype(np.int64),
                         np.uint32(1) << (pos & np.uint32(31)))

    def might_contain(self, items: np.ndarray) -> np.ndarray:
        items = np.atleast_1d(items)
        pos = self._positions(items)
        w = self.words[(pos >> np.uint32(5)).astype(np.int64)]
        bit = (w >> (pos & np.uint32(31))) & np.uint32(1)
        return np.all(bit.astype(bool), axis=1)

    @property
    def nbytes(self) -> int:
        return self.words.nbytes


@dataclass
class RAEConfig:
    capacity: int = 800_000  # range records per RAE (paper default 0.8M)
    bits_per_record: int = 10
    n_hashes: int = 6  # ~= 0.69 * bits_per_record, capped
    key_universe: int = 1 << 63
    virt_scale: int = 4  # m_virt = capacity * virt_scale


class RAE:
    """One range-aware estimator in the EVE chain."""

    def __init__(self, config: RAEConfig, seed: int = 1):
        self.config = config
        self.m_virt = max(64, config.capacity * config.virt_scale)
        self.bloom = BloomBits(config.capacity * config.bits_per_record,
                               config.n_hashes, seed=seed)
        self.count = 0
        self.min_seq = None
        self.max_seq = 0

    def _pos(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        # p = floor(key * m_virt / U), computed in float-free integer math.
        shift = int(self.config.key_universe // self.m_virt) or 1
        return keys // np.uint64(shift)

    def insert_range(self, lo: int, hi: int, seq: int) -> None:
        """Mark the virtual-bit segment of deleted keys [lo, hi)."""
        self.insert_range_batch([lo], [hi], [seq])

    def insert_range_batch(self, los, his, seqs) -> None:
        """Batched ``insert_range``: one filter insert for the whole
        batch (identical bits — inserts are idempotent ORs)."""
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        if len(los) == 0:
            return
        p_lo = self._pos(los)
        p_hi = self._pos(np.maximum(los, his - np.uint64(1)))
        self.bloom.insert(np.concatenate(
            [np.arange(int(a), int(b) + 1, dtype=np.uint64)
             for a, b in zip(p_lo.tolist(), p_hi.tolist())]))
        self.count += len(los)
        self.max_seq = max(self.max_seq, int(seqs.max()))
        lo_seq = int(seqs.min())
        self.min_seq = lo_seq if self.min_seq is None else min(
            self.min_seq, lo_seq)

    def might_cover(self, keys: np.ndarray) -> np.ndarray:
        return self.bloom.might_contain(self._pos(np.atleast_1d(keys)))

    @property
    def full(self) -> bool:
        return self.count >= self.config.capacity

    @property
    def nbytes(self) -> int:
        return self.bloom.nbytes


class EVE:
    """Chained, doubling sequence of RAEs (Fig. 8)."""

    def __init__(self, config: RAEConfig | None = None):
        self.config = config or RAEConfig()
        self._next_seed = 1
        self.chain: list[RAE] = [self._new_rae(self.config.capacity)]

    def _new_rae(self, capacity: int) -> RAE:
        cfg = RAEConfig(capacity=capacity,
                        bits_per_record=self.config.bits_per_record,
                        n_hashes=self.config.n_hashes,
                        key_universe=self.config.key_universe,
                        virt_scale=self.config.virt_scale)
        self._next_seed += 1
        return RAE(cfg, seed=self._next_seed)

    @property
    def active(self) -> RAE:
        return self.chain[-1]

    def insert_range(self, lo: int, hi: int, seq: int) -> None:
        if self.active.full:
            self.chain.append(self._new_rae(self.active.config.capacity * 2))
        self.active.insert_range(lo, hi, seq)

    def insert_range_batch(self, los, his, seqs) -> None:
        """Batched inserts with the same chaining points as sequential
        ``insert_range`` calls: each chunk fills the active RAE to its
        capacity, then the chain doubles."""
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        seqs = np.asarray(seqs, dtype=np.uint64)
        i, n = 0, len(los)
        while i < n:
            if self.active.full:
                self.chain.append(
                    self._new_rae(self.active.config.capacity * 2))
            take = min(n - i,
                       self.active.config.capacity - self.active.count)
            self.active.insert_range_batch(los[i:i + take],
                                           his[i:i + take],
                                           seqs[i:i + take])
            i += take

    def maybe_deleted(self, key: int, entry_seq: int) -> bool:
        """False => the entry is PROVEN valid (skip the global index)."""
        for rae in reversed(self.chain):
            if rae.count and rae.max_seq <= entry_seq:
                break  # older RAEs can only kill strictly older entries
            if rae.count and bool(rae.might_cover(np.uint64(key))[0]):
                return True
        return False

    def maybe_deleted_batch(self, keys: np.ndarray,
                            entry_seqs: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.uint64)
        entry_seqs = np.asarray(entry_seqs, dtype=np.uint64)
        out = np.zeros(len(keys), dtype=bool)
        for rae in reversed(self.chain):
            if rae.count == 0:
                continue
            relevant = ~out & (entry_seqs < np.uint64(rae.max_seq))
            if not relevant.any():
                continue
            out[relevant] = rae.might_cover(keys[relevant])
        return out

    def gc(self, watermark: int) -> None:
        """Drop RAEs that only hold records below the watermark (§4.4)."""
        keep = [r for r in self.chain[:-1]
                if r.count and r.max_seq > watermark]
        self.chain = keep + [self.chain[-1]]

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.chain)
