"""Workload generation and execution for the paper's benchmarks.

Workloads are mixes of updates, point lookups, range lookups, and range
deletes over a uniform or Zipfian key distribution, executed in vectorized
batches (statistically equivalent to per-op interleaving; identical across
strategies so comparisons are fair).  Results carry wall-clock throughput,
per-op-type latency, and the simulated I/O ledger — the paper's own metric.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.gloran import GloranConfig
from ..core.lsm_drtree import LSMDRTreeConfig
from ..core.eve import RAEConfig
from ..lsm import LSMConfig, LSMTree


@dataclass
class WorkloadMix:
    lookup: float = 0.5
    update: float = 0.45
    range_delete: float = 0.05
    range_lookup: float = 0.0
    range_delete_len: int = 128
    range_lookup_len: int = 100
    universe: int = 1 << 24
    distribution: str = "uniform"  # or "zipfian"
    zipf_s: float = 0.99

    def normalized(self) -> "WorkloadMix":
        tot = self.lookup + self.update + self.range_delete + \
            self.range_lookup
        assert tot > 0
        return self


@dataclass
class WorkloadResult:
    n_ops: int
    wall_seconds: float
    ops_per_sec: float
    io_reads: int
    io_writes: int
    time_by_type: dict = field(default_factory=dict)
    io_by_type: dict = field(default_factory=dict)
    counts_by_type: dict = field(default_factory=dict)
    disk_bytes: int = 0
    memory_bytes: int = 0

    def io_per_op(self, op: str) -> float:
        c = self.counts_by_type.get(op, 0)
        return self.io_by_type.get(op, 0) / c if c else 0.0

    def modeled_ops_per_sec(self, t_io: float = 20e-6) -> float:
        """Device-grounded throughput: wall time + counted I/Os x t_io
        (default 20us ~ a 4 KB NVMe random read, the paper's hardware).
        The simulator counts I/Os instead of sleeping on them, so raw
        wall-clock alone under-charges I/O-heavy strategies."""
        total_io = self.io_reads + self.io_writes
        return self.n_ops / max(self.wall_seconds + total_io * t_io, 1e-9)

    def us_per_op(self, op: str) -> float:
        c = self.counts_by_type.get(op, 0)
        return 1e6 * self.time_by_type.get(op, 0.0) / c if c else 0.0


def zipf_keys(rng: np.random.Generator, n: int, universe: int,
              s: float = 0.99, n_distinct: int = 1 << 16) -> np.ndarray:
    """Zipfian keys over a bounded universe via inverse-CDF sampling."""
    ranks = np.arange(1, n_distinct + 1, dtype=np.float64)
    w = ranks ** (-s)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(n)
    idx = np.searchsorted(cdf, u)
    # Spread the hot ranks over the key universe deterministically.
    spread = (np.uint64(0x9E3779B97F4A7C15) *
              (idx.astype(np.uint64) + np.uint64(1)))
    return spread % np.uint64(universe)


def _draw_keys(rng, n, mix: WorkloadMix) -> np.ndarray:
    if mix.distribution == "zipfian":
        return zipf_keys(rng, n, mix.universe, mix.zipf_s)
    return rng.integers(0, mix.universe, size=n).astype(np.uint64)


def make_tree(strategy: str, *, buffer_capacity: int = 4096,
              size_ratio: int = 10, key_size: int = 256,
              value_size: int = 768, block_size: int = 4096,
              index_buffer: int = 8192, index_ratio: int = 10,
              eve_capacity: int = 100_000, eve_bits: int = 10,
              use_eve: bool = True, use_drtree: bool = True,
              universe: int = 1 << 24) -> LSMTree:
    cfg = LSMConfig(buffer_capacity=buffer_capacity, size_ratio=size_ratio,
                    key_size=key_size, value_size=value_size,
                    block_size=block_size, key_universe=universe)
    g = None
    if strategy == "gloran":
        g = GloranConfig(
            index=LSMDRTreeConfig(buffer_capacity=index_buffer,
                                  size_ratio=index_ratio, key_size=key_size,
                                  block_size=block_size),
            eve=RAEConfig(capacity=eve_capacity, bits_per_record=eve_bits,
                          key_universe=universe),
            use_eve=use_eve, use_drtree=use_drtree)
    return LSMTree(cfg, strategy=strategy, gloran_config=g)


def run_workload(tree: LSMTree, n_ops: int, mix: WorkloadMix,
                 seed: int = 0, batch: int = 512) -> WorkloadResult:
    mix = mix.normalized()
    rng = np.random.default_rng(seed)
    names = ["update", "lookup", "range_delete", "range_lookup"]
    ratios = np.array([mix.update, mix.lookup, mix.range_delete,
                       mix.range_lookup], dtype=np.float64)
    # Range ops execute batch//8 ops per drawn batch (they are per-op
    # calls); weight the batch-type draw by ratio / ops-per-batch so the
    # EFFECTIVE op mix matches the requested ratios.
    ops_per_batch = np.array([batch, batch, max(1, batch // 8),
                              max(1, batch // 8)], dtype=np.float64)
    probs = ratios / ops_per_batch
    probs /= probs.sum()
    time_by = {k: 0.0 for k in names}
    io_by = {k: 0 for k in names}
    cnt_by = {k: 0 for k in names}
    done = 0
    t_start = time.perf_counter()
    while done < n_ops:
        b = min(batch, n_ops - done)
        op = names[int(rng.choice(4, p=probs))]
        io0 = tree.io.total
        t0 = time.perf_counter()
        if op == "update":
            keys = _draw_keys(rng, b, mix)
            tree.put_batch(keys, keys * np.uint64(31) + np.uint64(7))
            n = b
        elif op == "lookup":
            keys = _draw_keys(rng, b, mix)
            tree.get_batch(keys)
            n = b
        elif op == "range_delete":
            # One range delete per "op"; a batch of b ops = b deletes.
            n = max(1, b // 8)  # cap per-batch count to keep interleaving
            los = _draw_keys(rng, n, mix)
            for lo in los.tolist():
                lo = min(lo, mix.universe - mix.range_delete_len - 1)
                tree.range_delete(lo, lo + mix.range_delete_len)
        else:  # range_lookup
            n = max(1, b // 8)
            los = _draw_keys(rng, n, mix)
            for lo in los.tolist():
                lo = min(lo, mix.universe - mix.range_lookup_len - 1)
                tree.range_scan(lo, lo + mix.range_lookup_len)
        dt = time.perf_counter() - t0
        time_by[op] += dt
        io_by[op] += tree.io.total - io0
        cnt_by[op] += n
        done += n
    wall = time.perf_counter() - t_start
    return WorkloadResult(
        n_ops=done, wall_seconds=wall, ops_per_sec=done / max(wall, 1e-9),
        io_reads=tree.io.reads, io_writes=tree.io.writes,
        time_by_type=time_by, io_by_type=io_by, counts_by_type=cnt_by,
        disk_bytes=tree.disk_bytes, memory_bytes=tree.memory_bytes)
