"""Range-delete baselines and workload harness.

The four baseline strategies from the paper's evaluation (§6) are
implemented inside :class:`repro.lsm.LSMTree` (strategy= "decomp",
"lookup_delete", "scan_delete", "lrr") next to "gloran"; this package holds
the workload generator/executor used by every benchmark.
"""

from .workload import (WorkloadMix, WorkloadResult, make_tree, run_workload,
                       zipf_keys)

__all__ = ["WorkloadMix", "WorkloadResult", "make_tree", "run_workload",
           "zipf_keys"]
