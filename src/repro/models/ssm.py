"""Mamba2 (SSD) block: in-proj, causal depthwise conv, SSD scan, gated
out-proj.  The scan itself lives in kernels/ssd (Pallas intra-chunk kernel
+ jnp chunked reference used for the differentiable path).

Decode keeps a recurrent state (h: (B, NH, N, P), conv tail: (B, W-1, Di))
— constant memory per token, which is what makes SSM archs eligible for
the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ssd.ops import ssd_chunked_scan
from .layers import rmsnorm
from .sharding import ShardingRules, constrain


def _causal_conv(x, conv_w, tail=None):
    """Depthwise causal conv. x: (B, S, Di); conv_w: (W, Di);
    tail: (B, W-1, Di) previous context for decode."""
    w = conv_w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, Di)
    out = jnp.zeros_like(x)
    for i in range(w):  # small static W (4): unrolled taps
        out = out + xp[:, i:i + x.shape[1]] * conv_w[i][None, None, :]
    new_tail = xp[:, x.shape[1]:]  # last W-1 positions
    return out, new_tail


def mamba2_block(x, p, cfg, rules: ShardingRules, state=None,
                 return_state: bool = False):
    """x: (B, S, D). p: layer params dict. state: None (train, or prefill
    when ``return_state=True``) or dict(h, conv) for single-step decode.
    Returns (y, new_state)."""
    b, s, d = x.shape
    di = cfg.ssm.expand * d
    n = cfg.ssm.d_state
    pdim = cfg.ssm.head_dim
    nh = di // pdim

    zx = jnp.einsum("bsd,de->bse", x, p["w_in"])  # (B,S,2*Di)
    z, xin = zx[..., :di], zx[..., di:]
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])  # (B,S,2N)
    Bm, Cm = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))  # (B,S,NH)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (NH,)

    xin, new_tail = _causal_conv(xin, p["conv_w"],
                                 None if state is None else state["conv"])
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    xh = xin.reshape(b, s, nh, pdim)
    xh = constrain(xh, ("batch", None, "ssm_heads", None), rules)

    if state is None:
        chunk = min(cfg.ssm.chunk, s)
        if s % chunk:
            pad = chunk - s % chunk
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            # Padding with dt=0 => exp(0)=1 decay and zero input: the
            # final state equals the state at position s.
            res = ssd_chunked_scan(xh_p, dt_p, A, B_p, C_p, chunk=chunk,
                                   return_final=return_state)
            y = (res[0] if return_state else res)[:, :s]
            new_h = res[1] if return_state else None
        else:
            res = ssd_chunked_scan(xh, dt, A, Bm, Cm, chunk=chunk,
                                   return_final=return_state)
            y = res[0] if return_state else res
            new_h = res[1] if return_state else None
    else:
        # Single-step recurrence: h <- exp(dt*A) h + dt * B x^T; y = C h.
        assert s == 1
        h = state["h"].astype(jnp.float32)  # (B, NH, N, P)
        da = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
        upd = (dt[:, 0, :, None, None]
               * Bm[:, 0, None, :, None].astype(jnp.float32)
               * xh[:, 0, :, None, :].astype(jnp.float32))
        h = h * da + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32),
                       h)[:, None].reshape(b, 1, nh, pdim)
        new_h = h
    y = y.astype(x.dtype) + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if state is not None or return_state:
        new_state = {"h": new_h, "conv": new_tail}
    else:
        new_state = None
    return out, new_state
