"""Mixture-of-Experts layer with sort-based capacity dispatch.

No (tokens x experts x capacity) one-hots: token->expert assignments are
argsorted by expert id, ranked within expert by a cumulative count, dropped
beyond capacity, and scattered into an (E, C, D) buffer — static shapes,
scalable to kimi-k2's 384 experts where dense dispatch is impossible.
Top-k gate weights are softmax-renormalized over the selected experts
(Mixtral §2).  An optional shared expert (Kimi/DeepSeek style) adds a dense
SwiGLU path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import swiglu
from .sharding import ShardingRules, constrain


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float, rules: ShardingRules,
            shared=None):
    """x: (B, S, D); router_w: (D, E); w_*: (E, D, F) / (E, F, D).

    Returns (B, S, D)."""
    b, s, d = x.shape
    e = router_w.shape[-1]
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    top_vals, top_idx = jax.lax.top_k(logits, top_k)  # (t, k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # renormalize over selected

    flat_e = top_idx.reshape(-1)  # (t*k,)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)

    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    g_sorted = flat_g[order]

    # Rank within expert: position in sorted order minus expert offset.
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - offsets[e_sorted]

    cap = int(max(8, -(-(t * top_k) // e * capacity_factor)))
    cap = -(-cap // 8) * 8  # round up to 8

    # Dispatch: scatter straight into the SHARDED (E, C, D) buffer with
    # (expert, rank) index pairs; rank >= capacity drops via OOB mode.
    # (A flat (E*C, D) intermediate would be scattered replicated on every
    # device — at kimi-k2 scale that is a ~150 GB/device temp buffer.)
    buf0 = constrain(jnp.zeros((e, cap, d), x.dtype),
                     ("experts", "expert_in", "expert_d"), rules)
    idx = jnp.stack([e_sorted, rank], axis=1)  # (t*k, 2)
    buf = buf0.at[idx[:, 0], idx[:, 1]].add(
        xf[tok_sorted], mode="drop", unique_indices=True)
    buf = constrain(buf, ("experts", "expert_in", "expert_d"), rules)

    # Expert-batched SwiGLU (einsum over the expert dim).
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    hh = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", hh, w_down)
    out_buf = constrain(out_buf, ("experts", "expert_in", "expert_d"),
                        rules)

    # Combine: gather each kept pair's expert output (OOB rank -> 0 via
    # fill), weight by the gate, scatter-add back to tokens.
    pair_out = out_buf.at[idx[:, 0], idx[:, 1]].get(
        mode="fill", fill_value=0) * g_sorted[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_sorted].add(pair_out)

    if shared is not None:
        y = y + swiglu(xf, shared["w_gate"], shared["w_up"],
                       shared["w_down"])
    return y.reshape(b, s, d)


def moe_aux_loss(x, router_w, *, top_k: int):
    """Load-balancing auxiliary loss (Switch-style f*P)."""
    t = x.shape[0] * x.shape[1]
    e = router_w.shape[-1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32)).reshape(t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(logits, top_k)
    f = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0 / (t * top_k))
    p = probs.mean(axis=0)
    return e * jnp.sum(f * p)
