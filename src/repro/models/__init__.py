"""LM model stack: attention/MoE/SSM/hybrid decoders with logical-axis
sharding and scan-over-layers compilation."""

from .model import Transformer
from .params import (ParamSpec, count_params, tree_abstract, tree_init,
                     tree_shardings)
from .sharding import DEFAULT_RULES, ShardingRules, constrain, sharding_for

__all__ = ["Transformer", "ParamSpec", "count_params", "tree_abstract",
           "tree_init", "tree_shardings", "DEFAULT_RULES", "ShardingRules",
           "constrain", "sharding_for"]
