"""Logical-axis sharding (MaxText-style rules -> PartitionSpec).

Every parameter/activation dimension carries a *logical* axis name; a rule
table maps logical axes to mesh axes.  Rules adapt to the mesh actually in
use (single-pod ('data','model') vs multi-pod ('pod','data','model')) and
per-architecture overrides handle divisibility (e.g. gemma3's 4 heads can't
split 16-way -> shard head_dim instead).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# Logical axes used across the stack:
#   batch, seq, embed, mlp, heads, kv_heads, head_dim, qkv, vocab,
#   experts, expert_in, expert_out, ssm_state, ssm_heads, conv, layers,
#   groups, stack
DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,  # residual-stream seq dim (seqpar variant -> model)
    "embed": None,
    "embed_fsdp": ("data",),  # FSDP weight shard of the d_model dim
    "mlp": ("model",),
    "q_heads": ("model",),  # resolved per-arch in Transformer.__init__
    "kv_heads": None,
    "head_dim": None,
    "vocab": ("model",),
    "experts": ("model",),
    "expert_in": ("data",),
    "expert_d": None,  # dispatch-buffer d_model dim (decode -> data)
    "expert_out": None,
    "ssm_state": None,
    "ssm_heads": ("model",),
    "conv": None,
    "layers": None,
    "groups": None,
    "stack": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_heads": None,
    "cache_dim": ("model",),
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def with_overrides(self, **kw) -> "ShardingRules":
        r = dict(self.rules)
        for k, v in kw.items():
            r[k] = tuple(v) if isinstance(v, (list, tuple)) else (
                None if v is None else (v,))
        return ShardingRules(r)

    def spec(self, axes: tuple[str | None, ...],
             mesh_axes: tuple[str, ...]) -> PartitionSpec:
        """Map logical axes -> PartitionSpec, dropping mesh axes that are
        not present in the mesh and de-duplicating mesh axes (first logical
        dim wins)."""
        used: set[str] = set()
        out = []
        for ax in axes:
            if ax is None:
                out.append(None)
                continue
            target = self.rules.get(ax)
            if target is None:
                out.append(None)
                continue
            picked = tuple(m for m in target if m in mesh_axes and
                           m not in used)
            used.update(picked)
            if len(picked) == 0:
                out.append(None)
            elif len(picked) == 1:
                out.append(picked[0])
            else:
                out.append(picked)
        return PartitionSpec(*out)


def sharding_for(axes: tuple[str | None, ...], mesh: Mesh,
                 rules: ShardingRules) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(axes, tuple(mesh.axis_names)))


def constrain(x, axes: tuple[str | None, ...], rules: ShardingRules):
    """with_sharding_constraint under the ambient mesh (no-op outside)."""
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            mesh = None
    except Exception:
        mesh = None
    if mesh is None:
        env = jax.interpreters.pxla.thread_resources.env
        if env.physical_mesh.empty:
            return x
        mesh = env.physical_mesh
    spec = rules.spec(axes, tuple(mesh.axis_names))
    return jax.lax.with_sharding_constraint(x, spec)
