"""GQA attention (causal / sliding-window / local-global) + KV cache.

Training and prefill use the differentiable jnp path (the Pallas
`flash_attention` kernel covers the TPU serving hot spot; both share
semantics via kernels/flash_attention/ref.py).  ``window`` may be a traced
scalar (-1 = full attention) so heterogeneous stacks (gemma3 5:1
local:global) scan over per-layer window values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rope
from .sharding import ShardingRules, constrain


def masked_attention(q, k, v, *, window, q_offset, lengths=None):
    """q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D); window: traced int (-1=full).

    Causal with suffix alignment: absolute query position = q_offset + i.
    ``lengths``: optional (B,) valid kv lengths (decode with ragged cache).

    GQA is a grouped einsum — K/V are never materialized per q-head
    (a jnp.repeat on a sharded KV cache forces SPMD rematerialization and
    4-8x the cache bytes).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(skv)[None, :]
    mask = k_pos <= q_pos
    mask &= jnp.where(window > 0, k_pos > (q_pos - window), True)
    mask = mask[None, None, None]
    if lengths is not None:
        mask = mask & (k_pos[None, None, None] <
                       lengths[:, None, None, None, None])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def banded_local_attention(q, k, v, *, window: int):
    """Sliding-window self-attention computing only the W-band of scores.

    Masked-full attention materializes S x S scores even when the window
    is tiny (gemma3: 512 of 32768 -> 98% of score memory/flops wasted).
    Queries are blocked by W; block i attends key blocks [i-1, i]
    (sufficient for window <= W), so scores are (S x 2W): a 2W/S fraction
    of the full computation.  ``window`` must be STATIC; S % window == 0
    (callers pad).
    """
    b, s, hq, d = q.shape
    _, _, hkv, _ = k.shape
    w = window
    assert s % w == 0 and s >= 2 * w
    nb = s // w
    group = hq // hkv
    scale = d ** -0.5

    qb = q.reshape(b, nb, w, hkv, group, d)
    kb = k.reshape(b, nb, w, hkv, d)
    vb = v.reshape(b, nb, w, hkv, d)
    zero = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zero, kb[:, :-1]], axis=1), kb],
                         axis=2)  # (b, nb, 2w, hkv, d)
    v2 = jnp.concatenate([jnp.concatenate([zero, vb[:, :-1]], axis=1), vb],
                         axis=2)

    sc = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb.astype(jnp.float32),
                    k2.astype(jnp.float32)) * scale
    q_pos = jnp.arange(w)[:, None] + w  # within-band absolute offsets
    k_pos = jnp.arange(2 * w)[None, :]
    first = jnp.arange(nb) == 0  # block 0's prev-band is padding
    mask = (k_pos <= q_pos) & (k_pos > q_pos - w)
    mask = mask[None, None] & ~(first[None, :, None, None]
                                & (k_pos[None, None] < w))
    sc = jnp.where(mask[:, :, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, v2.astype(jnp.float32))
    return o.reshape(b, s, hq, d).astype(q.dtype)


def attention_block(x, wq, wk, wv, wo, *, n_heads, n_kv_heads, head_dim,
                    positions, window, rope_fraction, rules: ShardingRules,
                    cache=None, cache_pos=None, ring: bool = False,
                    static_local_window: int | None = None):
    """Full attention sublayer (projections + rope + attention + out).

    cache: None (train/prefill over x's own keys) or dict(k=(B,Smax,Hkv,D),
    v=...) for decode; cache_pos: absolute decode position.  ``ring=True``
    treats the cache as a circular window buffer (SWA long-context decode):
    writes go to pos % cache_len and every written slot is attended (the
    buffer holds exactly the last ``window`` positions; softmax is
    permutation-invariant so slot order is irrelevant).
    Returns (out, new_cache_kv or computed kv for prefill caching).
    """
    b, s, dm = x.shape
    # 3-D projection weights (D, H, hd): head/head_dim sharding flows
    # through the einsum with no reshape (reshaping a sharded fused H*hd
    # dim forces SPMD rematerialization).
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = constrain(q, ("batch", None, "q_heads", "head_dim"), rules)
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"), rules)
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"), rules)
    q = rope(q, positions, fraction=rope_fraction)
    k = rope(k, positions, fraction=rope_fraction)

    if cache is None:
        slw = static_local_window
        if slw is not None and s % slw == 0 and s >= 2 * slw:
            # Heterogeneous stacks (gemma3 5:1): the scanned per-layer
            # ``window`` picks banded (local layers) or full (globals).
            o = jax.lax.cond(
                window > 0,
                lambda: banded_local_attention(q, k, v, window=slw),
                lambda: masked_attention(q, k, v, window=jnp.int32(-1),
                                         q_offset=0))
            new_kv = (k, v)
        else:
            o = masked_attention(q, k, v, window=window, q_offset=0)
            new_kv = (k, v)
    else:
        cache_len = cache["k"].shape[1]
        if ring:
            write_pos = cache_pos % cache_len
            q_offset = cache_len  # all written slots are in-window
            eff_window = jnp.int32(-1)
            length = jnp.minimum(cache_pos + s, cache_len)
        else:
            write_pos = cache_pos
            q_offset = cache_pos
            eff_window = window
            length = cache_pos + s
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0))
        ck = constrain(ck, ("cache_batch", "cache_seq", "cache_heads",
                            "cache_dim"), rules)
        cv = constrain(cv, ("cache_batch", "cache_seq", "cache_heads",
                            "cache_dim"), rules)
        lengths = jnp.full((b,), length, dtype=jnp.int32)
        o = masked_attention(q, ck, cv, window=eff_window,
                             q_offset=q_offset, lengths=lengths)
        new_kv = {"k": ck, "v": cv}
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return out, new_kv
