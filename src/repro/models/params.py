"""Abstract parameter specs: shapes + logical sharding axes + init rules.

The model is defined over a pytree of ``ParamSpec``; from it we derive
  - jax.ShapeDtypeStruct trees (allocation-free dry-run lowering),
  - NamedSharding trees (in_shardings for pjit),
  - materialized parameters (CPU smoke tests / the end-to-end example).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import ShardingRules, sharding_for


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | ones | zeros | a_log | dt_bias
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_abstract(specs, dtype) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=is_spec)


def tree_shardings(specs, mesh, rules: ShardingRules):
    return jax.tree.map(lambda s: sharding_for(s.axes, mesh, rules), specs,
                        is_leaf=is_spec)


def tree_init(specs, rng: jax.Array, dtype) -> dict:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    outs = []
    for k, s in zip(keys, leaves):
        if s.init == "normal":
            x = jax.random.normal(k, s.shape, jnp.float32) * s.scale
        elif s.init == "ones":
            x = jnp.ones(s.shape, jnp.float32)
        elif s.init == "zeros":
            x = jnp.zeros(s.shape, jnp.float32)
        elif s.init == "a_log":  # mamba2: A in -[1, 16], stored as log
            u = jax.random.uniform(k, s.shape, jnp.float32, 1.0, 16.0)
            x = jnp.log(u)
        elif s.init == "dt_bias":  # softplus^-1 of dt in [1e-3, 1e-1]
            u = jax.random.uniform(k, s.shape, jnp.float32, 1e-3, 1e-1)
            x = u + jnp.log(-jnp.expm1(-u))
        else:
            raise ValueError(s.init)
        outs.append(x.astype(dtype))
    return jax.tree.unflatten(treedef, outs)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))
