"""Model assembly: dense / MoE / SSM / hybrid decoder stacks.

All stacks scan over layers with stacked parameters (compact HLO — the 512
device dry-run compiles on one host).  Heterogeneous stacks are expressed
as scanned per-layer static metadata (gemma3's 5:1 local:global = per-layer
window vector) or grouped scans (zamba2's shared attention block applied
between groups of Mamba2 layers).

Entry points:
  forward_train(params, tokens|embeds)            -> logits
  prefill(params, tokens|embeds)                  -> (logits, cache)
  decode_step(params, token, cache, pos)          -> (logits, cache)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention_block
from .layers import rmsnorm, swiglu
from .moe import moe_ffn
from .params import ParamSpec
from .sharding import ShardingRules, constrain
from .ssm import mamba2_block

P = ParamSpec


class Transformer:
    # Tensor-parallel width of the production meshes ('model' axis).
    MODEL_PAR = 16

    def __init__(self, cfg: ModelConfig, rules: ShardingRules | None = None):
        self.cfg = cfg
        self.rules = rules or ShardingRules()
        # Resolve head sharding per arch: shard q heads when 16-divisible,
        # else shard head_dim (gemma3/paligemma: 4-8 heads of dim 256),
        # else replicate (h2o/zamba: 120/112-dim heads).
        m = self.MODEL_PAR
        if cfg.n_heads and cfg.n_heads % m == 0:
            q_rule, hd_rule = "model", None
        elif cfg.head_dim_ and cfg.head_dim_ % m == 0:
            q_rule, hd_rule = None, "model"
        else:
            q_rule, hd_rule = None, None
        kv_rule = "model" if (cfg.n_kv_heads and cfg.n_kv_heads % m == 0
                              and hd_rule is None) else None
        self.rules = self.rules.with_overrides(
            q_heads=q_rule, kv_heads=kv_rule, head_dim=hd_rule)
        if cfg.sharding_overrides:
            self.rules = self.rules.with_overrides(**cfg.sharding_overrides)
        self.dtype = jnp.dtype(cfg.dtype)
        # Static window for banded local attention (train/prefill):
        # uniform-SWA archs use cfg.window; local:global stacks use the
        # local window (global layers take the full path via lax.cond).
        self._static_window = (cfg.local_window if cfg.local_global
                               else cfg.window)

    # ------------------------------------------------------------- specs
    def _attn_specs(self, lead: tuple, lead_axes: tuple) -> dict:
        c = self.cfg
        hd = c.head_dim_
        return {
            "ln": P(lead + (c.d_model,), lead_axes + ("embed",), "ones"),
            "wq": P(lead + (c.d_model, c.n_heads, hd),
                    lead_axes + ("embed_fsdp", "q_heads", "head_dim")),
            "wk": P(lead + (c.d_model, c.n_kv_heads, hd),
                    lead_axes + ("embed_fsdp", "kv_heads", "head_dim")),
            "wv": P(lead + (c.d_model, c.n_kv_heads, hd),
                    lead_axes + ("embed_fsdp", "kv_heads", "head_dim")),
            "wo": P(lead + (c.n_heads, hd, c.d_model),
                    lead_axes + ("q_heads", "head_dim", "embed_fsdp")),
        }

    def _mlp_specs(self, lead: tuple, lead_axes: tuple) -> dict:
        c = self.cfg
        return {
            "ln": P(lead + (c.d_model,), lead_axes + ("embed",), "ones"),
            "w_gate": P(lead + (c.d_model, c.d_ff),
                        lead_axes + ("embed_fsdp", "mlp")),
            "w_up": P(lead + (c.d_model, c.d_ff),
                      lead_axes + ("embed_fsdp", "mlp")),
            "w_down": P(lead + (c.d_ff, c.d_model),
                        lead_axes + ("mlp", "embed_fsdp")),
        }

    def _moe_specs(self, lead: tuple, lead_axes: tuple) -> dict:
        c, m = self.cfg, self.cfg.moe
        out = {
            "ln": P(lead + (c.d_model,), lead_axes + ("embed",), "ones"),
            "router": P(lead + (c.d_model, m.n_experts),
                        lead_axes + ("embed", None)),
            "w_gate": P(lead + (m.n_experts, c.d_model, m.d_expert),
                        lead_axes + ("experts", "embed_fsdp", "expert_out")),
            "w_up": P(lead + (m.n_experts, c.d_model, m.d_expert),
                      lead_axes + ("experts", "embed_fsdp", "expert_out")),
            "w_down": P(lead + (m.n_experts, m.d_expert, c.d_model),
                        lead_axes + ("experts", "expert_out", "embed_fsdp")),
        }
        if m.shared_expert:
            out["shared"] = {
                "w_gate": P(lead + (c.d_model, m.d_expert),
                            lead_axes + ("embed_fsdp", "mlp")),
                "w_up": P(lead + (c.d_model, m.d_expert),
                          lead_axes + ("embed_fsdp", "mlp")),
                "w_down": P(lead + (m.d_expert, c.d_model),
                            lead_axes + ("mlp", "embed_fsdp")),
            }
        return out

    def _mamba_specs(self, lead: tuple, lead_axes: tuple) -> dict:
        c = self.cfg
        di = c.ssm.expand * c.d_model
        n = c.ssm.d_state
        nh = di // c.ssm.head_dim
        return {
            "ln": P(lead + (c.d_model,), lead_axes + ("embed",), "ones"),
            "w_in": P(lead + (c.d_model, 2 * di),
                      lead_axes + ("embed_fsdp", "mlp")),
            "w_bc": P(lead + (c.d_model, 2 * n),
                      lead_axes + ("embed_fsdp", None)),
            "w_dt": P(lead + (c.d_model, nh),
                      lead_axes + ("embed_fsdp", "ssm_heads")),
            "dt_bias": P(lead + (nh,), lead_axes + ("ssm_heads",),
                         "dt_bias"),
            "a_log": P(lead + (nh,), lead_axes + ("ssm_heads",), "a_log"),
            "d_skip": P(lead + (nh,), lead_axes + ("ssm_heads",), "ones"),
            "conv_w": P(lead + (c.ssm.conv_width, di),
                        lead_axes + ("conv", "mlp")),
            "out_norm": P(lead + (di,), lead_axes + ("mlp",), "ones"),
            "w_out": P(lead + (di, c.d_model),
                       lead_axes + ("mlp", "embed_fsdp")),
        }

    def param_specs(self) -> dict:
        c = self.cfg
        specs: dict = {
            "final_norm": P((c.d_model,), ("embed",), "ones"),
            "lm_head": P((c.d_model, c.vocab), ("embed_fsdp", "vocab")),
        }
        if c.stub_frontend is None:
            specs["embed"] = P((c.vocab, c.d_model), ("vocab", "embed"),
                               "normal", 1.0)
        L = (c.n_layers,)
        LA = ("layers",)
        if c.family in ("dense", "moe", "vlm", "audio"):
            layer = {"attn": self._attn_specs(L, LA)}
            if c.moe is not None:
                layer["moe"] = self._moe_specs(L, LA)
            else:
                layer["mlp"] = self._mlp_specs(L, LA)
            specs["layers"] = layer
        elif c.family == "ssm":
            specs["layers"] = {"mamba": self._mamba_specs(L, LA)}
        elif c.family == "hybrid":
            per = c.hybrid_attn_every or 6
            n_groups, tail = divmod(c.n_layers, per)
            G = (n_groups, per)
            GA = ("groups", "stack")
            specs["groups"] = {"mamba": self._mamba_specs(G, GA)}
            if tail:
                specs["tail"] = {"mamba": self._mamba_specs((tail,),
                                                            ("layers",))}
            specs["shared_attn"] = self._attn_specs((), ())
            specs["shared_mlp"] = self._mlp_specs((), ())
        else:
            raise ValueError(c.family)
        return specs

    # ----------------------------------------------------------- helpers
    def _window_vector(self) -> jnp.ndarray:
        """Per-layer attention window (-1 = full), static metadata."""
        c = self.cfg
        if c.local_global is not None:
            per = c.local_global + 1  # N local then 1 global
            w = [(c.local_window or 1024) if (i % per) != c.local_global
                 else -1 for i in range(c.n_layers)]
        elif c.window is not None:
            w = [c.window] * c.n_layers
        else:
            w = [-1] * c.n_layers
        return jnp.asarray(w, dtype=jnp.int32)

    def _block_dense(self, x, lp, window, positions, cache, cache_pos,
                     ring=False):
        c = self.cfg
        h, new_kv = attention_block(
            rmsnorm(x, lp["attn"]["ln"], c.norm_eps),
            lp["attn"]["wq"], lp["attn"]["wk"], lp["attn"]["wv"],
            lp["attn"]["wo"], n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
            head_dim=c.head_dim_, positions=positions, window=window,
            rope_fraction=c.rope_fraction, rules=self.rules, cache=cache,
            cache_pos=cache_pos, ring=ring,
            static_local_window=self._static_window)
        x = x + h
        if c.moe is not None:
            mp = lp["moe"]
            y = moe_ffn(rmsnorm(x, mp["ln"], c.norm_eps), mp["router"],
                        mp["w_gate"], mp["w_up"], mp["w_down"],
                        top_k=c.moe.top_k,
                        capacity_factor=c.moe.capacity_factor,
                        rules=self.rules, shared=mp.get("shared"))
        else:
            mp = lp["mlp"]
            y = swiglu(rmsnorm(x, mp["ln"], c.norm_eps), mp["w_gate"],
                       mp["w_up"], mp["w_down"])
        x = x + y
        return constrain(x, ("batch", "act_seq", "embed"), self.rules), new_kv

    def _block_mamba(self, x, lp, state, return_state: bool = False):
        c = self.cfg
        y, new_state = mamba2_block(rmsnorm(x, lp["ln"], c.norm_eps),
                                    lp, c, self.rules, state=state,
                                    return_state=return_state)
        x = x + y
        return constrain(x, ("batch", "act_seq", "embed"), self.rules), new_state

    def _maybe_remat(self, f):
        if self.cfg.remat == "full":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.nothing_saveable)
        return f

    # ----------------------------------------------------- forward paths
    def _embed_in(self, params, tokens, embeds):
        c = self.cfg
        if c.stub_frontend is not None:
            assert embeds is not None, "stub frontend takes embeddings"
            x = embeds.astype(self.dtype)
        else:
            x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
            x = x * jnp.asarray(c.d_model ** 0.5, self.dtype)
        return constrain(x, ("batch", "act_seq", "embed"), self.rules)

    def _head_out(self, params, x):
        x = rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return constrain(logits, ("batch", None, "vocab"), self.rules)

    def forward_train(self, params, tokens=None, embeds=None):
        """Teacher-forced forward -> logits (B, S, V)."""
        c = self.cfg
        x = self._embed_in(params, tokens, embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        if c.family in ("dense", "moe", "vlm", "audio"):
            windows = self._window_vector()

            def body(carry, xs):
                lp, w = xs
                out, _ = self._block_dense(carry, lp, w, positions, None,
                                           None)
                return out, None

            x, _ = jax.lax.scan(self._maybe_remat(body), x,
                                (params["layers"], windows))
        elif c.family == "ssm":
            def body(carry, lp):
                out, _ = self._block_mamba(carry, lp, None)
                return out, None

            x, _ = jax.lax.scan(self._maybe_remat(body), x,
                                params["layers"]["mamba"])
        else:  # hybrid
            x = self._hybrid_forward(params, x, positions)
        return self._head_out(params, x)

    def _hybrid_forward(self, params, x, positions):
        c = self.cfg
        window = jnp.int32(c.window if c.window else -1)

        def shared_block(h):
            out, _ = attention_block(
                rmsnorm(h, params["shared_attn"]["ln"], c.norm_eps),
                params["shared_attn"]["wq"], params["shared_attn"]["wk"],
                params["shared_attn"]["wv"], params["shared_attn"]["wo"],
                n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
                head_dim=c.head_dim_, positions=positions, window=window,
                rope_fraction=c.rope_fraction, rules=self.rules)
            h = h + out
            mp = params["shared_mlp"]
            h = h + swiglu(rmsnorm(h, mp["ln"], c.norm_eps), mp["w_gate"],
                           mp["w_up"], mp["w_down"])
            return h

        def group_body(carry, gp):
            def inner(carry2, lp):
                out, _ = self._block_mamba(carry2, lp, None)
                return out, None

            h, _ = jax.lax.scan(inner, carry, gp["mamba"])
            return shared_block(h), None

        x, _ = jax.lax.scan(self._maybe_remat(group_body), x,
                            params["groups"])
        if "tail" in params:
            def inner(carry2, lp):
                out, _ = self._block_mamba(carry2, lp, None)
                return out, None

            x, _ = jax.lax.scan(self._maybe_remat(inner), x,
                                params["tail"]["mamba"])
        return x

    # ------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int, dtype=None) -> dict:
        """Abstract cache shapes (used by dry-run input_specs too)."""
        c = self.cfg
        dtype = dtype or self.dtype
        hd = c.head_dim_
        if c.family in ("dense", "moe", "vlm", "audio"):
            shape = (c.n_layers, batch, max_len, c.n_kv_heads, hd)
            return {"k": jnp.zeros(shape, dtype),
                    "v": jnp.zeros(shape, dtype)}
        di = c.ssm.expand * c.d_model
        nh = di // c.ssm.head_dim
        if c.family == "ssm":
            return {
                "h": jnp.zeros((c.n_layers, batch, nh, c.ssm.d_state,
                                c.ssm.head_dim), jnp.float32),
                "conv": jnp.zeros((c.n_layers, batch,
                                   c.ssm.conv_width - 1, di), dtype),
            }
        # hybrid: mamba states per layer + shared-attn KV per application.
        per = c.hybrid_attn_every or 6
        n_groups, tail = divmod(c.n_layers, per)
        cache = {
            "gh": jnp.zeros((n_groups, per, batch, nh, c.ssm.d_state,
                             c.ssm.head_dim), jnp.float32),
            "gconv": jnp.zeros((n_groups, per, batch,
                                c.ssm.conv_width - 1, di), dtype),
            "ak": jnp.zeros((n_groups, batch, max_len, c.n_kv_heads, hd),
                            dtype),
            "av": jnp.zeros((n_groups, batch, max_len, c.n_kv_heads, hd),
                            dtype),
        }
        if tail:
            cache["th"] = jnp.zeros((tail, batch, nh, c.ssm.d_state,
                                     c.ssm.head_dim), jnp.float32)
            cache["tconv"] = jnp.zeros((tail, batch, c.ssm.conv_width - 1,
                                        di), dtype)
        return cache

    def cache_logical_axes(self) -> dict:
        c = self.cfg
        kv = ("layers", "cache_batch", "cache_seq", "cache_heads",
              "cache_dim")
        if c.family in ("dense", "moe", "vlm", "audio"):
            return {"k": kv, "v": kv}
        sh = ("layers", "cache_batch", "ssm_heads", None, None)
        cv = ("layers", "cache_batch", None, "mlp")
        if c.family == "ssm":
            return {"h": sh, "conv": cv}
        out = {"gh": ("groups",) + sh, "gconv": ("groups",) + cv,
               "ak": ("groups",) + kv[1:], "av": ("groups",) + kv[1:]}
        per = c.hybrid_attn_every or 6
        if c.n_layers % per:
            out["th"] = sh
            out["tconv"] = cv
        return out

    def prefill(self, params, tokens=None, embeds=None):
        """Forward + emit a KV/state cache sized to the input length."""
        c = self.cfg
        x = self._embed_in(params, tokens, embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        if c.family in ("dense", "moe", "vlm", "audio"):
            windows = self._window_vector()

            def body(carry, xs):
                lp, w = xs
                out, kv = self._block_dense(carry, lp, w, positions, None,
                                            None)
                return out, kv

            x, kvs = jax.lax.scan(self._maybe_remat(body), x,
                                  (params["layers"], windows))
            cache = {"k": kvs[0], "v": kvs[1]}
            return self._head_out(params, x[:, -1:]), cache
        if c.family == "ssm":
            def body(carry, lp):
                out, st = self._block_mamba(carry, lp, None,
                                            return_state=True)
                return out, (st["h"], st["conv"])

            x, (hs, convs) = jax.lax.scan(self._maybe_remat(body), x,
                                          params["layers"]["mamba"])
            return self._head_out(params, x[:, -1:]), \
                {"h": hs, "conv": convs}
        # hybrid: mamba states + shared-attn KV per group application.
        window = jnp.int32(c.window if c.window else -1)

        def group_body(carry, gp):
            def inner(carry2, lp):
                out, st = self._block_mamba(carry2, lp, None,
                                            return_state=True)
                return out, (st["h"], st["conv"])

            h, (hs, convs) = jax.lax.scan(inner, carry, gp["mamba"])
            out, kv = attention_block(
                rmsnorm(h, params["shared_attn"]["ln"], c.norm_eps),
                params["shared_attn"]["wq"], params["shared_attn"]["wk"],
                params["shared_attn"]["wv"], params["shared_attn"]["wo"],
                n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
                head_dim=c.head_dim_, positions=positions, window=window,
                rope_fraction=c.rope_fraction, rules=self.rules)
            h = h + out
            mp = params["shared_mlp"]
            h = h + swiglu(rmsnorm(h, mp["ln"], c.norm_eps), mp["w_gate"],
                           mp["w_up"], mp["w_down"])
            return h, (hs, convs, kv[0], kv[1])

        x, (ghs, gconvs, aks, avs) = jax.lax.scan(
            self._maybe_remat(group_body), x, params["groups"])
        cache = {"gh": ghs, "gconv": gconvs, "ak": aks, "av": avs}
        if "tail" in params:
            def inner(carry2, lp):
                out, st = self._block_mamba(carry2, lp, None,
                                            return_state=True)
                return out, (st["h"], st["conv"])

            x, (ths, tconvs) = jax.lax.scan(self._maybe_remat(inner), x,
                                            params["tail"]["mamba"])
            cache["th"] = ths
            cache["tconv"] = tconvs
        return self._head_out(params, x[:, -1:]), cache

    def decode_step(self, params, token, cache, pos, ring: bool = False):
        """One decode step. token: (B, 1) int32 (or (B,1,D) embeds for stub
        frontends); pos: scalar int32 current position.  ``ring=True``
        treats attention caches as circular window buffers (SWA long
        decode)."""
        c = self.cfg
        if c.stub_frontend is not None:
            x = self._embed_in(params, None, token)
        else:
            x = self._embed_in(params, token, None)
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, dtype=jnp.int32)
        if c.family in ("dense", "moe", "vlm", "audio"):
            windows = self._window_vector()

            def body(carry, xs):
                lp, w, ck, cv = xs
                out, new_kv = self._block_dense(carry, lp, w, positions,
                                                {"k": ck, "v": cv}, pos,
                                                ring=ring)
                return out, (new_kv["k"], new_kv["v"])

            x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], windows,
                                                 cache["k"], cache["v"]))
            return self._head_out(params, x), {"k": nk, "v": nv}
        if c.family == "ssm":
            def body(carry, xs):
                lp, h, conv = xs
                out, st = self._block_mamba(carry, lp,
                                            {"h": h, "conv": conv})
                return out, (st["h"], st["conv"])

            x, (nh_, nc_) = jax.lax.scan(body, x,
                                         (params["layers"]["mamba"],
                                          cache["h"], cache["conv"]))
            return self._head_out(params, x), {"h": nh_, "conv": nc_}
        # hybrid
        return self._hybrid_decode(params, x, cache, pos, positions, ring)

    def _hybrid_decode(self, params, x, cache, pos, positions, ring=False):
        c = self.cfg
        window = jnp.int32(c.window if c.window else -1)

        def group_body(carry, xs):
            gp, gh, gconv, ak, av = xs

            def inner(carry2, ys):
                lp, h, conv = ys
                out, st = self._block_mamba(carry2, lp,
                                            {"h": h, "conv": conv})
                return out, (st["h"], st["conv"])

            h, (nh_, nc_) = jax.lax.scan(inner, carry,
                                         (gp["mamba"], gh, gconv))
            out, new_kv = attention_block(
                rmsnorm(h, params["shared_attn"]["ln"], c.norm_eps),
                params["shared_attn"]["wq"], params["shared_attn"]["wk"],
                params["shared_attn"]["wv"], params["shared_attn"]["wo"],
                n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
                head_dim=c.head_dim_, positions=positions, window=window,
                rope_fraction=c.rope_fraction, rules=self.rules,
                cache={"k": ak, "v": av}, cache_pos=pos, ring=ring)
            h = h + out
            mp = params["shared_mlp"]
            h = h + swiglu(rmsnorm(h, mp["ln"], c.norm_eps), mp["w_gate"],
                           mp["w_up"], mp["w_down"])
            return h, (nh_, nc_, new_kv["k"], new_kv["v"])

        x, (ngh, ngconv, nak, nav) = jax.lax.scan(
            group_body, x, (params["groups"], cache["gh"], cache["gconv"],
                            cache["ak"], cache["av"]))
        new_cache = {"gh": ngh, "gconv": ngconv, "ak": nak, "av": nav}
        if "tail" in params:
            def inner(carry2, ys):
                lp, h, conv = ys
                out, st = self._block_mamba(carry2, lp,
                                            {"h": h, "conv": conv})
                return out, (st["h"], st["conv"])

            x, (nth, ntconv) = jax.lax.scan(
                inner, x, (params["tail"]["mamba"], cache["th"],
                           cache["tconv"]))
            new_cache["th"] = nth
            new_cache["tconv"] = ntconv
        return self._head_out(params, x), new_cache
