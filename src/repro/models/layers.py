"""Shared neural layers: RMSNorm, rotary embeddings, SwiGLU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, *, base: float = 10000.0, fraction: float = 1.0):
    """Rotary embedding on the leading ``fraction`` of head dims.

    x: (B, S, H, D); positions: (B, S) int32.  chatglm3 uses fraction=0.5
    (2-d RoPE on half the dims); others use 1.0.
    """
    b, s, h, d = x.shape
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    half = d_rot // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]  # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = xr[..., :half].astype(jnp.float32), \
        xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0):
    """Mean CE over tokens; logits (..., V) in any dtype, f32 math.

    The label pick uses an iota-compare-select (fuses under vocab-sharded
    logits; take_along_axis on a sharded dim lowers to expensive gathers).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * (lse ** 2).mean()
    return loss
