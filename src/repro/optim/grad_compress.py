"""Int8 gradient compression with error feedback for cross-pod reduction.

At 1000+ node scale the pod-to-pod (DCI) axis is the slow hop; reducing
bf16/f32 gradients across it wastes 2-4x bandwidth.  This implements the
standard recipe: per-tensor-block scale -> int8 quantize -> all-reduce the
int8 payload (here: psum of dequantized values inside shard_map, modelling
the wire format) -> dequantize, with the quantization residual fed back
into the next step (error feedback keeps SGD convergence; Karimireddy et
al. 2019).

``compressed_psum`` is numerically validated against exact psum in tests;
``wrap_grads_with_compression`` composes it into a train step over the
'pod' mesh axis only (intra-pod ICI reductions stay exact bf16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PSpec

BLOCK = 256


def _quantize(x32, block=BLOCK):
    flat = x32.reshape(-1)
    pad = -flat.shape[0] % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def quantize_roundtrip(x):
    """Quantize + dequantize (the wire transform); returns (y, residual)."""
    x32 = x.astype(jnp.float32)
    q, scale, pad = _quantize(x32)
    y = _dequantize(q, scale, pad, x32.shape)
    return y, x32 - y


def compressed_psum(grads, errors, axis_name: str):
    """psum over ``axis_name`` with int8 wire format + error feedback.

    grads/errors: pytrees (inside shard_map, with ``axis_name`` bound).
    Returns (reduced_grads, new_errors)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        y, resid = quantize_roundtrip(g32)
        red = jax.lax.psum(y, axis_name) / n
        return red, resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def make_compressed_crosspod_reduce(mesh, param_specs_tree):
    """Returns reduce_fn(grads, errors) -> (grads, errors) that averages
    gradients across the 'pod' axis in int8-with-error-feedback, leaving
    intra-pod axes untouched (they reduce exactly during backward)."""
    if "pod" not in mesh.axis_names:
        return None

    def reduce_fn(grads, errors):
        specs = jax.tree.map(lambda _: PSpec(), grads)  # per-leaf full

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(specs, specs), out_specs=(specs, specs),
            check_rep=False)
        def inner(g, e):
            return compressed_psum(g, e, "pod")

        return inner(grads, errors)

    return reduce_fn
