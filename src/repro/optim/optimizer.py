"""Optimizers: AdamW and Adafactor (factored second moments), pure JAX.

Adafactor exists because the 1T-param MoE (kimi-k2) cannot hold AdamW's
fp32 m/v (14 TB on 512 chips); factored row/col second-moment statistics
cut optimizer state to ~(r+c) per matrix (Shazeer & Stern, 2018).  Both
optimizers are pytree-shaped like the params, so they shard with the same
logical rules (optimizer state inherits the param sharding).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), \
        norm


# ------------------------------------------------------------------ AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, params, grads, state):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, mu, nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}


# -------------------------------------------------------------- Adafactor
def adafactor_init(params):
    def st(p):
        if p.ndim >= 2:
            # factor the two largest (trailing) dims; leading dims (layer
            # stacks) are batched.
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(st, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, params, grads, state):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, f):
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * f["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            v = (vr[..., None] * vc[..., None, :]) / \
                jnp.maximum(denom[..., None], 1e-30)
            newf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            newf = {"v": v}
        update = g / jnp.sqrt(v + 1e-30)
        # Update clipping (RMS <= 1) as in the paper.
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        delta = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), newf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_f = treedef.flatten_up_to(state["f"])
    out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"f": treedef.unflatten([o[1] for o in out]), "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, partial(adafactor_update, cfg)
    raise ValueError(cfg.name)
