from .grad_compress import (compressed_psum, make_compressed_crosspod_reduce,
                            quantize_roundtrip)
from .optimizer import (OptimizerConfig, adafactor_init, adafactor_update,
                        adamw_init, adamw_update, clip_by_global_norm,
                        global_norm, lr_schedule, make_optimizer)

__all__ = ["OptimizerConfig", "adafactor_init", "adafactor_update",
           "adamw_init", "adamw_update", "clip_by_global_norm",
           "global_norm", "lr_schedule", "make_optimizer",
           "compressed_psum", "make_compressed_crosspod_reduce",
           "quantize_roundtrip"]
