"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results/*.json.

    PYTHONPATH=src python -m repro.analysis.report results/ > tables.md

With ``--trace trace.json`` (a Chrome trace exported by
``repro.obs.Tracer.export_chrome``) a §Trace section is appended:
per-batch critical path, per-shard busy/stall (and each shard's share
of the pipeline's total stall — the modeled-vs-wall gap), and kernel
launches per lookup.
"""

from __future__ import annotations

import json
import os
import sys

from ..configs import ARCHS, SHAPES, get_config


def load(results_dir: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compile | bytes/device (arg+tmp) | "
           "fits 16G | HLO GFLOPs/dev | collective bytes | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mem = r.get("memory_per_device", {})
        tot = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        colls = " ".join(f"{k.split('-')[0][:3]}+{k.split('-')[1][:4]}:"
                         f"{fmt_b(v)}" if "-" in k else f"{k}:{fmt_b(v)}"
                         for k, v in sorted(r["coll_by_op"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {fmt_b(tot)} | "
            f"{'Y' if tot < 16e9 else '**N**'} | "
            f"{r['hlo_flops'] / 1e9:.1f} | {fmt_b(r['coll_bytes'])} | "
            f"{colls} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | roofline frac | useful FLOPs ratio | "
           "what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "single":
            continue  # roofline table is single-pod per the brief
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {hint} |")
    return "\n".join(out)


def _hint(r: dict) -> str:
    b = r["bottleneck"]
    if b == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return ("decode is weight+cache-streaming bound: batch more "
                    "queries per weight read, or quantize KV")
        return ("reduce rematerialized bytes: coarser remat policy, fused "
                "loss, smaller logits footprint")
    if b == "collective":
        top = max(r["coll_by_op"], key=r["coll_by_op"].get) \
            if r["coll_by_op"] else "all-reduce"
        return (f"dominant {top}: reshard to cut it (e.g. reduce-scatter "
                "grads, keep activations sharded through the stack)")
    return "compute-bound: at the roofline; only kernel-level wins remain"


def trace_report(events: list[dict]) -> dict:
    """Distill a Chrome trace (``Tracer.chrome_events`` output, or the
    JSON file's ``traceEvents`` list) into the pipeline's span-level
    story:

    - ``batches``: per submitted batch, the execution window across its
      shard plans and the critical-path shard (the slowest ``shard.plan``
      span — the one the collect actually waited on).
    - ``shards``: per shard, total busy vs stall microseconds and
      ``stall_share`` — this shard's fraction of the pipeline's total
      idle time, i.e. who owns the modeled-vs-wall gap.
    - ``wall_us`` (submit->collect extent), ``modeled_us`` (busiest
      shard's total busy time = the perfect-overlap lower bound) and
      ``gap_us`` = wall - modeled.
    - ``kernel_launches`` / ``launches_per_lookup``: fused-cascade
      efficiency — how many device launches each point lookup cost.
    """
    xs = [e for e in events if e.get("ph") == "X"]
    plans = [e for e in xs if e["name"] == "shard.plan"]
    by_batch: dict[int, list[dict]] = {}
    for e in plans:
        by_batch.setdefault(e.get("args", {}).get("batch", -1),
                            []).append(e)
    batches = []
    busy: dict[int, float] = {}
    stall: dict[int, float] = {}
    for b, evs in sorted(by_batch.items()):
        w0 = min(e["ts"] for e in evs)
        w1 = max(e["ts"] + e["dur"] for e in evs)
        crit = max(evs, key=lambda e: e["dur"])
        for e in evs:
            s = e["args"]["shard"]
            busy[s] = busy.get(s, 0.0) + e["dur"]
            stall[s] = stall.get(s, 0.0) + (w1 - w0) - e["dur"]
        batches.append({"batch": b, "window_us": w1 - w0,
                        "critical_shard": crit["args"]["shard"],
                        "critical_us": crit["dur"],
                        "n_shards": len(evs)})
    outer = [e for e in xs
             if e["name"] in ("engine.submit", "engine.collect")] or plans
    wall = (max(e["ts"] + e["dur"] for e in outer)
            - min(e["ts"] for e in outer)) if outer else 0.0
    modeled = max(busy.values()) if busy else 0.0
    tot_stall = sum(stall.values())
    shards = {s: {"busy_us": busy[s], "stall_us": stall[s],
                  "stall_share": stall[s] / tot_stall if tot_stall else 0.0}
              for s in sorted(busy)}
    launches = sum(1 for e in xs if e["name"].startswith("kernel."))
    lookups = sum(e.get("args", {}).get("n", 0)
                  for e in xs if e["name"] == "shard.get")
    return {"batches": batches, "shards": shards, "wall_us": wall,
            "modeled_us": modeled, "gap_us": max(0.0, wall - modeled),
            "kernel_launches": launches, "lookups": lookups,
            "launches_per_lookup": launches / lookups if lookups else 0.0}


def trace_tables(rep: dict) -> str:
    out = [f"Wall {rep['wall_us']:.0f}us, perfect-overlap bound "
           f"{rep['modeled_us']:.0f}us, gap {rep['gap_us']:.0f}us; "
           f"{rep['kernel_launches']} kernel launches / "
           f"{rep['lookups']} lookups = "
           f"{rep['launches_per_lookup']:.4f} launches/lookup.", "",
           "| shard | busy | stall | stall share of gap |",
           "|---|---|---|---|"]
    for s, r in rep["shards"].items():
        out.append(f"| {s} | {fmt_s(r['busy_us'] * 1e-6)} | "
                   f"{fmt_s(r['stall_us'] * 1e-6)} | "
                   f"{r['stall_share']:.1%} |")
    out += ["", "| batch | window | critical shard | critical path | "
            "shards |", "|---|---|---|---|---|"]
    for b in rep["batches"][:20]:
        out.append(f"| {b['batch']} | {fmt_s(b['window_us'] * 1e-6)} | "
                   f"{b['critical_shard']} | "
                   f"{fmt_s(b['critical_us'] * 1e-6)} | {b['n_shards']} |")
    if len(rep["batches"]) > 20:
        out.append(f"| ... {len(rep['batches']) - 20} more batches |  |  "
                   "|  |  |")
    return "\n".join(out)


def load_trace(path: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    return data["traceEvents"] if isinstance(data, dict) else data


def main():
    argv = list(sys.argv[1:])
    trace_path = None
    if "--trace" in argv:
        i = argv.index("--trace")
        trace_path = argv[i + 1]
        del argv[i:i + 2]
    results_dir = argv[0] if argv else "results"
    if trace_path is not None:
        print("## §Trace (spans from submit to kernel launch)\n")
        print(trace_tables(trace_report(load_trace(trace_path))))
        if not os.path.isdir(results_dir):
            return
        print()
    rows = load(results_dir)
    key = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    ordered = [key[k] for k in sorted(key)]
    print("## §Dry-run (all cells, both meshes)\n")
    print(dryrun_table(ordered))
    skips = [(a, s) for a in ARCHS for s in SHAPES
             if (a, s, "single") not in key]
    print("\nSkipped cells (full attention x long_500k, per DESIGN.md): "
          + ", ".join(f"{a}/{s}" for a, s in skips))
    print("\n## §Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(ordered))


if __name__ == "__main__":
    main()
