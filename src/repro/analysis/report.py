"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results/*.json.

    PYTHONPATH=src python -m repro.analysis.report results/ > tables.md
"""

from __future__ import annotations

import json
import os
import sys

from ..configs import ARCHS, SHAPES, get_config


def load(results_dir: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compile | bytes/device (arg+tmp) | "
           "fits 16G | HLO GFLOPs/dev | collective bytes | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        mem = r.get("memory_per_device", {})
        tot = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        colls = " ".join(f"{k.split('-')[0][:3]}+{k.split('-')[1][:4]}:"
                         f"{fmt_b(v)}" if "-" in k else f"{k}:{fmt_b(v)}"
                         for k, v in sorted(r["coll_by_op"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {fmt_b(tot)} | "
            f"{'Y' if tot < 16e9 else '**N**'} | "
            f"{r['hlo_flops'] / 1e9:.1f} | {fmt_b(r['coll_bytes'])} | "
            f"{colls} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | roofline frac | useful FLOPs ratio | "
           "what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != "single":
            continue  # roofline table is single-pod per the brief
        hint = _hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {hint} |")
    return "\n".join(out)


def _hint(r: dict) -> str:
    b = r["bottleneck"]
    if b == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return ("decode is weight+cache-streaming bound: batch more "
                    "queries per weight read, or quantize KV")
        return ("reduce rematerialized bytes: coarser remat policy, fused "
                "loss, smaller logits footprint")
    if b == "collective":
        top = max(r["coll_by_op"], key=r["coll_by_op"].get) \
            if r["coll_by_op"] else "all-reduce"
        return (f"dominant {top}: reshard to cut it (e.g. reduce-scatter "
                "grads, keep activations sharded through the stack)")
    return "compute-bound: at the roofline; only kernel-level wins remain"


def main():
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results"
    rows = load(results_dir)
    key = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    ordered = [key[k] for k in sorted(key)]
    print("## §Dry-run (all cells, both meshes)\n")
    print(dryrun_table(ordered))
    skips = [(a, s) for a in ARCHS for s in SHAPES
             if (a, s, "single") not in key]
    print("\nSkipped cells (full attention x long_500k, per DESIGN.md): "
          + ", ".join(f"{a}/{s}" for a, s in skips))
    print("\n## §Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(ordered))


if __name__ == "__main__":
    main()
