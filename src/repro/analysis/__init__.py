from .roofline import RooflineReport, analyze_compiled, collective_bytes

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes"]
