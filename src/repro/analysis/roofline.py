"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (v5e constants):

  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = collective_bytes / (chips * 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the partitioned HLO text, summing operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, and multiply collectives inside while-loop bodies
(scan-over-layers) by their trip counts, recovered from each loop
condition's comparison constant.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_COMP_RE = re.compile(r"^\s*%?(\S+?)\s+\(.*?\)\s*->", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo: str) -> dict[str, str]:
    """Split HLO text into {computation_name: body}.

    Computation headers sit at column 0 as ``[ENTRY ]%name (params) ->``;
    params may contain NESTED parens (tuple-typed while-loop state), so
    the params blob is matched greedily up to the ``->``."""
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", line)
        if m and not line.startswith(" "):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(2)
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _while_trip_counts(hlo: str, comps: dict[str, str]) -> dict[str, int]:
    """body_computation_name -> trip count (from the condition's compare
    against a constant; defaults to 1 when unrecoverable)."""
    trips: dict[str, int] = {}
    for m in re.finditer(
            r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?"
            r"([\w\.\-]+)", hlo):
        cond, body = m.group(1), m.group(2)
        count = 1
        cbody = comps.get(cond, "")
        consts = re.findall(r"constant\((\d+)\)", cbody)
        if consts:
            count = max(int(c) for c in consts)
        trips[body] = max(trips.get(body, 1), count)
    return trips


def collective_bytes(hlo: str) -> tuple[int, dict]:
    """Total collective operand bytes (loop-aware) + per-op breakdown."""
    comps = _split_computations(hlo)
    trips = _while_trip_counts(hlo, comps)
    total = 0
    by_op: dict[str, int] = {}
    for name, body in comps.items():
        mult = trips.get(name, 1)
        for m in _COLL_RE.finditer(body):
            dtype, dims, op = m.group(1), m.group(2), m.group(3)
            b = _shape_bytes(dtype, dims) * mult
            total += b
            by_op[op] = by_op.get(op, 0) + b
    return total, by_op


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_op: dict = field(default_factory=dict)
    model_flops: float = 0.0
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9
    memory_per_device: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * self.ici_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term: 1.0 == compute-bound at the roofline."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste detector)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "coll_bytes": self.coll_bytes,
            "coll_by_op": self.coll_by_op, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
            "memory_per_device": self.memory_per_device,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float) -> RooflineReport:
    # cost_analysis() on the partitioned executable reports PER-DEVICE
    # flops/bytes; the report stores GLOBAL quantities (x chips) so the
    # brief's term formulas (global / (chips * rate)) apply directly.
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0)) * chips
    byts = float(ca.get("bytes accessed", 0.0)) * chips
    try:
        hlo = compiled.as_text()
        coll, by_op = collective_bytes(hlo)
        coll *= chips  # per-device operand bytes -> global
    except Exception:
        coll, by_op = 0, {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    except Exception:
        pass
    return RooflineReport(arch=arch, shape=shape, mesh=mesh_name,
                          chips=chips, hlo_flops=flops, hlo_bytes=byts,
                          coll_bytes=float(coll), coll_by_op=by_op,
                          model_flops=model_flops, memory_per_device=mem)
