"""Durability subsystem: columnar WAL, level manifest, snapshots,
crash-consistent recovery.

Turn it on by pointing ``EngineConfig.wal_dir`` at a directory; reopen
the directory after a crash (or clean ``close()``) with ``recover``::

    cfg = EngineConfig(wal_dir="/data/store", fsync="batch")
    with Engine(4, config=cfg) as eng:
        eng.put_batch(keys, vals)          # acked only after WAL append
    eng = recover("/data/store")           # byte-identical store

See ``docs/DURABILITY.md`` for the frame format, fsync policies, and
the recovery sequence.
"""

from .atomic import (atomic_publish_dir, atomic_write_bytes,
                     atomic_write_json, clear_stale_tmp, fsync_dir,
                     keep_last_k, list_versions, versioned_name)
from .manifest import (LevelManifest, configs_from_doc, describe_tree,
                       engine_config_doc, structure_fingerprint)
from .recovery import recover, replay_frame
from .snapshot import (latest_snapshot, load_snapshot, save_snapshot,
                       take_snapshot)
from .wal import (FRAME_BATCH, FRAME_FLUSH, FSYNC_POLICIES, WalFrame,
                  WalReader, WalWriter, decode_payload, encode_frame,
                  wal_has_frames, wal_shards)

__all__ = [
    "atomic_publish_dir", "atomic_write_bytes", "atomic_write_json",
    "clear_stale_tmp", "fsync_dir", "keep_last_k", "list_versions",
    "versioned_name",
    "LevelManifest", "configs_from_doc", "describe_tree",
    "engine_config_doc", "structure_fingerprint",
    "recover", "replay_frame",
    "latest_snapshot", "load_snapshot", "save_snapshot", "take_snapshot",
    "FRAME_BATCH", "FRAME_FLUSH", "FSYNC_POLICIES", "WalFrame",
    "WalReader", "WalWriter", "decode_payload", "encode_frame",
    "wal_has_frames", "wal_shards",
]
