"""Full-store snapshots for snapshot + WAL-tail restarts.

A snapshot is an atomic, versioned directory (``snap-<n>/``: one npz per
shard + ``meta.json``) holding everything needed to reconstruct each
shard's LSMTree bit-for-bit: memtable entries, LRR buffers, every
SSTable level's arrays *plus its Bloom seed* (the filter rebuilds
deterministically from keys + seed), range-tombstone blocks, sequence
counters, and — the GLORAN twist — the staging buffer's raw records, the
DR-tree index levels, the index epoch/GC floor, and the full EVE chain
(per-RAE capacity/seed/count/seq-window + filter words), so recovered
stores reproduce exactly the same lookup validity verdicts.

``meta.json`` records the per-shard WAL frame positions at snapshot time
(and the manifest version), so a restart loads the snapshot and replays
only the WAL *tail* — recovery cost proportional to work since the last
snapshot, not store size.  Publication is write-tmp-then-rename
(``durable.atomic``) with keep-last-k GC, same as checkpoints.
"""

from __future__ import annotations

import json
import os

import numpy as np

from ..core.areas import AreaSet
from ..core.eve import EVE, RAE, RAEConfig
from ..lsm.sstable import RangeTombstoneBlock, SSTable
from .atomic import (atomic_publish_dir, clear_stale_tmp, fsync_dir,
                     keep_last_k, list_versions, versioned_name)

PREFIX = "snap-"


def latest_snapshot(directory: str) -> str | None:
    """Path of the newest published snapshot under ``directory``."""
    if not os.path.isdir(directory):
        return None
    versions = list_versions(directory, PREFIX)
    if not versions:
        return None
    return os.path.join(directory, versioned_name(PREFIX, versions[-1]))


def _shard_arrays(tree) -> tuple[dict, dict]:
    """(npz arrays, JSON meta) capturing one shard's tree exactly."""
    arrays: dict = {}
    meta: dict = {
        "seq": int(tree.seq),
        "sstable_seed": int(tree._sstable_seed),
        "strategy": tree.strategy,
        "levels": [],
        "level_rts": len(tree.level_rts),
    }
    if tree.mem:
        rows = np.array([(k, s, t, v)
                         for k, (s, t, v) in tree.mem.items()],
                        dtype=np.uint64)
    else:
        rows = np.zeros((0, 4), dtype=np.uint64)
    arrays["mem"] = rows
    arrays["mem_rts"] = (np.array(tree.mem_rts, dtype=np.uint64)
                         if tree.mem_rts
                         else np.zeros((0, 3), dtype=np.uint64))
    for i, lvl in enumerate(tree.levels):
        if lvl is None:
            meta["levels"].append(None)
            continue
        meta["levels"].append({"seed": int(lvl.seed)})
        arrays[f"lvl{i}_keys"] = lvl.keys
        arrays[f"lvl{i}_seqs"] = lvl.seqs
        arrays[f"lvl{i}_types"] = lvl.types
        arrays[f"lvl{i}_vals"] = lvl.vals
    for i, rtb in enumerate(tree.level_rts):
        arrays[f"rt{i}_starts"] = rtb.starts
        arrays[f"rt{i}_ends"] = rtb.ends
        arrays[f"rt{i}_seqs"] = rtb.seqs
    if tree.gloran is not None:
        g = tree.gloran
        idx = g.index
        if not hasattr(idx, "_make_drtree"):
            raise ValueError(
                "snapshots support the DR-tree GLORAN index only "
                "(GLORAN0's R-tree levels recover via WAL replay)")
        meta["gloran"] = {
            "gc_floor": int(g.gc_floor),
            "num_range_deletes": int(g.num_range_deletes),
            "epoch": int(getattr(idx, "epoch", 0)),
            "records_inserted": int(getattr(idx, "records_inserted", 0)),
            "index_levels": [lvl is not None
                             for lvl in getattr(idx, "levels", [])],
            "eve": None,
        }
        stg = idx.buffer.extract_all()
        arrays["stg_lo"], arrays["stg_hi"] = stg.lo, stg.hi
        arrays["stg_smin"], arrays["stg_smax"] = stg.smin, stg.smax
        for i, lvl in enumerate(getattr(idx, "levels", [])):
            if lvl is None:
                continue
            a = lvl.areas
            arrays[f"gl{i}_lo"], arrays[f"gl{i}_hi"] = a.lo, a.hi
            arrays[f"gl{i}_smin"], arrays[f"gl{i}_smax"] = a.smin, a.smax
        if g.eve is not None:
            # RAE seeds are assigned deterministically by chain position
            # (EVE._next_seed starts at 1 and increments per RAE), so
            # replaying _new_rae with the saved capacities reproduces
            # them; capacity/count/seq-window are captured explicitly.
            metas = []
            for j, rae in enumerate(g.eve.chain):
                arrays[f"eve{j}_words"] = rae.bloom.words
                metas.append({
                    "capacity": int(rae.config.capacity),
                    "count": int(rae.count),
                    "min_seq": rae.min_seq,
                    "max_seq": int(rae.max_seq),
                })
            meta["gloran"]["eve"] = {
                "next_seed": int(g.eve._next_seed),
                "raes": metas,
            }
    return arrays, meta


def save_snapshot(engine, directory: str, *, keep: int = 2) -> str:
    """Publish one atomic snapshot of a drained engine; returns its
    path.  Call via ``repro.durable.take_snapshot`` (which drains and
    records the manifest pointer)."""
    os.makedirs(directory, exist_ok=True)
    versions = list_versions(directory, PREFIX)
    version = (versions[-1] + 1) if versions else 1
    final = os.path.join(directory, versioned_name(PREFIX, version))
    tmp = final + ".tmp"
    clear_stale_tmp(tmp)
    os.makedirs(tmp)
    wal_frames = {
        s: (sh.wal.frames_appended if getattr(sh, "wal", None) else 0)
        for s, sh in enumerate(engine.shards)}
    meta = {
        "version": version,
        "num_shards": engine.num_shards,
        "wal_frames": {str(s): n for s, n in wal_frames.items()},
        "manifest_version": getattr(
            getattr(engine, "manifest", None), "version", None),
        "shards": [],
    }
    for s, sh in enumerate(engine.shards):
        arrays, shard_meta = _shard_arrays(sh.tree)
        np.savez(os.path.join(tmp, f"shard-{s:03d}.npz"), **arrays)
        meta["shards"].append(shard_meta)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    atomic_publish_dir(tmp, final)
    fsync_dir(directory)
    keep_last_k(directory, PREFIX, keep)
    return final


def take_snapshot(engine, directory: str | None = None, *,
                  keep: int = 2) -> str:
    """Drain, publish a snapshot, and point the manifest at it (with the
    per-shard WAL positions it covers) so the next restart replays only
    the tail.  ``directory`` defaults to ``<wal_dir>/snapshots``."""
    if getattr(engine, "procs", 0):
        raise RuntimeError(
            "take_snapshot needs direct tree access, but this engine's "
            "shards live in worker processes (EngineConfig.procs / "
            "REPRO_ENGINE_PROCS); procs-mode stores recover by full WAL "
            "replay — snapshot from an in-process (procs=0) engine")
    engine.drain()
    if directory is None:
        if not engine.wal_dir:
            raise ValueError("no wal_dir on this engine; pass an "
                             "explicit snapshot directory")
        directory = os.path.join(engine.wal_dir, "snapshots")
    path = save_snapshot(engine, directory, keep=keep)
    if engine.manifest is not None:
        frames = {
            s: (sh.wal.frames_appended if sh.wal is not None else 0)
            for s, sh in enumerate(engine.shards)}
        engine.manifest.record_snapshot(os.path.basename(path), frames)
    return path


def _restore_tree(tree, arrays, meta: dict) -> None:
    """Load one shard's saved state into a freshly constructed tree."""
    cfg = tree.config
    mem = arrays["mem"]
    tree.mem = {int(k): (int(s), int(t), int(v))
                for k, s, t, v in mem.tolist()}
    tree._mem_snap = None
    tree.mem_rts = [tuple(int(x) for x in row)
                    for row in arrays["mem_rts"].tolist()]
    tree.seq = int(meta["seq"])
    tree._sstable_seed = int(meta["sstable_seed"])
    tree.levels = []
    for i, lm in enumerate(meta["levels"]):
        if lm is None:
            tree.levels.append(None)
            continue
        tree.levels.append(SSTable(
            arrays[f"lvl{i}_keys"], arrays[f"lvl{i}_seqs"],
            arrays[f"lvl{i}_types"], arrays[f"lvl{i}_vals"], cfg,
            seed=int(lm["seed"])))
    tree.level_rts = [
        RangeTombstoneBlock(arrays[f"rt{i}_starts"],
                            arrays[f"rt{i}_ends"],
                            arrays[f"rt{i}_seqs"], cfg)
        for i in range(int(meta["level_rts"]))]
    gm = meta.get("gloran")
    if gm is None or tree.gloran is None:
        return
    g = tree.gloran
    idx = g.index
    g.gc_floor = int(gm["gc_floor"])
    g.num_range_deletes = int(gm["num_range_deletes"])
    idx.buffer.clear()
    if len(arrays["stg_lo"]):
        idx.buffer.insert_batch(arrays["stg_lo"], arrays["stg_hi"],
                                arrays["stg_smin"], arrays["stg_smax"])
    idx.levels = []
    for i, present in enumerate(gm["index_levels"]):
        if not present:
            idx.levels.append(None)
            continue
        areas = AreaSet(arrays[f"gl{i}_lo"], arrays[f"gl{i}_hi"],
                        arrays[f"gl{i}_smin"], arrays[f"gl{i}_smax"])
        idx.levels.append(idx._make_drtree(areas))
    idx.epoch = int(gm["epoch"])
    idx.records_inserted = int(gm["records_inserted"])
    em = gm.get("eve")
    if em is not None and g.eve is not None:
        eve = g.eve
        eve._next_seed = 1
        chain = []
        for j, rm in enumerate(em["raes"]):
            rae = eve._new_rae(int(rm["capacity"]))
            rae.bloom.words = arrays[f"eve{j}_words"].astype(
                np.uint32, copy=True)
            rae.count = int(rm["count"])
            rae.min_seq = rm["min_seq"]
            rae.max_seq = int(rm["max_seq"])
            chain.append(rae)
        eve.chain = chain
        eve._next_seed = int(em["next_seed"])


def load_snapshot(engine, path: str) -> dict:
    """Restore a published snapshot into a freshly built engine (same
    topology/configs).  Returns the per-shard WAL frame positions the
    snapshot covers — recovery replays only frames past them."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert meta["num_shards"] == engine.num_shards, \
        f"snapshot has {meta['num_shards']} shards, engine has " \
        f"{engine.num_shards}"
    for s, sh in enumerate(engine.shards):
        with np.load(os.path.join(path, f"shard-{s:03d}.npz")) as data:
            arrays = {k: data[k] for k in data.files}
        _restore_tree(sh.tree, arrays, meta["shards"][s])
    return {int(s): int(n) for s, n in meta["wal_frames"].items()}
