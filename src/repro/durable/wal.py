"""Segmented write-ahead log of columnar op batches (one stream/shard).

The record format IS the engine's typed columnar ``OpBatch``: a frame
carries the write ops of one shard plan as five flat arrays (kinds u8,
keys/vals/los/his u64) — no per-op encoding, one ``tobytes`` per column.
Frames are length-prefixed and CRC-checksummed::

    segment  = SEG_MAGIC(8) | shard u32 | seg_index u32 | frame*
    frame    = payload_len u32 | crc32(payload) u32 | payload
    payload  = ftype u8 | plan_seq u64 | n u32
             | kinds (n)  | keys (8n) | vals (8n) | los (8n) | his (8n)

``ftype`` distinguishes batch frames (``FRAME_BATCH``, replayed through
the shard's write paths) from flush markers (``FRAME_FLUSH``: an explicit
``Engine.flush`` mutated level structure outside any plan, so replay must
flush at the same point to keep level shapes byte-identical).

**Group commit**: the engine appends ONE frame per shard plan — all of a
submitted batch's write steps for that shard — so a single fsync covers
the whole batch.  Appends happen on the shard's single worker thread
(the existing per-shard FIFO), which is the writer's thread-safety model:
one appender per stream, no lock.

**Torn tails**: a crash can leave a half-written frame at the end of the
last segment.  ``WalReader`` stops at the first short or CRC-failing
frame and reports the valid byte offset, so recovery replays exactly the
durable prefix and truncates the garbage before appending resumes.

fsync policy (``EngineConfig.fsync``):

  ``batch``   fsync after every appended frame — an acknowledged batch
              survives power loss (the durability default),
  ``rotate``  fsync only on segment rotation and close — bounded loss,
  ``never``   no fsync (OS-buffered only; ``flush()`` still runs so
              bytes survive process death, just not power loss).
"""

from __future__ import annotations

import os
import struct
import zlib

import numpy as np

SEG_MAGIC = b"RWAL0001"
SEG_HEADER = struct.Struct("<8sII")  # magic, shard, segment index
FRAME_HEADER = struct.Struct("<II")  # payload length, crc32(payload)
PAYLOAD_HEADER = struct.Struct("<BQI")  # ftype, plan seq, n ops

FRAME_BATCH = 0
FRAME_FLUSH = 1

FSYNC_POLICIES = ("batch", "rotate", "never")


def shard_dir(wal_dir: str, shard: int) -> str:
    return os.path.join(wal_dir, f"shard-{shard:03d}")


def _seg_path(sdir: str, index: int) -> str:
    return os.path.join(sdir, f"seg-{index:08d}.wal")


def _list_segments(sdir: str) -> list[int]:
    if not os.path.isdir(sdir):
        return []
    out = []
    for name in os.listdir(sdir):
        if name.startswith("seg-") and name.endswith(".wal"):
            try:
                out.append(int(name[4:-4]))
            except ValueError:
                pass
    return sorted(out)


def encode_frame(ftype: int, plan_seq: int, kinds: np.ndarray,
                 keys: np.ndarray, vals: np.ndarray, los: np.ndarray,
                 his: np.ndarray) -> bytes:
    """One checksummed length-prefixed frame around a columnar payload."""
    n = len(kinds)
    payload = b"".join((
        PAYLOAD_HEADER.pack(ftype, plan_seq, n),
        np.ascontiguousarray(kinds, dtype=np.uint8).tobytes(),
        np.ascontiguousarray(keys, dtype=np.uint64).tobytes(),
        np.ascontiguousarray(vals, dtype=np.uint64).tobytes(),
        np.ascontiguousarray(los, dtype=np.uint64).tobytes(),
        np.ascontiguousarray(his, dtype=np.uint64).tobytes(),
    ))
    return FRAME_HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def decode_payload(payload: bytes):
    """payload -> (ftype, plan_seq, kinds, keys, vals, los, his)."""
    ftype, plan_seq, n = PAYLOAD_HEADER.unpack_from(payload, 0)
    at = PAYLOAD_HEADER.size
    kinds = np.frombuffer(payload, np.uint8, n, at)
    at += n
    cols = []
    for _ in range(4):
        cols.append(np.frombuffer(payload, np.uint64, n, at))
        at += 8 * n
    return (ftype, plan_seq, kinds) + tuple(cols)


class WalFrame:
    """One decoded WAL record (a write-only columnar op batch)."""

    __slots__ = ("ftype", "plan_seq", "kinds", "keys", "vals", "los",
                 "his")

    def __init__(self, ftype, plan_seq, kinds, keys, vals, los, his):
        self.ftype = ftype
        self.plan_seq = plan_seq
        self.kinds = kinds
        self.keys = keys
        self.vals = vals
        self.los = los
        self.his = his

    def __len__(self) -> int:
        return len(self.kinds)


class WalWriter:
    """Appender for one shard's log stream (single-threaded by design:
    the shard's worker IS the only appender, per-shard FIFO)."""

    def __init__(self, wal_dir: str, shard: int, *,
                 segment_bytes: int = 4 << 20, fsync: str = "batch"):
        assert fsync in FSYNC_POLICIES, fsync
        self.dir = shard_dir(wal_dir, shard)
        self.shard = shard
        self.segment_bytes = int(segment_bytes)
        self.fsync_policy = fsync
        os.makedirs(self.dir, exist_ok=True)
        # Durability counters (the engine absorbs these into metrics).
        self.bytes_written = 0
        self.appends = 0
        self.fsyncs = 0
        self.frames_appended = 0
        self.segments_rotated = 0
        segs = _list_segments(self.dir)
        self._seg_index = segs[-1] if segs else 0
        self._file = None
        self._closed = False

    # ---------------------------------------------------------- segments
    def _open_segment(self, index: int, append: bool) -> None:
        path = _seg_path(self.dir, index)
        if append and os.path.exists(path):
            self._file = open(path, "ab")
        else:
            self._file = open(path, "wb")
            hdr = SEG_HEADER.pack(SEG_MAGIC, self.shard, index)
            self._file.write(hdr)
            self.bytes_written += len(hdr)
        self._seg_index = index

    def _ensure_open(self) -> None:
        if self._file is None:
            # Resume at the existing tail (recovery truncated any torn
            # frame before handing the stream back to a writer).
            self._open_segment(self._seg_index,
                               append=bool(_list_segments(self.dir)))

    def _rotate(self) -> None:
        if self.fsync_policy in ("batch", "rotate"):
            self._file.flush()
            os.fsync(self._file.fileno())
            self.fsyncs += 1
        self._file.close()
        self._open_segment(self._seg_index + 1, append=False)
        self.segments_rotated += 1

    # ------------------------------------------------------------ append
    def append(self, ftype: int, plan_seq: int, kinds, keys, vals, los,
               his) -> int:
        """Append one frame; returns bytes written.  With the ``batch``
        policy the frame is durable (fsynced) before this returns — the
        engine acknowledges the batch only after that."""
        assert not self._closed, "append on closed WAL"
        self._ensure_open()
        frame = encode_frame(ftype, plan_seq, kinds, keys, vals, los, his)
        self._file.write(frame)
        # Always reach the OS: process death (vs power loss) never loses
        # an acknowledged frame regardless of fsync policy.
        self._file.flush()
        if self.fsync_policy == "batch":
            os.fsync(self._file.fileno())
            self.fsyncs += 1
        self.bytes_written += len(frame)
        self.appends += 1
        self.frames_appended += 1
        if self._file.tell() >= self.segment_bytes:
            self._rotate()
        return len(frame)

    def append_batch(self, plan_seq: int, kinds, keys, vals, los,
                     his) -> int:
        return self.append(FRAME_BATCH, plan_seq, kinds, keys, vals, los,
                           his)

    def append_flush(self) -> int:
        z8 = np.zeros(0, np.uint8)
        z64 = np.zeros(0, np.uint64)
        return self.append(FRAME_FLUSH, 0, z8, z64, z64, z64, z64)

    # ------------------------------------------------------------- close
    def sync(self) -> None:
        """Flush + fsync whatever has been appended so far."""
        if self._file is not None:
            self._file.flush()
            if self.fsync_policy != "never":
                os.fsync(self._file.fileno())
                self.fsyncs += 1

    def close(self) -> None:
        """Deterministic shutdown: flush, fsync, close (idempotent)."""
        if self._closed:
            return
        self.sync()
        if self._file is not None:
            self._file.close()
            self._file = None
        self._closed = True

    def counters(self) -> dict:
        return {
            "bytes": self.bytes_written,
            "appends": self.appends,
            "fsyncs": self.fsyncs,
            "frames": self.frames_appended,
            "segments": self.segments_rotated + 1,
        }


class WalReader:
    """Torn-tail-tolerant scan of one shard's log stream."""

    def __init__(self, wal_dir: str, shard: int):
        self.dir = shard_dir(wal_dir, shard)
        self.shard = shard
        # Set by read_frames: where the durable prefix ends.
        self.valid_segment: int | None = None
        self.valid_offset: int = 0
        self.torn = False

    def read_frames(self) -> list[WalFrame]:
        """Every decodable frame, in append order, across all segments.

        Stops at the first torn frame (short read, bad CRC, or bad
        segment header) and records ``valid_segment``/``valid_offset`` —
        the truncation point recovery applies before re-opening the
        stream for appends.  Segments after a torn one are ignored (a
        crash mid-rotation leaves garbage only at the tail).
        """
        frames: list[WalFrame] = []
        self.valid_segment, self.valid_offset, self.torn = None, 0, False
        for seg in _list_segments(self.dir):
            path = _seg_path(self.dir, seg)
            with open(path, "rb") as f:
                data = f.read()
            if len(data) < SEG_HEADER.size:
                self.torn = True
                break
            magic, shard, idx = SEG_HEADER.unpack_from(data, 0)
            if magic != SEG_MAGIC or shard != self.shard or idx != seg:
                self.torn = True
                break
            self.valid_segment, self.valid_offset = seg, SEG_HEADER.size
            at = SEG_HEADER.size
            ok = True
            while at + FRAME_HEADER.size <= len(data):
                plen, crc = FRAME_HEADER.unpack_from(data, at)
                body0 = at + FRAME_HEADER.size
                if body0 + plen > len(data):
                    ok = False
                    break
                payload = data[body0:body0 + plen]
                if zlib.crc32(payload) != crc:
                    ok = False
                    break
                frames.append(WalFrame(*decode_payload(payload)))
                at = body0 + plen
                self.valid_offset = at
            if at != len(data) or not ok:
                self.torn = True
                break
        return frames

    def truncate_torn_tail(self) -> None:
        """Cut the last segment back to its durable prefix and drop any
        segments past it, so a re-opened writer appends after the last
        valid frame (call ``read_frames`` first)."""
        if self.valid_segment is None:
            # Nothing durable at all: clear every segment file.
            for seg in _list_segments(self.dir):
                os.remove(_seg_path(self.dir, seg))
            return
        for seg in _list_segments(self.dir):
            if seg > self.valid_segment:
                os.remove(_seg_path(self.dir, seg))
        path = _seg_path(self.dir, self.valid_segment)
        if os.path.getsize(path) > self.valid_offset:
            with open(path, "r+b") as f:
                f.truncate(self.valid_offset)


def wal_shards(wal_dir: str) -> list[int]:
    """Shard ids with a log stream under ``wal_dir``."""
    if not os.path.isdir(wal_dir):
        return []
    out = []
    for name in os.listdir(wal_dir):
        if name.startswith("shard-"):
            try:
                out.append(int(name.split("-")[1]))
            except (IndexError, ValueError):
                pass
    return sorted(out)


def wal_has_frames(wal_dir: str) -> bool:
    """Does any shard stream hold at least one durable frame?  (The
    engine refuses to open such a directory for fresh writes — recovery
    must run first so acknowledged data is never silently orphaned.)"""
    for s in wal_shards(wal_dir):
        if WalReader(wal_dir, s).read_frames():
            return True
    return False
