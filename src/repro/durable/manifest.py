"""Append-only level manifest with atomic versioned commits.

The manifest is the durable record of *structure*: per shard, the level
stack (SSTable uids, key ranges, seq windows, entry counts) and the
GLORAN index epoch; plus the engine topology and serialized configs so
cold-start recovery can rebuild an identical engine from the directory
alone; plus the latest snapshot pointer (which snapshot, and how many
WAL frames per shard it already covers) so restart replays only the
WAL tail.

Commits follow the write-tmp-then-rename discipline (``durable.atomic``,
extracted from ``ckpt/checkpoint.py``): each commit publishes a complete
``MANIFEST-<version>.json``; readers load the highest parsable version
and fall back to the previous one if the newest is damaged, so there is
never a window in which no consistent manifest exists.  An in-memory
append-only edit log (flush/compaction/GC/recover events) rides along in
each version for observability and post-crash forensics.

fsync policy: only two commits are durability-critical — the initial
one carrying the config doc (recovery cannot rebuild the engine without
it) and snapshot pointers (``record_snapshot`` forces fsync) — and the
engine fsyncs those explicitly.  Routine per-flush/compaction structure
records are NOT load-bearing for crash consistency (recovery replays
the WAL; level records are observability), so they default to the
cheap non-fsynced atomic rename — that is what keeps group-commit WAL
overhead inside the 1.25x acceptance gate.

Thread safety: shard workers record structure changes concurrently; a
single lock serializes mutation + commit.
"""

from __future__ import annotations

import json
import os
import threading
from dataclasses import asdict

from .atomic import atomic_write_json, keep_last_k, list_versions

PREFIX = "MANIFEST-"
SUFFIX = ".json"
MAX_EDITS = 256  # append-only edit log rides in each version, bounded


def _manifest_path(directory: str, version: int) -> str:
    return os.path.join(directory, f"{PREFIX}{version:08d}{SUFFIX}")


def describe_tree(tree) -> dict:
    """The manifest's view of one shard's LSMTree structure."""
    levels = []
    for lvl in tree.levels:
        if lvl is None or len(lvl) == 0:
            levels.append(None)
        else:
            levels.append({
                "uid": int(lvl.uid),
                "n": len(lvl),
                "min_key": int(lvl.keys[0]),
                "max_key": int(lvl.max_key),
                "min_seq": int(lvl.min_seq),
                "max_seq": int(lvl.max_seq),
            })
    out = {
        "levels": levels,
        "seq": int(tree.seq),
        "sstable_seed": int(tree._sstable_seed),
    }
    if tree.gloran is not None:
        out["gloran_epoch"] = tree.gloran.index_epoch
        out["gloran_gc_floor"] = int(tree.gloran.gc_floor)
    return out


def structure_fingerprint(tree) -> tuple:
    """Cheap token that moves iff the durable structure moved: level
    uids (flush/compaction build new SSTables) + the GLORAN index epoch
    (staging flush / index compaction / GC)."""
    uids = tuple(lvl.uid if lvl is not None and len(lvl) else 0
                 for lvl in tree.levels)
    epoch = tree.gloran.index_epoch if tree.gloran is not None else None
    return (uids, epoch)


class LevelManifest:
    """Versioned, atomically-committed manifest for one engine."""

    def __init__(self, directory: str, *, keep: int = 3,
                 config: dict | None = None, fsync: bool = True):
        self.dir = directory
        self.keep = int(keep)
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self.version = 0
        self.doc: dict = {
            "version": 0,
            "config": config or {},
            "shards": {},
            "snapshot": None,
            "edits": [],
        }

    # ------------------------------------------------------------ commit
    def _commit_locked(self, fsync: bool | None = None) -> None:
        self.version += 1
        self.doc["version"] = self.version
        if len(self.doc["edits"]) > MAX_EDITS:
            self.doc["edits"] = self.doc["edits"][-MAX_EDITS:]
        atomic_write_json(
            _manifest_path(self.dir, self.version), self.doc,
            fsync=self.fsync if fsync is None else fsync)
        keep_last_k(self.dir, PREFIX, self.keep, SUFFIX)

    def commit(self, *, fsync: bool | None = None) -> int:
        with self._lock:
            self._commit_locked(fsync=fsync)
            return self.version

    # ------------------------------------------------------------- edits
    def record_structure(self, shard: int, tree, *, reason: str) -> int:
        """One structural edit (flush / compaction / GC / recover):
        replace the shard's level record and commit a new version."""
        return self.record_structure_desc(shard, describe_tree(tree),
                                          reason=reason)

    def record_structure_desc(self, shard: int, desc: dict, *,
                              reason: str) -> int:
        """Commit a pre-described level record — how structure edits
        from shard worker processes (which describe their own trees and
        ship the document home) land in the parent's manifest."""
        with self._lock:
            self.doc["shards"][str(shard)] = desc
            self.doc["edits"].append({
                "shard": int(shard),
                "reason": reason,
                "seq": desc["seq"],
                "gloran_epoch": desc.get("gloran_epoch"),
            })
            self._commit_locked()
            return self.version

    def record_snapshot(self, name: str, wal_frames: dict) -> int:
        """Point the manifest at a published snapshot.  ``wal_frames``
        maps shard id -> frames already folded into the snapshot, so
        recovery replays only frames past those positions."""
        with self._lock:
            self.doc["snapshot"] = {
                "name": name,
                "wal_frames": {str(s): int(n)
                               for s, n in wal_frames.items()},
                "manifest_version": self.version + 1,
            }
            self.doc["edits"].append({"reason": "snapshot", "name": name})
            # The pointer is what makes WAL-tail restarts possible —
            # worth an fsync regardless of the routine-commit policy.
            self._commit_locked(fsync=True)
            return self.version

    # -------------------------------------------------------------- load
    @classmethod
    def load(cls, directory: str, *, keep: int = 3,
             fsync: bool = True) -> "LevelManifest":
        """Load the newest parsable version (fall back past a damaged
        newest file — the atomic rename makes that near-impossible, but
        recovery must not wedge on a scribbled disk)."""
        m = cls(directory, keep=keep, fsync=fsync)
        for v in reversed(list_versions(directory, PREFIX, SUFFIX)):
            try:
                with open(_manifest_path(directory, v)) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            m.version = v
            m.doc = doc
            break
        return m

    @property
    def config(self) -> dict:
        return self.doc.get("config", {})

    @property
    def snapshot(self) -> dict | None:
        return self.doc.get("snapshot")

    def shard_record(self, shard: int) -> dict | None:
        return self.doc.get("shards", {}).get(str(shard))


def engine_config_doc(engine) -> dict:
    """Serialize everything recovery needs to rebuild the engine: the
    topology, the strategy, and the storage configs (flat dataclasses —
    JSON round-trips them losslessly)."""
    doc = {
        "num_shards": engine.num_shards,
        "strategy": engine.strategy,
        "partition": engine.router.partition,
        "lsm_config": asdict(engine.lsm_config),
        "gloran_config": None,
    }
    gc = engine._gloran_eff
    if gc is not None:
        doc["gloran_config"] = {
            "index": asdict(gc.index),
            "eve": asdict(gc.eve) if gc.eve is not None else None,
            "use_eve": gc.use_eve,
            "use_drtree": gc.use_drtree,
        }
    return doc


def configs_from_doc(doc: dict):
    """Inverse of ``engine_config_doc``: (num_shards, strategy,
    partition, LSMConfig, GloranConfig | None)."""
    from ..core.gloran import GloranConfig
    from ..core.lsm_drtree import LSMDRTreeConfig
    from ..core.eve import RAEConfig
    from ..lsm.format import LSMConfig

    lsm = LSMConfig(**doc["lsm_config"])
    gloran = None
    g = doc.get("gloran_config")
    if g is not None:
        gloran = GloranConfig(
            index=LSMDRTreeConfig(**g["index"]),
            eve=RAEConfig(**g["eve"]) if g["eve"] is not None else None,
            use_eve=g["use_eve"],
            use_drtree=g["use_drtree"])
    return (int(doc["num_shards"]), doc["strategy"], doc["partition"],
            lsm, gloran)
