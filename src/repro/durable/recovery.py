"""Cold-start recovery: manifest -> (snapshot) -> WAL-tail replay.

``recover(wal_dir)`` rebuilds a live engine from the durable directory
alone:

1. **Manifest** — load the newest committed ``MANIFEST-<v>.json``; its
   config doc carries topology + strategy + storage configs, so the
   caller needs nothing but the path.
2. **WAL scan** — read every shard stream's durable prefix
   (torn-tail-tolerant) and truncate the garbage past it, so the
   re-opened writers append exactly after the last acknowledged frame.
3. **Snapshot fast path** — if the manifest points at a published
   snapshot whose recorded WAL positions are covered by the durable
   prefix, load it and replay only the *tail*; otherwise replay the
   whole log from an empty store.
4. **Replay** — frames re-enter through the shard executors' own write
   paths (``put_batch`` / ``delete_batch`` / ``range_delete_arrays``,
   FLUSH markers through ``LSMTree.flush``).  Because every batch-insert
   path chunks at its flush/capacity boundaries (memtable,
   ``StagingBuffer.insert_batch`` via ``LSMDRTree.insert_batch``, the
   EVE chain) and sequence numbers are re-issued by the same
   ``_next_seqs`` arithmetic, the rebuilt store's flush points, level
   shapes, and lookup verdicts are byte-identical to the pre-crash
   store's durable prefix.  The ``DeviceFilterRegistry`` is NOT warmed
   here — rebuilt SSTables/epochs carry fresh uids, so the registry
   re-packs lazily on first lookup, exactly like any post-compaction
   invalidation.
5. **Re-attach** — WAL writers resume at the durable tail, the loaded
   manifest is re-wired, and per-shard "recover" edits are committed.

Recovery timings land in ``engine.recovery`` and surface through
``engine.stats()["metrics"]`` as ``recovery.wall_s`` /
``recovery.frames_replayed`` / ``recovery.snapshot_loaded``.
"""

from __future__ import annotations

import os
import time

import numpy as np

from .manifest import LevelManifest, configs_from_doc
from .snapshot import load_snapshot
from .wal import FRAME_FLUSH, WalReader, WalWriter

# OP codes are frozen by the WAL format; resolve them through the plan
# module (submodule import — safe against the engine<->durable cycle).
from ..engine.plan import OP_DELETE, OP_PUT, OP_RANGE_DELETE


def replay_frame(sh, frame) -> None:
    """Re-execute one durable frame on a shard executor.

    A frame concatenates the plan's write steps in request order; the
    maximal same-kind runs here may merge steps that were split only by
    interleaved reads, which is equivalence-preserving: every batch
    write path chunks at its own flush/capacity boundaries, so the same
    records cross the same thresholds in the same order.

    Each frame was one shard plan, and with the background scheduler on
    every plan drained due jobs before its steps — replay mirrors that
    drain point so flushes/compactions interleave with the write stream
    at the same boundaries (delete application during bottom compaction
    is order-sensitive).
    """
    sh.run_scheduler("recover")
    if frame.ftype == FRAME_FLUSH:
        sh.flush()
        return
    kinds = frame.kinds
    if not len(kinds):
        return
    cuts = (np.flatnonzero(np.diff(kinds)) + 1).tolist()
    bounds = [0, *cuts, len(kinds)]
    for a, b in zip(bounds[:-1], bounds[1:]):
        k = int(kinds[a])
        if k == OP_PUT:
            sh.put_batch(frame.keys[a:b], frame.vals[a:b])
        elif k == OP_DELETE:
            sh.delete_batch(frame.keys[a:b])
        elif k == OP_RANGE_DELETE:
            sh.range_delete_arrays(frame.los[a:b], frame.his[a:b])


def recover(wal_dir: str, *, config=None, use_snapshot: bool = True):
    """Rebuild a live, durable engine from ``wal_dir``; see module doc.

    ``config`` optionally carries execution knobs (kernel gates, cache,
    pipeline, fsync policy for the re-opened writers); topology and
    storage configs always come from the manifest.  Returns the engine
    with WAL + manifest re-attached and ``engine.recovery`` populated.
    """
    from dataclasses import replace

    from ..engine.engine import Engine, _resolve_procs
    from ..engine.executor import EngineConfig

    t0 = time.perf_counter()
    mdir = os.path.join(wal_dir, "manifest")
    manifest = LevelManifest.load(mdir)
    doc = manifest.config
    if not doc:
        raise RuntimeError(f"no committed manifest under {mdir}; "
                           "nothing to recover")
    num_shards, strategy, partition, lsm, gloran = configs_from_doc(doc)
    # wal_dir=None: replay must not re-log, and __init__ must not refuse
    # the non-empty directory; writers re-attach after replay.
    cfg = replace(config or EngineConfig(), partition=partition,
                  wal_dir=None)

    # Procs mode: each worker replays its own shard streams during
    # startup (WAL ownership lives with the worker), the parent loads
    # the manifest and records the shipped-back "recover" level
    # records.  No snapshot fast path — worker trees rebuild from the
    # full log (take_snapshot is refused on procs engines anyway).
    if _resolve_procs(cfg, num_shards):
        engine = Engine(num_shards, strategy=strategy, lsm_config=lsm,
                        gloran_config=gloran, config=cfg,
                        _recover_from=wal_dir)
        engine.recovery["wall_s"] = time.perf_counter() - t0
        return engine

    def fresh() -> "Engine":
        return Engine(num_shards, strategy=strategy, lsm_config=lsm,
                      gloran_config=gloran, config=cfg)

    engine = fresh()
    frames = {}
    for s in range(num_shards):
        r = WalReader(wal_dir, s)
        frames[s] = r.read_frames()
        r.truncate_torn_tail()

    starts = {s: 0 for s in range(num_shards)}
    snap_used = 0
    snap = manifest.snapshot if use_snapshot else None
    if snap is not None:
        path = os.path.join(wal_dir, "snapshots", snap["name"])
        if os.path.isdir(path):
            pos = load_snapshot(engine, path)
            if all(pos.get(s, 0) <= len(frames[s])
                   for s in range(num_shards)):
                starts = {s: pos.get(s, 0) for s in range(num_shards)}
                snap_used = 1
            else:
                # The snapshot saw frames past the durable prefix (a
                # weaker-than-"batch" fsync policy lost the tail it was
                # built on): discard it and replay the full log.
                engine = fresh()

    replayed = 0
    for s in range(num_shards):
        sh = engine.shards[s]
        for fr in frames[s][starts[s]:]:
            replay_frame(sh, fr)
            replayed += 1
    # Background mode: replay enters through the executors' write paths
    # directly (no plans run), so seals queued by capacity boundaries
    # drain here — the manifest records below must describe the fully
    # published level structure, same as a drained live engine.
    engine.drain()

    writers = []
    for s in range(num_shards):
        w = WalWriter(wal_dir, s, segment_bytes=cfg.wal_segment_bytes,
                      fsync=cfg.fsync)
        # Position the appender's counters at the stream totals so
        # later snapshot pointers (frame counts) and the ``wal.bytes``
        # metric stay consistent with the durable log.
        w.frames_appended = len(frames[s])
        sdir = os.path.join(wal_dir, f"shard-{s:03d}")
        if os.path.isdir(sdir):
            w.bytes_written = sum(
                os.path.getsize(os.path.join(sdir, f))
                for f in os.listdir(sdir) if f.endswith(".wal"))
        writers.append(w)
    engine._attach_durability(wal_dir, manifest=manifest,
                              writers=writers)
    for s in range(num_shards):
        manifest.record_structure(s, engine.shards[s].tree,
                                  reason="recover")
    engine.recovery = {
        "wall_s": time.perf_counter() - t0,
        "frames_replayed": replayed,
        "snapshot_loaded": snap_used,
    }
    return engine
