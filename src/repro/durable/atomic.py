"""Atomic filesystem publication: write-tmp-then-rename + keep-last-k.

The discipline proven in ``repro.ckpt.checkpoint`` — materialize into a
``*.tmp`` sibling, then ``os.rename`` onto the final name so a crash
mid-write never corrupts the last published version — extracted here so
the checkpoint manager, the level manifest, and store snapshots all share
one implementation instead of three divergent copies.

POSIX ``rename`` within one filesystem is atomic; readers either see the
complete old version or the complete new one.  ``fsync_dir`` additionally
persists the directory entry itself, which the WAL/manifest recovery
chain needs (a renamed file whose directory entry was never synced can
vanish across a power cut).
"""

from __future__ import annotations

import json
import os
import re
import shutil


def fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates inside it are durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes, *, fsync: bool = True) -> None:
    """Publish ``data`` at ``path`` atomically (tmp sibling + rename)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        fsync_dir(os.path.dirname(os.path.abspath(path)))


def atomic_write_json(path: str, obj, *, fsync: bool = True) -> None:
    """Publish a JSON document atomically."""
    atomic_write_bytes(path, json.dumps(obj, indent=1).encode(),
                       fsync=fsync)


def atomic_publish_dir(tmp: str, final: str) -> None:
    """Atomically publish a staged directory at its final name.

    ``tmp`` must be a fully-written sibling directory (same parent).  An
    existing ``final`` is removed first — the caller's versioning scheme
    (numbered names + ``keep_last_k``) is what makes that safe.
    """
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def clear_stale_tmp(path: str) -> None:
    """Remove a leftover ``path`` (file or dir) from a crashed writer."""
    if os.path.isdir(path):
        shutil.rmtree(path)
    elif os.path.exists(path):
        os.remove(path)


def versioned_name(prefix: str, version: int, suffix: str = "") -> str:
    return f"{prefix}{version:08d}{suffix}"


def list_versions(directory: str, prefix: str,
                  suffix: str = "") -> list[int]:
    """Sorted published versions matching ``<prefix><number><suffix>``
    (tmp siblings and foreign names are ignored)."""
    pat = re.compile(re.escape(prefix) + r"(\d+)" + re.escape(suffix)
                     + r"$")
    out = []
    for name in os.listdir(directory):
        m = pat.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def keep_last_k(directory: str, prefix: str, k: int,
                suffix: str = "") -> list[int]:
    """Drop all but the newest ``k`` published versions; returns the
    versions removed.  Bounded disk for any append-forever publisher."""
    versions = list_versions(directory, prefix, suffix)
    dropped = versions[:-k] if k > 0 else versions
    for v in dropped:
        target = os.path.join(directory, versioned_name(prefix, v, suffix))
        if os.path.isdir(target):
            shutil.rmtree(target, ignore_errors=True)
        else:
            try:
                os.remove(target)
            except OSError:
                pass
    return dropped
