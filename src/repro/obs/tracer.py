"""Low-overhead tracing: spans on ``time.perf_counter``, Chrome export.

The engine's request path is instrumented with ``obs.span(name, **attrs)``
context managers — submit, plan compilation, per-shard plan execution,
kernel dispatch, registry uploads, flush/compaction.  A process-global
tracer decides what those calls cost:

  NullTracer   the default: ``span()`` returns a shared no-op context
               manager, no lock, no allocation beyond the (empty) kwargs
               dict — the instrumented path stays within noise of an
               uninstrumented one (gated in ``scripts/check.sh``),
  Tracer       records (name, begin, end, thread) per span, thread-safe,
               bounded (drops past ``max_events``), exportable as Chrome
               trace-event JSON that loads directly in Perfetto / about:
               //tracing, with one named track per thread — the shard
               worker pools are named ``shard-N``, so per-shard timelines
               come out of the box.

Enable globally with env ``REPRO_TRACE=1`` (read once at import), or per
scope with ``set_tracer(Tracer())`` / the ``enabled()`` context manager.
Span names are dot-namespaced (``engine.submit``, ``shard.plan``,
``kernel.cascade``); the prefix becomes the Chrome event category.
"""

from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op context manager (the zero-cost off switch)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracer that records nothing; every call is O(1) and lock-free."""

    enabled = False

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        pass

    def events(self) -> list:
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """One open span; records on ``__exit__`` (begin/end always pair)."""

    __slots__ = ("tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = time.perf_counter()

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.tracer._record(self.name, self.t0, time.perf_counter(),
                            self.attrs)
        return False


class Tracer:
    """Thread-safe span recorder on the monotonic ``perf_counter`` clock.

    Every span is stored as a completed ``(name, t0, t1, tid, thread
    name, attrs)`` tuple — begin/end pair by construction, timestamps are
    monotonic and shared across threads (one clock).  Memory is bounded:
    past ``max_events`` spans, new ones are counted in ``dropped`` and
    discarded (the trace stays loadable, never OOMs a long run).
    """

    enabled = True

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = int(max_events)
        self.dropped = 0
        self._events: list[tuple] = []
        # Spans absorbed from other processes (shard workers): same
        # tuple shape prefixed with (pid, process name).  perf_counter
        # is CLOCK_MONOTONIC system-wide on Linux, so foreign
        # timestamps land on this tracer's clock directly.
        self._foreign: list[tuple] = []
        self._lock = threading.Lock()
        self._base = time.perf_counter()

    # ----------------------------------------------------------- record
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        t = time.perf_counter()
        self._record(name, t, t, attrs)

    def _record(self, name: str, t0: float, t1: float,
                attrs: dict) -> None:
        th = threading.current_thread()
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append((name, t0, t1, th.ident, th.name, attrs))

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._foreign.clear()
            self.dropped = 0
            self._base = time.perf_counter()

    # --------------------------------------------- cross-process merge
    def drain(self) -> list[list]:
        """Return + clear the recorded spans as JSON-able rows — the
        shipping format a shard worker sends home with each reply.  The
        epoch is kept, so successive drains stay on one timeline."""
        with self._lock:
            snap, self._events = self._events, []
        return [[n, t0, t1, tid, tname, attrs]
                for n, t0, t1, tid, tname, attrs in snap]

    def absorb(self, rows: list, *, pid: int,
               process_name: str | None = None) -> None:
        """Merge spans drained in another process into this trace,
        keyed under that process's pid so the Chrome export renders one
        named track group per worker."""
        with self._lock:
            for r in rows:
                if (len(self._events) + len(self._foreign)
                        >= self.max_events):
                    self.dropped += 1
                    continue
                self._foreign.append((int(pid), process_name, r[0],
                                      float(r[1]), float(r[2]),
                                      int(r[3]), r[4], r[5] or {}))

    # ------------------------------------------------------------ views
    def events(self) -> list[dict]:
        """Completed spans as dicts (seconds on the tracer's clock)."""
        with self._lock:
            snap = list(self._events)
        return [{"name": n, "t0": t0, "t1": t1, "tid": tid,
                 "thread": tname, "attrs": attrs}
                for n, t0, t1, tid, tname, attrs in snap]

    def chrome_events(self) -> list[dict]:
        """Chrome trace-event list: complete ('X') events in microseconds
        relative to the tracer epoch, plus thread/process name metadata
        so Perfetto labels each shard worker's track."""
        with self._lock:
            snap = list(self._events)
            foreign = list(self._foreign)
            base = self._base
        out = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
                "args": {"name": "repro-engine"}}]
        seen: dict[tuple, str] = {}

        def emit(pid, name, t0, t1, tid, tname, attrs):
            if (pid, tid) not in seen:
                seen[(pid, tid)] = tname
                out.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": tname}})
            ev = {"name": name, "cat": name.split(".", 1)[0], "ph": "X",
                  "pid": pid, "tid": tid,
                  "ts": round((t0 - base) * 1e6, 3),
                  "dur": round((t1 - t0) * 1e6, 3)}
            if attrs:
                ev["args"] = attrs
            out.append(ev)

        for name, t0, t1, tid, tname, attrs in snap:
            emit(1, name, t0, t1, tid, tname, attrs)
        pids_named: set[int] = set()
        for pid, pname, name, t0, t1, tid, tname, attrs in foreign:
            if pid not in pids_named:
                pids_named.add(pid)
                out.append({"name": "process_name", "ph": "M",
                            "pid": pid, "tid": 0,
                            "args": {"name": pname or f"pid {pid}"}})
            emit(pid, name, t0, t1, tid, tname, attrs)
        return out

    def export_chrome(self, path: str) -> dict:
        """Write the Chrome/Perfetto trace JSON; returns the document."""
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms",
               "otherData": {"clock": "perf_counter",
                             "dropped_events": self.dropped}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


# ------------------------------------------------------- global dispatch
def _from_env() -> NullTracer | Tracer:
    return Tracer() if os.environ.get("REPRO_TRACE", "0") not in \
        ("0", "", "off") else NULL_TRACER


_TRACER = _from_env()


def get_tracer():
    """The process-global tracer all instrumented call sites use."""
    return _TRACER


def set_tracer(tracer) -> None:
    """Install ``tracer`` globally (``NULL_TRACER`` to disable)."""
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER


def span(name: str, **attrs):
    """Open a span on the global tracer (a no-op when tracing is off).

    Hot call sites pass at most a couple of scalar attrs; anything
    costly to compute should be guarded with ``tracing_enabled()``.
    """
    return _TRACER.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    """Record a zero-duration marker on the global tracer."""
    _TRACER.instant(name, **attrs)


def tracing_enabled() -> bool:
    return _TRACER.enabled


class enabled:
    """Scope with a fresh recording ``Tracer`` installed globally.

        with obs.enabled() as tr:
            engine.get_batch(keys)
        tr.export_chrome("trace.json")
    """

    def __init__(self, tracer: Tracer | None = None):
        self.tracer = tracer or Tracer()
        self._prev = None

    def __enter__(self) -> Tracer:
        self._prev = get_tracer()
        set_tracer(self.tracer)
        return self.tracer

    def __exit__(self, *exc) -> bool:
        set_tracer(self._prev)
        return False
