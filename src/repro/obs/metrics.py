"""Unified namespaced metrics: counters + gauges under one flat schema.

The engine's observability surface had grown one ad-hoc ledger per
subsystem — ``KernelCounters``, the registry's ``upload_bytes``, block
-cache hit rates, staging-buffer occupancy — each with its own snapshot
shape.  ``MetricsRegistry`` absorbs them all under dot-namespaced keys
(``kernels.cascade_calls``, ``cache.hit_rate``, ``staging.occupancy``)
into ONE flat, sorted, JSON-serializable dict, so dashboards and tests
consume a single stable schema regardless of which subsystem a number
came from.
"""

from __future__ import annotations

import threading

_SCALARS = (bool, int, float, str)


class MetricsRegistry:
    """Thread-safe flat registry of namespaced counters and gauges."""

    def __init__(self):
        self._vals: dict[str, float | int | str | bool] = {}
        self._lock = threading.Lock()

    def inc(self, name: str, n: int | float = 1) -> None:
        """Add ``n`` to counter ``name`` (created at 0)."""
        with self._lock:
            self._vals[name] = self._vals.get(name, 0) + n

    def set(self, name: str, value) -> None:
        """Set gauge ``name`` to ``value`` (last write wins)."""
        with self._lock:
            self._vals[name] = value

    def get(self, name: str, default=0):
        with self._lock:
            return self._vals.get(name, default)

    def absorb(self, prefix: str, mapping: dict) -> None:
        """Fold a subsystem snapshot in under ``prefix.``.

        Nested dicts recurse (``a.b.c``); scalar leaves are kept, and
        non-scalar leaves (lists, arrays, per-shard breakdowns) are
        skipped — the flat schema carries rollups, the source snapshot
        keeps the structure.
        """
        flat = {}
        _flatten(prefix, mapping, flat)
        with self._lock:
            self._vals.update(flat)

    def snapshot(self) -> dict:
        """Key-sorted flat dict; every value is JSON-serializable."""
        with self._lock:
            return {k: self._vals[k] for k in sorted(self._vals)}

    def reset(self) -> None:
        with self._lock:
            self._vals.clear()


def _flatten(prefix: str, mapping: dict, out: dict) -> None:
    for k, v in mapping.items():
        key = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            _flatten(key, v, out)
        elif isinstance(v, _SCALARS):
            out[key] = v
        elif hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            out[key] = v.item()  # numpy scalar
