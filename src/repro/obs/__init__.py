"""repro.obs: tracing + metrics for the whole request path.

Three pieces, one import surface:

  ``span`` / ``Tracer``      begin/end spans on ``time.perf_counter``
                             from ``Engine.submit`` down to kernel
                             dispatch, exported as Chrome trace-event
                             JSON (loads in Perfetto with one track per
                             shard worker thread).  A process-global
                             no-op tracer is the default — the off
                             switch costs nothing measurable (env
                             ``REPRO_TRACE=1`` turns recording on),
  ``LatencyHistogram``       fixed log-scale buckets feeding p50/p95/p99
                             per op class and per shard into
                             ``engine.stats()``,
  ``MetricsRegistry``        counters/gauges from every subsystem under
                             one dot-namespaced flat snapshot schema.

See docs/OBSERVABILITY.md for usage and the metric namespace.
"""

from .hist import LatencyHistogram
from .metrics import MetricsRegistry
from .tracer import (NULL_TRACER, NullTracer, Tracer, enabled, get_tracer,
                     instant, set_tracer, span, tracing_enabled)

__all__ = ["LatencyHistogram", "MetricsRegistry", "NULL_TRACER",
           "NullTracer", "Tracer", "enabled", "get_tracer", "instant",
           "set_tracer", "span", "tracing_enabled"]
