"""Fixed-bucket log-scale latency histograms with tail quantiles.

``EngineStats`` kept only totals and means, so the paper's headline
effect — a *tail* latency shift under range-delete churn — was
invisible.  ``LatencyHistogram`` records durations into geometric
buckets (4 per octave, 100 ns .. ~100 s) at O(1) per sample and answers
``p50/p95/p99`` by log-linear interpolation inside the covering bucket;
the relative quantile error is bounded by one bucket ratio
(2^0.25 ~ 19%, typically far less — tested against ``np.percentile``).

Histograms merge (per-shard -> fleet), reset (per-window serving
stats), and snapshot into a stable JSON schema.
"""

from __future__ import annotations

import math

import numpy as np

_LO = 1e-7                  # bucket 0 lower edge: 100 ns
_PER_OCTAVE = 4             # buckets per factor-of-2 (ratio 2^0.25)
_NB = 124                   # covers _LO * 2^(124/4) ~ 215 s
_INV_LN2 = 1.0 / math.log(2.0)


def _bucket(seconds: float) -> int:
    if seconds <= _LO:
        return 0
    i = int(math.log(seconds / _LO) * _INV_LN2 * _PER_OCTAVE)
    return i if i < _NB else _NB - 1


def _edge(i: int) -> float:
    """Lower edge of bucket ``i`` in seconds."""
    return _LO * 2.0 ** (i / _PER_OCTAVE)


class LatencyHistogram:
    """O(1)-record log-bucket histogram over durations in seconds."""

    __slots__ = ("counts", "n", "total", "vmin", "vmax")

    def __init__(self):
        self.counts = np.zeros(_NB, dtype=np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        self.counts[_bucket(s)] += 1
        self.n += 1
        self.total += s
        if s < self.vmin:
            self.vmin = s
        if s > self.vmax:
            self.vmax = s

    def record_many(self, seconds) -> None:
        for s in np.asarray(seconds, dtype=float).ravel():
            self.record(float(s))

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def reset(self) -> None:
        self.counts[:] = 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    # -------------------------------------------------------- quantiles
    def quantile(self, q: float) -> float:
        """The q-quantile in seconds (0 when empty).

        Log-linear interpolation inside the covering bucket, clamped to
        the observed [min, max] so the extremes are exact.
        """
        if self.n == 0:
            return 0.0
        rank = q * (self.n - 1)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank, side="right"))
        if i >= _NB:
            i = _NB - 1
        prev = int(cum[i - 1]) if i else 0
        inb = int(self.counts[i])
        frac = (rank - prev + 0.5) / inb if inb else 0.5
        frac = min(max(frac, 0.0), 1.0)
        lo, hi = _edge(i), _edge(i + 1)
        v = lo * (hi / lo) ** frac
        return min(max(v, self.vmin), self.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def snapshot(self) -> dict:
        """Stable JSON schema: counts + microsecond summary quantiles."""
        us = 1e6
        return {
            "count": int(self.n),
            "total_seconds": round(self.total, 6),
            "mean_us": round(self.mean * us, 3),
            "min_us": round(self.vmin * us, 3) if self.n else 0.0,
            "max_us": round(self.vmax * us, 3),
            "p50_us": round(self.quantile(0.50) * us, 3),
            "p95_us": round(self.quantile(0.95) * us, 3),
            "p99_us": round(self.quantile(0.99) * us, 3),
        }
