"""On-disk format model and configuration for the LSM-tree substrate.

Follows the paper's cost model (Table 1): memory buffer of F entries, size
ratio T, key size k, entry size e, block size B, Bloom filters with
``bits_per_key`` bits/entry (10 by default, RocksDB's default), leveling
compaction.  Keys are uint64; values are modeled as ``value_size`` opaque
bytes and carried as a uint64 payload for correctness checking.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PUT = np.uint8(0)
TOMBSTONE = np.uint8(1)


@dataclass
class LSMConfig:
    buffer_capacity: int = 4096  # F, entries
    size_ratio: int = 10  # T
    key_size: int = 256  # k bytes (paper default)
    value_size: int = 768  # bytes (paper default)
    block_size: int = 4096  # B bytes
    bloom_bits_per_key: int = 10
    bloom_hashes: int = 6
    key_universe: int = 1 << 63  # U

    @property
    def entry_size(self) -> int:  # e
        return self.key_size + self.value_size

    @property
    def entries_per_block(self) -> int:
        return max(1, self.block_size // self.entry_size)

    @property
    def range_tombstone_size(self) -> int:
        # A range tombstone encodes start and end keys: 2k (paper §3).
        return 2 * self.key_size

    def level_capacity(self, i: int) -> int:
        """Capacity in entries of on-disk level i (0-based: L1 == i=0)."""
        return self.buffer_capacity * self.size_ratio ** (i + 1)
