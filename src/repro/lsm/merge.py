"""Vectorized sorted-view merge across sorted runs (REMIX-style).

An LSM range scan produces one sorted slice per run (memtable + one per
level).  Instead of concatenating and re-sorting (O(n log n) with a full
lexsort per scan), the slices are merged as *sorted views*: a tournament
of vectorized two-way merges, where each element's position in the merged
output is computed with one ``searchsorted`` per side — the same
cross-run sorted-view idea REMIX uses to make LSM range queries cheap.

Runs are ``(keys, seqs, types, vals)`` tuples sorted by key.  Keys may
repeat *across* runs (versions of the same key on different levels);
``newest_wins`` then resolves each duplicate group to its max-seq entry,
which is exact because sequence numbers are unique per tree.
"""

from __future__ import annotations

import numpy as np

Run = tuple  # (keys, seqs, types, vals) | (keys, vals) | ... sorted by [0]


def merge_two(a: Run, b: Run, rank_fn=None) -> Run:
    """Merge two key-sorted runs into one, preserving all entries.

    Works for any tuple arity as long as element 0 is the sort key; the
    output position of each entry is its rank in the merged order, so the
    merge is a pure scatter (no comparison loop).  Ties place ``a``'s
    entries first (stable), which callers never rely on — duplicates are
    resolved by ``newest_wins`` on seq, not by run order.

    ``rank_fn(ka, kb) -> (pa, pb) | None`` optionally replaces HOW the
    ranks are computed (``repro.engine`` supplies the Pallas merge-rank
    kernel, which gates itself and declines with None); the scatter —
    and the result — is identical either way.
    """
    ka, kb = a[0], b[0]
    na, nb = len(ka), len(kb)
    if na == 0:
        return b
    if nb == 0:
        return a
    ranks = rank_fn(ka, kb) if rank_fn is not None else None
    if ranks is not None:
        pa, pb = ranks
    else:
        pa = np.arange(na) + np.searchsorted(kb, ka, side="left")
        pb = np.arange(nb) + np.searchsorted(ka, kb, side="right")
    out = []
    for xa, xb in zip(a, b):
        x = np.empty(na + nb, dtype=xa.dtype)
        x[pa] = xa
        x[pb] = xb
        out.append(x)
    return tuple(out)


def empty_run() -> Run:
    """The empty (keys, seqs, types, vals) run."""
    z = np.zeros(0, np.uint64)
    return z, z.copy(), np.zeros(0, np.uint8), z.copy()


def merge_runs(parts: list[Run], empty: Run | None = None,
               rank_fn=None) -> Run:
    """Tournament-merge k key-sorted runs; duplicates stay adjacent.

    ``empty`` is returned when every part is empty (defaults to the
    4-tuple ``empty_run``; pass a matching-arity tuple otherwise).
    ``rank_fn`` is forwarded to every two-way round (see ``merge_two``).
    """
    parts = [p for p in parts if len(p[0])]
    if not parts:
        return empty if empty is not None else empty_run()
    while len(parts) > 1:
        nxt = [merge_two(parts[i], parts[i + 1], rank_fn=rank_fn)
               for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def newest_wins(keys: np.ndarray, seqs: np.ndarray, typs: np.ndarray,
                vals: np.ndarray) -> Run:
    """Resolve duplicate keys in a key-sorted stream to the max-seq entry.

    Sequence numbers are unique per tree, so exactly one entry survives
    per key regardless of the order duplicates arrived in.
    """
    n = len(keys)
    if n == 0:
        return keys, seqs, typs, vals
    new_grp = np.empty(n, dtype=bool)
    new_grp[0] = True
    np.not_equal(keys[1:], keys[:-1], out=new_grp[1:])
    starts = np.flatnonzero(new_grp)
    grp_max = np.maximum.reduceat(seqs, starts)
    gid = np.cumsum(new_grp) - 1
    keep = seqs == grp_max[gid]
    return keys[keep], seqs[keep], typs[keep], vals[keep]
