"""Background delete-aware flush/compaction scheduling (Lethe-style).

The inline write path stalls the serving thread every time a memtable
fills: ``LSMTree.flush`` runs the whole flush + leveled-compaction
cascade synchronously.  With a ``CompactionScheduler`` attached, the
tree instead **seals** the full memtable into an immutable frozen
snapshot (``FrozenMemtable`` — the cached sorted columnar view, zero
copy work beyond what a read batch already paid) and returns; the
heavy lifting becomes *jobs* on a priority queue that the execution
layer drains at deterministic points (the start of every shard plan,
and every explicit ``drain``/``flush``/``close``/``stats``).

Running jobs only at those points — never on an opportunistic side
thread — is what keeps the background mode byte-identical to the
inline path for any sequence of engine calls: every plan begins from
exactly the state the inline path would have reached, every I/O charge
lands on the same ledger before the next observation point, and the
per-shard FIFO (WAL ordering, recovery replay) is untouched.  What
moves is latency *attribution*: a put batch no longer carries the
flush + cascade on its own wall clock.

Job classes, in heap priority order:

  0  CASCADE    capacity-driven compaction of an overflowing level —
                the barrier children of the flush that overflowed it
                (the inline path runs them immediately after the flush,
                and so do we: at most one level overflows at a time, so
                any within-class order reproduces the inline cascade),
  1  FLUSH      one frozen memtable -> a level-0 run, FIFO,
  2  PROACTIVE  delete-aware compactions scored by
                ``(-range_tombstone_density, -level_overflow_ratio)``
                (Lethe: evict tombstone-dense runs first).  Enabled
                only when ``tombstone_trigger`` is set; a level whose
                estimated density reaches the trigger is compacted
                down even though it has not overflowed, so GLORAN
                garbage (and the DeviceFilterRegistry re-uploads its
                growing index causes) is reclaimed early instead of at
                an arbitrary overflow moment.

Density per level: LRR counts its range-tombstone block directly
(``len(level_rts[i]) / len(level_i)``); GLORAN asks the paper's own
estimator — a deterministic evenly-spaced sample of the level's
(key, seq) pairs probed through EVE — for the fraction of entries a
live range delete maybe-covers.  A (level uid, range-delete count)
stamp on proactive outputs stops EVE's false-positive floor from
re-triggering on a run we just compacted.

Backpressure: sealing past ``max_frozen`` pending snapshots runs due
jobs on the sealing thread until the backlog is back under the soft
limit, counted as a stall (``stall_count`` / ``stall_seconds`` and a
``sched.stall`` span) — the only point where a put can block on
compaction debt.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..obs import span

# Job classes (heap key element 0).
JOB_CASCADE = 0    # capacity-driven compaction (barrier child of a flush)
JOB_FLUSH = 1      # frozen memtable -> level 0
JOB_PROACTIVE = 2  # delete-aware compaction (Lethe scoring)

_PROACTIVE_SAMPLE = 256  # EVE probes per level-density estimate
_PROACTIVE_PER_KICK = 4  # proactive compactions per drain point


@dataclass
class FrozenMemtable:
    """One sealed, immutable memtable: the key-sorted columnar snapshot
    (unique keys — the dict semantics already resolved overwrites) plus
    the LRR range-tombstone buffer that sealed with it."""

    keys: np.ndarray
    seqs: np.ndarray
    types: np.ndarray
    vals: np.ndarray
    rts: list = field(default_factory=list)  # [(lo, hi, seq)] (LRR)

    @property
    def min_seq(self) -> int:
        return int(self.seqs.min()) if len(self.seqs) else 0

    def __len__(self) -> int:
        return len(self.keys)


def level_rt_density(tree, i: int) -> float:
    """Estimated fraction of level ``i``'s entries covered by a live
    range tombstone — the scheduler's Lethe priority input, also
    surfaced per level in ``engine.stats()``.

    LRR: exact ratio of the level's range-tombstone block to its run.
    GLORAN: EVE-sampled — probe an evenly-spaced deterministic sample
    of the level's (key, seq) pairs through the estimator (no I/O; the
    estimator is the in-memory structure the paper builds for exactly
    this maybe-deleted question) and return the maybe-covered fraction.
    Other strategies carry no range-tombstone metadata: 0.0.
    """
    lvl = tree.levels[i] if i < len(tree.levels) else None
    n = len(lvl) if lvl is not None else 0
    if tree.strategy == "lrr":
        nrt = len(tree.level_rts[i]) if i < len(tree.level_rts) else 0
        return nrt / max(n, 1)
    if tree.strategy == "gloran" and tree.gloran is not None and n:
        gl = tree.gloran
        if gl.num_range_deletes == 0 or gl.eve is None:
            return 0.0
        m = min(n, _PROACTIVE_SAMPLE)
        idx = np.linspace(0, n - 1, m).astype(np.int64)
        maybe = gl.eve.maybe_deleted_batch(lvl.keys[idx], lvl.seqs[idx])
        return float(np.mean(maybe))
    return 0.0


class CompactionScheduler:
    """Per-shard background flush/compaction job queue (see module doc).

    Owned by one shard's tree + executor; ``run_due`` executes every
    queued job (and any proactive candidates) on the calling thread.
    The run lock only guards against overlapping drain points (e.g. an
    engine-level ``drain`` racing a shard worker's plan-start kick);
    within the per-shard FIFO there is no concurrency to manage.
    """

    def __init__(self, tree, *, max_frozen: int = 4,
                 tombstone_trigger: float | None = None):
        self.tree = tree
        self.max_frozen = max(1, int(max_frozen))
        self.tombstone_trigger = tombstone_trigger
        self._heap: list[tuple] = []
        self._tick = itertools.count()
        self._run_lock = threading.RLock()
        # (level uid -> range-delete count at stamp time): proactive
        # outputs are not re-candidates until new range deletes arrive,
        # which caps the estimator's false-positive floor at one
        # compaction instead of an unbounded walk down the tree.
        self._proactive_stamp: dict[int, int] = {}
        self._proactive_seen = (-1, -1)  # (rdel count, struct epoch)
        # Counters (surfaced as ``sched.*`` metrics).
        self.flush_jobs = 0
        self.cascade_jobs = 0
        self.proactive_jobs = 0
        self.stall_count = 0
        self.stall_seconds = 0.0
        self.max_queue_depth = 0

    # ------------------------------------------------------------ queue
    def _push(self, klass: int, score, kind: str, level: int) -> None:
        heapq.heappush(self._heap, (klass, score, next(self._tick),
                                    kind, level))
        self.max_queue_depth = max(self.max_queue_depth, len(self._heap))

    def queue_depth(self) -> int:
        return len(self._heap)

    def compaction_debt(self) -> int:
        """Pending background work: queued jobs + unflushed snapshots."""
        return len(self._heap) + len(self.tree.frozen)

    def has_work(self) -> bool:
        return bool(self._heap) or bool(self.tree.frozen) or \
            self._proactive_due()

    # ------------------------------------------------------------ seal
    def on_seal(self) -> None:
        """A memtable was just frozen: enqueue its flush; apply the
        soft-limit backpressure if the backlog is past ``max_frozen``."""
        self._push(JOB_FLUSH, 0.0, "flush", -1)
        if len(self.tree.frozen) > self.max_frozen:
            t0 = time.perf_counter()
            with span("sched.stall", frozen=len(self.tree.frozen),
                      limit=self.max_frozen):
                while (self.tree.frozen and
                       len(self.tree.frozen) > self.max_frozen):
                    if not self._run_one():
                        break
            self.stall_count += 1
            self.stall_seconds += time.perf_counter() - t0

    # ------------------------------------------------------- execution
    def run_due(self) -> int:
        """Execute every queued job plus due proactive compactions.

        Called at the deterministic drain points (plan start, engine
        drain/flush/close/stats).  Returns the number of jobs run.
        """
        if not self._heap and not self._proactive_due():
            return 0
        ran = 0
        with self._run_lock:
            while self._run_one():
                ran += 1
            ran += self._run_proactive()
        return ran

    def drain(self) -> int:
        """Synchronously run until no queued work remains (explicit
        flush/close semantics: a FLUSH ack implies the background flush
        durably published)."""
        with self._run_lock:
            ran = self.run_due()
            # A flush can enqueue cascades; loop until quiescent.
            while self._heap:
                ran += self.run_due()
        return ran

    def _run_one(self) -> bool:
        """Pop and execute the highest-priority job; False when idle."""
        with self._run_lock:
            if not self._heap:
                return False
            klass, score, _, kind, level = heapq.heappop(self._heap)
            if kind == "flush":
                self._job_flush()
            else:
                self._job_compact(level, kind)
            return True

    def _job_flush(self) -> None:
        tree = self.tree
        if not tree.frozen:
            return
        fz = tree.frozen[0]
        with span("sched.flush", entries=len(fz),
                  range_tombstones=len(fz.rts),
                  backlog=len(tree.frozen)):
            tree._flush_frozen_one()
        self.flush_jobs += 1
        self._enqueue_overflows()

    def _job_compact(self, level: int, kind: str) -> None:
        tree = self.tree
        if level >= len(tree.levels):
            return
        lvl = tree.levels[level]
        if lvl is None or len(lvl) == 0:
            return
        over = len(lvl) > tree.config.level_capacity(level)
        if kind == "cascade" and not over:
            return  # stale: another job already compacted it
        with span("sched.compact", level=level, entries=len(lvl),
                  reason=kind):
            tree._compact(level)
        if kind == "cascade":
            self.cascade_jobs += 1
        else:
            self.proactive_jobs += 1
            merged = (tree.levels[level + 1]
                      if level + 1 < len(tree.levels) else None)
            if merged is not None and len(merged):
                self._proactive_stamp[merged.uid] = self._rdel_count()
        self._enqueue_overflows()

    def _enqueue_overflows(self) -> None:
        """Queue a CASCADE job per overflowing level (ascending, like
        the inline cascade; in practice at most one level overflows at
        any instant, so the order is forced either way)."""
        tree = self.tree
        queued = {(e[3], e[4]) for e in self._heap}
        for i, lvl in enumerate(tree.levels):
            if lvl is not None and len(lvl) > tree.config.level_capacity(i):
                if ("cascade", i) not in queued:
                    ratio = len(lvl) / tree.config.level_capacity(i)
                    self._push(JOB_CASCADE, (float(i), -ratio),
                               "cascade", i)

    # ------------------------------------------------------- proactive
    def _rdel_count(self) -> int:
        tree = self.tree
        if tree.strategy == "gloran" and tree.gloran is not None:
            return int(tree.gloran.num_range_deletes)
        if tree.strategy == "lrr":
            return int(sum(len(r) for r in tree.level_rts) +
                       len(tree.mem_rts) +
                       sum(len(f.rts) for f in tree.frozen))
        return 0

    def _proactive_due(self) -> bool:
        """Cheap gate: only re-evaluate densities when range deletes or
        the level structure moved since the last evaluation."""
        if self.tombstone_trigger is None:
            return False
        now = (self._rdel_count(), self.tree.struct_epoch)
        return now != self._proactive_seen

    def _run_proactive(self) -> int:
        if not self._proactive_due():
            return 0
        tree = self.tree
        ran = 0
        for _ in range(_PROACTIVE_PER_KICK):
            best = None
            rdels = self._rdel_count()
            for i, lvl in enumerate(tree.levels):
                if lvl is None or len(lvl) == 0:
                    continue
                if self._proactive_stamp.get(lvl.uid) == rdels:
                    continue  # our own output; no new deletes since
                density = level_rt_density(tree, i)
                if density < self.tombstone_trigger:
                    continue
                ratio = len(lvl) / tree.config.level_capacity(i)
                score = (-density, -ratio)
                if best is None or score < best[0]:
                    best = (score, i, density)
            if best is None:
                break
            _, i, density = best
            self._push(JOB_PROACTIVE, best[0], "proactive", i)
            self._run_one()
            ran += 1
        self._proactive_seen = (self._rdel_count(), tree.struct_epoch)
        return ran

    # ------------------------------------------------------------ misc
    def counters(self) -> dict:
        return {
            "flush_jobs": self.flush_jobs,
            "cascade_jobs": self.cascade_jobs,
            "proactive_jobs": self.proactive_jobs,
            "stall_count": self.stall_count,
            "stall_seconds": round(self.stall_seconds, 6),
            "queue_depth": len(self._heap),
            "max_queue_depth": self.max_queue_depth,
            "frozen": len(self.tree.frozen),
            "compaction_debt": self.compaction_debt(),
        }
