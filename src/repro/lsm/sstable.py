"""Sorted runs ("SSTables") with blocks, fence pointers and Bloom filters.

A run is a struct-of-arrays (keys, seqs, types, vals) sorted by key with
unique keys (leveling keeps one version per key per level; recency across
levels resolves versions).  Fence pointers (first key of each B-byte block)
live in memory; each point lookup that passes the Bloom filter costs one
block I/O, matching §2's cost model.
"""

from __future__ import annotations

import itertools
import math

import numpy as np

from ..core.areas import AreaSet
from ..core.disjointize import disjointize
from ..core.eve import BloomBits
from ..core.iostats import IOStats
from .format import LSMConfig, PUT, TOMBSTONE


_RUN_UID = itertools.count(1)


class SSTable:
    def __init__(self, keys: np.ndarray, seqs: np.ndarray, types: np.ndarray,
                 vals: np.ndarray, config: LSMConfig, seed: int = 0):
        assert len(keys) == len(seqs) == len(types) == len(vals)
        assert np.all(keys[:-1] < keys[1:]), "run must be sorted, unique"
        # Process-unique run id: block caches key cached blocks on
        # (uid, block), so entries of compacted-away runs age out safely.
        self.uid = next(_RUN_UID)
        self.keys = keys.astype(np.uint64, copy=False)
        self.seqs = seqs.astype(np.uint64, copy=False)
        self.types = types.astype(np.uint8, copy=False)
        self.vals = vals.astype(np.uint64, copy=False)
        self.config = config
        # Recorded so snapshots can rebuild this exact run (arrays +
        # seed fully determine the filter) on restore.
        self.seed = int(seed)
        n = len(keys)
        self.bloom = BloomBits(max(64, n * config.bloom_bits_per_key),
                               config.bloom_hashes, seed=seed or 17)
        if n:
            self.bloom.insert(self.keys)
        self.min_seq = int(self.seqs.min()) if n else 0
        self.max_seq = int(self.seqs.max()) if n else 0

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        return len(self.keys) * self.config.entry_size

    @property
    def max_key(self) -> int:
        """Largest key in the run (0 when empty) — the u32-eligibility
        gate for device-resident packed views of this run."""
        return int(self.keys[-1]) if len(self.keys) else 0

    def data_blocks(self) -> int:
        return math.ceil(len(self.keys) / self.config.entries_per_block)

    # ------------------------------------------------------------- lookups
    def get(self, key: int, io: IOStats | None = None):
        """Returns (found, seq, type, val). Charges 1 I/O on Bloom pass."""
        key = np.uint64(key)
        if len(self.keys) == 0:
            return (False, 0, PUT, 0)
        if not bool(self.bloom.might_contain(key)[0]):
            return (False, 0, PUT, 0)
        if io is not None:
            io.read_blocks(1, tag="data_block")  # fence pointer -> 1 block
        i = int(np.searchsorted(self.keys, key))
        if i < len(self.keys) and self.keys[i] == key:
            return (True, int(self.seqs[i]), self.types[i], int(self.vals[i]))
        return (False, 0, PUT, 0)

    def get_batch(self, keys: np.ndarray, io: IOStats | None = None, *,
                  cache=None, maybe: np.ndarray | None = None):
        """Vectorized point lookups.

        Returns (found, seqs, types, vals); charges one block I/O per key
        that passes the Bloom filter (fence pointers are in memory).
        ``maybe`` optionally supplies a precomputed filter verdict (e.g.
        from the Pallas bloom kernel — bit-exact with the host filter);
        ``cache`` is an optional read-through block cache: block reads it
        already holds are not charged."""
        keys = np.asarray(keys, dtype=np.uint64)
        n = len(keys)
        found = np.zeros(n, dtype=bool)
        seqs = np.zeros(n, dtype=np.uint64)
        types = np.zeros(n, dtype=np.uint8)
        vals = np.zeros(n, dtype=np.uint64)
        if len(self.keys) == 0 or n == 0:
            return found, seqs, types, vals
        if maybe is None:
            maybe = self.bloom.might_contain(keys)
        idx = np.searchsorted(self.keys, keys[maybe])
        idxc = np.minimum(idx, len(self.keys) - 1)
        self.charge_probe(idxc, io, cache=cache)
        hit = self.keys[idxc] == keys[maybe]
        sub = np.flatnonzero(maybe)[hit]
        found[sub] = True
        seqs[sub] = self.seqs[idxc[hit]]
        types[sub] = self.types[idxc[hit]]
        vals[sub] = self.vals[idxc[hit]]
        return found, seqs, types, vals

    def charge_probe(self, pos: np.ndarray, io: IOStats | None = None, *,
                     cache=None) -> None:
        """Charge the data-block reads of filter-passing point probes.

        ``pos`` holds the candidate entry index of every probe that
        passed this run's Bloom filter (the fence-pointer search result,
        e.g. the fused cascade kernel's per-level output) — exactly the
        indices ``get_batch`` derives before charging, so the charges
        are identical: one block per probe, or only cache-missed blocks
        when a read-through ``cache`` absorbs them.
        """
        if io is None or len(pos) == 0:
            return
        if cache is not None:
            blocks = pos // self.config.entries_per_block
            hits = cache.probe_many(self.uid, blocks)
            io.read_blocks(int((~hits).sum()), tag="data_block")
        else:
            io.read_blocks(len(pos), tag="data_block")

    def rows_at(self, pos: np.ndarray):
        """Gather (seqs, types, vals) at known entry positions — the
        data-block payload step of a mask-driven lookup, after
        ``charge_probe`` paid for the reads."""
        return self.seqs[pos], self.types[pos], self.vals[pos]

    def range_slice(self, lo: int, hi: int, io: IOStats | None = None):
        """Entries with lo <= key < hi; charges sequential block reads."""
        lo_i = int(np.searchsorted(self.keys, np.uint64(lo)))
        hi_i = int(np.searchsorted(self.keys, np.uint64(hi)))
        cnt = hi_i - lo_i
        if io is not None and cnt > 0:
            io.read_blocks(
                1 + (cnt * self.config.entry_size) // self.config.block_size,
                tag="range_scan")
        sl = slice(lo_i, hi_i)
        return (self.keys[sl], self.seqs[sl], self.types[sl], self.vals[sl])

    def range_slice_many(self, los: np.ndarray, his: np.ndarray,
                         io: IOStats | None = None, *,
                         cache=None) -> list[tuple]:
        """One ``range_slice`` per [lo, hi) pair, with the slice bounds
        and the sequential-read charges computed vectorized across the
        whole batch (charges are identical to per-call ``range_slice``).

        ``cache`` is an optional read-through block cache: instead of the
        flat sequential-read formula, each scan charges exactly the data
        blocks its slice touches that the cache does not already hold —
        so repeated scans of hot slabs stop paying I/O, same as point
        lookups (scan-resident blocks are admitted read-through)."""
        lo_i = np.searchsorted(self.keys, np.asarray(los, np.uint64))
        hi_i = np.searchsorted(self.keys, np.asarray(his, np.uint64))
        cnts = hi_i - lo_i
        if io is not None and cnts.any():
            if cache is not None:
                epb = self.config.entries_per_block
                misses = 0
                for a, b in zip(lo_i.tolist(), hi_i.tolist()):
                    if b <= a:
                        continue
                    blocks = np.arange(a // epb, (b - 1) // epb + 1)
                    hits = cache.probe_many(self.uid, blocks)
                    misses += int((~hits).sum())
                io.read_blocks(misses, tag="range_scan")
            else:
                nz = cnts[cnts > 0]
                io.read_blocks(
                    int((1 + (nz * self.config.entry_size) //
                         self.config.block_size).sum()), tag="range_scan")
        return [(self.keys[a:b], self.seqs[a:b], self.types[a:b],
                 self.vals[a:b]) for a, b in zip(lo_i.tolist(),
                                                 hi_i.tolist())]


class RangeTombstoneBlock:
    """Per-level range-tombstone block (the LRR / RocksDB design, §3).

    Tombstones (start, end, seq) are sorted by start key.  A probe for key v
    must retrieve every tombstone whose start <= v (variable range lengths
    prevent pruning): 1 I/O for the first page plus sequential reads —
    exactly Eq. (1)'s ``1 + cnt * 2k / B`` term.
    """

    def __init__(self, starts, ends, seqs, config: LSMConfig):
        order = np.argsort(starts, kind="stable")
        self.starts = np.asarray(starts, dtype=np.uint64)[order]
        self.ends = np.asarray(ends, dtype=np.uint64)[order]
        self.seqs = np.asarray(seqs, dtype=np.uint64)[order]
        self.config = config
        self._stab: tuple | None = None  # lazy disjoint step function

    @staticmethod
    def empty(config: LSMConfig) -> "RangeTombstoneBlock":
        z = np.zeros(0, dtype=np.uint64)
        return RangeTombstoneBlock(z, z.copy(), z.copy(), config)

    def __len__(self) -> int:
        return len(self.starts)

    @property
    def nbytes(self) -> int:
        return len(self.starts) * self.config.range_tombstone_size

    def _step_fn(self) -> tuple:
        """Disjoint max-seq step function over the tombstones (lazy).

        Reuses the paper's disjointization (§4.2, ``core.disjointize``):
        tombstone (start, end, seq) is the effective area [start, end) x
        [0, seq), and disjointizing the set yields key-disjoint segments
        whose ``smax`` is exactly the max covering seq — so each probe is
        one ``searchsorted`` over segment starts instead of an
        O(keys x tombstones) cover mask.  Blocks are immutable (merges
        build new ones), so the function is computed once per block.
        """
        if self._stab is None:
            s = disjointize(AreaSet(self.starts, self.ends,
                                    np.zeros(len(self.starts), np.uint64),
                                    self.seqs))
            self._stab = (s.lo, s.hi, s.smax)
        return self._stab

    def probe(self, key: int, io: IOStats | None = None) -> int:
        """Max tombstone seq covering ``key`` (0 if none). Charges the
        paper's probe cost."""
        if len(self.starts) == 0:
            return 0
        return int(self.probe_batch(np.asarray([key], np.uint64),
                                    io=io)[0])

    def probe_batch(self, keys: np.ndarray,
                    io: IOStats | None = None) -> np.ndarray:
        """Vectorized probe: max covering seq per key.

        I/O charges are the per-key retrieval cost of Eq. (1) — every
        tombstone with start <= key streams in — while the verdict comes
        from the disjoint step function (O(log tombstones) per key).
        """
        keys = np.asarray(keys, dtype=np.uint64)
        if len(self.starts) == 0:
            if io is not None and len(keys):
                io.read_blocks(len(keys), tag="rt_block")
            return np.zeros(len(keys), dtype=np.uint64)
        if io is not None:
            cnts = np.searchsorted(self.starts, keys, side="right")
            ios = 1 + (cnts * self.config.range_tombstone_size) // \
                self.config.block_size
            io.read_blocks(int(ios.sum()), tag="rt_block")
        lo, hi, smax = self._step_fn()
        i = np.searchsorted(lo, keys, side="right").astype(np.int64) - 1
        ic = np.maximum(i, 0)
        cov = (i >= 0) & (keys < hi[ic])
        return np.where(cov, smax[ic], np.uint64(0)).astype(np.uint64)

    def merge(self, other: "RangeTombstoneBlock") -> "RangeTombstoneBlock":
        return RangeTombstoneBlock(
            np.concatenate([self.starts, other.starts]),
            np.concatenate([self.ends, other.ends]),
            np.concatenate([self.seqs, other.seqs]), self.config)

    def max_covering_batch(self, keys: np.ndarray) -> np.ndarray:
        return self.probe_batch(keys, io=None)


def build_sstable(keys, seqs, types, vals, config: LSMConfig,
                  io: IOStats | None = None, seed: int = 0,
                  presorted: bool = False) -> SSTable:
    """Sort + dedup (keep the newest version per key) and charge the
    sequential write I/O of the run.

    ``presorted=True`` skips the lexsort for input that is already
    key-sorted with duplicate keys adjacent (a memtable's cached
    columnar snapshot, or a two-run sorted-view merge): dedup resolves
    each adjacent group to its max-seq entry, which — sequence numbers
    being unique — selects exactly the rows the lexsort path keeps, so
    the built run (bloom bits included: same key set, same seed) is
    byte-identical either way.
    """
    keys = np.asarray(keys, dtype=np.uint64)
    seqs = np.asarray(seqs, dtype=np.uint64)
    types = np.asarray(types, dtype=np.uint8)
    vals = np.asarray(vals, dtype=np.uint64)
    if presorted:
        n = len(keys)
        if n:
            new_grp = np.empty(n, dtype=bool)
            new_grp[0] = True
            np.not_equal(keys[1:], keys[:-1], out=new_grp[1:])
            if not new_grp.all():  # duplicate keys across merged runs
                starts = np.flatnonzero(new_grp)
                grp_max = np.maximum.reduceat(seqs, starts)
                gid = np.cumsum(new_grp) - 1
                keep = seqs == grp_max[gid]
                keys, seqs, types, vals = (keys[keep], seqs[keep],
                                           types[keep], vals[keep])
    else:
        # Sort by (key, seq); the last duplicate of each key is the
        # newest.
        order = np.lexsort((seqs, keys))
        keys, seqs, types, vals = (keys[order], seqs[order], types[order],
                                   vals[order])
        last = np.ones(len(keys), dtype=bool)
        last[:-1] = keys[1:] != keys[:-1]
        keys, seqs, types, vals = (keys[last], seqs[last], types[last],
                                   vals[last])
    t = SSTable(keys, seqs, types, vals, config, seed=seed)
    if io is not None:
        io.write_sequential(t.nbytes, tag="flush_or_compact")
    return t
