"""LSM-tree key-value substrate with simulated-I/O accounting."""

from .format import LSMConfig, PUT, TOMBSTONE
from .sstable import RangeTombstoneBlock, SSTable, build_sstable
from .tree import CascadeVerdict, LSMTree, STRATEGIES

__all__ = ["LSMConfig", "PUT", "TOMBSTONE", "RangeTombstoneBlock", "SSTable",
           "build_sstable", "CascadeVerdict", "LSMTree", "STRATEGIES"]
