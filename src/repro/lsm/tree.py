"""LSM-tree key-value store with pluggable range-delete strategies.

Leveling configuration (one sorted run per level, size ratio T), following
§2: memtable of F entries, Bloom filter + fence pointers per run, point
tombstones, compaction cascades.  Range deletes dispatch to one of:

  decomp        tombstone per key in the range (the naive Delete loop)
  lookup_delete Get each key, Delete the ones that exist
  scan_delete   iterator scan, Delete found keys
  lrr           local range records: per-level range-tombstone blocks
                (RocksDB DeleteRange; the paper's SOTA baseline)
  gloran        this paper: global LSM-DRtree index + EVE

Every operation charges simulated block I/Os to ``self.io`` per the paper's
cost model; benchmarks report those counts alongside wall time.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from itertools import repeat

import numpy as np

from ..core.gloran import GloranConfig, GloranIndex
from ..core.iostats import IOStats
from ..obs import span
from .format import LSMConfig, PUT, TOMBSTONE
from .merge import empty_run, merge_runs, merge_two, newest_wins
from .scheduler import FrozenMemtable
from .sstable import RangeTombstoneBlock, SSTable, build_sstable

STRATEGIES = ("decomp", "lookup_delete", "scan_delete", "lrr", "gloran")


@dataclass
class CascadeVerdict:
    """One fused-launch answer to a lookup batch's filter questions.

    Produced by an execution layer's ``cascade_fn`` hook (the engine's
    device-resident cascade kernel) and consumed by ``get_batch``'s
    mask-driven level loop: per packed level, Bloom verdicts, exact-key
    hits, and the candidate entry position whose block a surviving probe
    reads; plus (GLORAN only) per-index-level coverage of (key, resolved
    seq).  The tree replays its own control flow — unresolved-only
    probing, first-hit resolution, validity early-exit — around these
    verdicts, so results and I/O charges are identical to computing each
    stage on the host.
    """

    slots: np.ndarray          # tree level index -> packed column (-1 none)
    maybe: np.ndarray          # (n, L) bool: Bloom pass per packed level
    hit: np.ndarray            # (n, L) bool: exact key match per level
    pos: np.ndarray            # (n, L) int64: level-local candidate index
    gl_cov: np.ndarray | None  # (n, G) bool: GLORAN level coverage


class LSMTree:
    def __init__(self, config: LSMConfig | None = None,
                 strategy: str = "gloran",
                 gloran_config: GloranConfig | None = None):
        assert strategy in STRATEGIES, strategy
        self.config = config or LSMConfig()
        self.strategy = strategy
        self.io = IOStats(block_size=self.config.block_size)
        self.mem: dict[int, tuple[int, int, int]] = {}  # key->(seq,type,val)
        self._mem_snap = None  # cached sorted snapshot; None = stale
        self.mem_rts: list[tuple[int, int, int]] = []  # LRR buffer
        self.levels: list[SSTable | None] = []
        self.level_rts: list[RangeTombstoneBlock] = []
        self.seq = 0
        self.gloran = None
        if strategy == "gloran":
            self.gloran = GloranIndex(gloran_config, io=self.io)
        self._sstable_seed = 0
        # Background mode (see lsm/scheduler.py): with a scheduler
        # attached, a full memtable SEALS into ``frozen`` (oldest first)
        # instead of flushing inline; reads serve active + frozen[] +
        # levels.  ``scheduler is None`` keeps the inline path
        # byte-identical — ``frozen`` stays empty and every guard below
        # short-circuits.
        self.frozen: list[FrozenMemtable] = []
        self.scheduler = None
        # Structural epoch + publish lock: every seal / level publish
        # bumps the epoch under the lock so out-of-band readers (stats,
        # registry views) can snapshot a consistent level set while a
        # drain point runs jobs on another thread.
        self.struct_epoch = 0
        self._struct_lock = threading.RLock()
        # Optional merge-rank hook for compactions (the engine installs
        # its gated Pallas merge-rank closure); None = host searchsorted.
        self.compaction_rank_fn = None
        # Per-level compaction observability (satellite of the
        # scheduler work): bytes moved compacting INTO each level and
        # range-tombstone bytes rewritten per level, surfaced as
        # ``lsm.compaction.bytes.L<i>`` / ``lsm.rt_compaction.bytes.L<i>``
        # in engine.stats().
        self.compaction_bytes: dict[int, int] = {}
        self.rt_compaction_bytes: dict[int, int] = {}

    # ------------------------------------------------------------ helpers
    def _next_seq(self) -> int:
        self.seq += 1
        return self.seq

    def _next_seqs(self, n: int) -> np.ndarray:
        out = np.arange(self.seq + 1, self.seq + n + 1, dtype=np.uint64)
        self.seq += n
        return out

    def _mem_put(self, key: int, seq: int, typ: int, val: int) -> None:
        self.mem[int(key)] = (int(seq), int(typ), int(val))
        self._mem_snap = None
        if len(self.mem) >= self.config.buffer_capacity:
            self.flush()

    # ------------------------------------------------------------- writes
    def put(self, key: int, val: int) -> None:
        self._mem_put(key, self._next_seq(), int(PUT), val)

    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.uint64)
        self._mem_insert_batch(keys, self._next_seqs(len(keys)),
                               int(PUT), vals)

    def delete(self, key: int) -> None:
        self._mem_put(key, self._next_seq(), int(TOMBSTONE), 0)

    def delete_batch(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        self._mem_insert_batch(keys, self._next_seqs(len(keys)),
                               int(TOMBSTONE), None)

    def _mem_insert_batch(self, keys: np.ndarray, seqs: np.ndarray,
                          typ: int, vals: np.ndarray | None) -> None:
        """Bulk memtable absorb, chunked at flush boundaries.

        Each chunk is one ``dict.update`` of at most the remaining
        buffer room, so the memtable can only reach capacity exactly at
        a chunk end: within a chunk the entry count grows by at most one
        per record and starts at least ``room`` below capacity, hence a
        per-record loop could not have flushed mid-chunk either.  Flush
        points (and therefore run shapes and I/O) are identical to
        per-record inserts; later duplicates win inside a chunk exactly
        as sequential overwrites would.
        """
        n = len(keys)
        kk = keys.tolist()
        ss = seqs.tolist()
        vv = vals.tolist() if vals is not None else None
        self._mem_snap = None
        at = 0
        while at < n:
            room = self.config.buffer_capacity - len(self.mem)
            take = min(max(room, 1), n - at)
            end = at + take
            payload = repeat(0, take) if vv is None else vv[at:end]
            self.mem.update(zip(kk[at:end],
                                zip(ss[at:end], repeat(typ, take),
                                    payload)))
            at = end
            if len(self.mem) >= self.config.buffer_capacity:
                self.flush()

    def range_delete(self, lo: int, hi: int) -> None:
        """Delete all keys in [lo, hi) using the configured strategy."""
        assert lo < hi
        if self.strategy == "decomp":
            self.delete_batch(np.arange(lo, hi, dtype=np.uint64))
        elif self.strategy == "lookup_delete":
            keys = np.arange(lo, hi, dtype=np.uint64)
            found, _ = self.get_batch(keys)
            if found.any():
                self.delete_batch(keys[found])
        elif self.strategy == "scan_delete":
            keys, _ = self.range_scan(lo, hi)
            if len(keys):
                self.delete_batch(keys)
        elif self.strategy == "lrr":
            self.mem_rts.append((int(lo), int(hi), self._next_seq()))
            # Range tombstones are memtable entries (RocksDB): they count
            # toward the buffer and flush with it.
            if len(self.mem) + len(self.mem_rts) >= \
                    self.config.buffer_capacity:
                self.flush()
        else:  # gloran
            self.gloran.range_delete(lo, hi, self._next_seq())

    def range_delete_batch(self, ranges) -> None:
        """Apply a batch of [lo, hi) range deletes in request order
        (tuple convenience over the columnar ``range_delete_arrays``)."""
        ranges = list(ranges)
        if not ranges:
            return
        self.range_delete_arrays(
            np.asarray([r[0] for r in ranges], dtype=np.uint64),
            np.asarray([r[1] for r in ranges], dtype=np.uint64))

    def range_delete_arrays(self, los: np.ndarray, his: np.ndarray) -> None:
        """Columnar batch range delete: two flat bound arrays, request
        order.

        Under GLORAN the whole batch stays columnar end-to-end — one
        call into the global index whose staging buffer absorbs it as
        vectorized appends (sequence numbers assigned in order, flush
        points identical to per-call deletes, estimator inserts
        vectorized); the other strategies apply their per-range write
        paths sequentially.
        """
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        if len(los) == 0:
            return
        if self.strategy == "gloran":
            assert (los < his).all()
            self.gloran.range_delete_batch(los, his,
                                           self._next_seqs(len(los)))
        else:
            for lo, hi in zip(los.tolist(), his.tolist()):
                self.range_delete(int(lo), int(hi))

    # -------------------------------------------------------------- reads
    def _mem_rt_cover(self, key: int) -> int:
        cov = 0
        for lo, hi, s in self.mem_rts:
            if lo <= key < hi:
                cov = max(cov, s)
        return cov

    def get(self, key: int):
        """Point lookup; returns value or None."""
        key = int(key)
        rt_max = self._mem_rt_cover(key) if self.strategy == "lrr" else 0
        hit = self.mem.get(key)
        if hit is not None:
            seq, typ, val = hit
            return self._resolve(key, seq, typ, val, rt_max)
        if self.frozen:
            # Sealed snapshots sit between the active memtable and the
            # levels: newest first, memory-resident (no I/O charge).
            # Seal boundaries are temporal (sequence numbers only grow),
            # so accumulating EVERY frozen range tombstone before
            # probing data is exact — an older tombstone's seq can
            # never exceed a newer entry's.
            if self.strategy == "lrr":
                for fz in self.frozen:
                    for lo, hi, s in fz.rts:
                        if lo <= key < hi:
                            rt_max = max(rt_max, s)
            for fz in reversed(self.frozen):
                if not len(fz.keys):
                    continue
                j = int(np.searchsorted(fz.keys, np.uint64(key)))
                if j < len(fz.keys) and fz.keys[j] == key:
                    return self._resolve(key, int(fz.seqs[j]),
                                         int(fz.types[j]),
                                         int(fz.vals[j]), rt_max)
        for i, lvl in enumerate(self.levels):
            if self.strategy == "lrr" and i < len(self.level_rts) and \
                    len(self.level_rts[i]):
                rt_max = max(rt_max, self.level_rts[i].probe(key, self.io))
            if lvl is None or len(lvl) == 0:
                continue
            found, seq, typ, val = lvl.get(key, self.io)
            if found:
                return self._resolve(key, seq, typ, val, rt_max)
        return None

    def _resolve(self, key, seq, typ, val, rt_max):
        if typ == TOMBSTONE:
            return None
        if self.strategy == "lrr" and rt_max > seq:
            return None
        if self.strategy == "gloran" and self.gloran.is_deleted(key, seq):
            return None
        return val

    def get_batch(self, keys: np.ndarray, *, cache=None, bloom_fn=None,
                  validity_fn=None, cascade_fn=None):
        """Vectorized point lookups. Returns (found_mask, values).

        Optional hooks let an execution layer swap HOW a stage computes
        without forking the read path (``repro.engine`` uses these for
        its Pallas kernels and block cache): ``bloom_fn(sstable, keys)``
        supplies filter verdicts, ``cache`` absorbs data-block charges,
        ``validity_fn(keys, seqs)`` replaces the GLORAN validity probe,
        and ``cascade_fn(keys, resolved, seqs)`` answers EVERY level's
        filter questions in one fused launch (a ``CascadeVerdict``, or
        None to decline).  With a cascade verdict the level loop below
        only charges/reads data blocks for filter survivors — levels
        with zero survivors are skipped without being touched — and the
        GLORAN probe replays charging around the fused per-level
        coverage bits; results and I/O are identical either way.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        n = len(keys)
        resolved = np.zeros(n, dtype=bool)
        out_found = np.zeros(n, dtype=bool)
        out_vals = np.zeros(n, dtype=np.uint64)
        out_seqs = np.zeros(n, dtype=np.uint64)
        rt_max = np.zeros(n, dtype=np.uint64)

        if self.strategy == "lrr" and self.mem_rts:
            for lo, hi, s in self.mem_rts:
                m = (keys >= lo) & (keys < hi)
                rt_max[m] = np.maximum(rt_max[m], np.uint64(s))

        # Memtable: one sorted snapshot + batched binary search (skipped
        # entirely when empty — the steady post-flush state of
        # read-mostly serving).
        if self.mem:
            mk, ms, mt, mv = self._mem_sorted()
            j = np.minimum(np.searchsorted(mk, keys), len(mk) - 1)
            hitm = mk[j] == keys
            jh = j[hitm]
            resolved[hitm] = True
            out_found[hitm] = mt[jh] == PUT
            out_seqs[hitm] = ms[jh]
            out_vals[hitm] = mv[jh]

        # Sealed (frozen) memtables, newest first: memory-resident
        # sorted snapshots probed with the same batched binary search,
        # no I/O charge.  Frozen LRR tombstones fold into rt_max up
        # front — seal boundaries are temporal, so the superset is
        # exact (an older tombstone can't outrank a newer entry).
        if self.frozen:
            if self.strategy == "lrr":
                for fz in self.frozen:
                    for lo, hi, s in fz.rts:
                        m = (keys >= lo) & (keys < hi)
                        rt_max[m] = np.maximum(rt_max[m], np.uint64(s))
            for fz in reversed(self.frozen):
                if not len(fz.keys):
                    continue
                todo = ~resolved
                if not todo.any():
                    break
                sub = keys[todo]
                j = np.minimum(np.searchsorted(fz.keys, sub),
                               len(fz.keys) - 1)
                hitm = fz.keys[j] == sub
                idx = np.flatnonzero(todo)[hitm]
                jh = j[hitm]
                resolved[idx] = True
                out_found[idx] = fz.types[jh] == PUT
                out_seqs[idx] = fz.seqs[jh]
                out_vals[idx] = fz.vals[jh]

        # One fused launch answers bloom + fence + GLORAN for all
        # levels; the loop below replays resolution order around it.
        cas = None
        if cascade_fn is not None and not resolved.all():
            cas = cascade_fn(keys, resolved, out_seqs)

        for i, lvl in enumerate(self.levels):
            todo = ~resolved
            if not todo.any():
                break
            if self.strategy == "lrr" and i < len(self.level_rts) and \
                    len(self.level_rts[i]):
                rt_max[todo] = np.maximum(
                    rt_max[todo],
                    self.level_rts[i].probe_batch(keys[todo], self.io))
            if lvl is None or len(lvl) == 0:
                continue
            if cas is not None:
                sl = int(cas.slots[i])
                maybe = cas.maybe[todo, sl]
                if not maybe.any():
                    continue  # zero survivors: level skipped untouched
                pos = cas.pos[todo, sl][maybe]
                lvl.charge_probe(pos, self.io, cache=cache)
                hitk = cas.hit[todo, sl][maybe]
                sel = pos[hitk]
                idx = np.flatnonzero(todo)[np.flatnonzero(maybe)[hitk]]
                s, t, v = lvl.rows_at(sel)
            else:
                sub = keys[todo]
                f, s, t, v = lvl.get_batch(
                    sub, self.io, cache=cache,
                    maybe=bloom_fn(lvl, sub) if bloom_fn is not None
                    else None)
                idx = np.flatnonzero(todo)[f]
                s, t, v = s[f], t[f], v[f]
            resolved[idx] = True
            out_found[idx] = t == PUT
            out_seqs[idx] = s
            out_vals[idx] = v

        # Validity filtering.
        if self.strategy == "lrr":
            dead = out_found & (rt_max > out_seqs)
            out_found &= ~dead
        elif self.strategy == "gloran":
            cand = out_found
            if cand.any():
                if cas is not None and cas.gl_cov is not None:
                    dead = self.gloran.is_deleted_batch(
                        keys[cand], out_seqs[cand],
                        level_cov=cas.gl_cov[cand])
                else:
                    is_dead = validity_fn or self.gloran.is_deleted_batch
                    dead = is_dead(keys[cand], out_seqs[cand])
                sub = np.flatnonzero(cand)[dead]
                out_found[sub] = False
        return out_found, out_vals

    def _mem_sorted(self):
        """Key-sorted snapshot of the memtable as a 4-array run, cached
        until the next memtable mutation so read bursts between writes
        (many lookup/scan batches against one buffered state) pay the
        O(m log m) sort once, not per batch."""
        if self._mem_snap is not None:
            return self._mem_snap
        m = len(self.mem)
        if m == 0:
            return empty_run()
        keys = np.fromiter(self.mem.keys(), np.uint64, m)
        rows = np.array(list(self.mem.values()), dtype=np.uint64)
        order = np.argsort(keys)
        self._mem_snap = (keys[order], rows[order, 0],
                          rows[order, 1].astype(np.uint8), rows[order, 2])
        return self._mem_snap

    def range_scan(self, lo: int, hi: int, *, validity_fn=None,
                   cache=None, rank_fn=None):
        """All live entries with lo <= key < hi. Returns (keys, vals)."""
        return self.range_scan_batch([(lo, hi)], validity_fn=validity_fn,
                                     cache=cache, rank_fn=rank_fn)[0]

    def range_scan_batch(self, ranges, *, validity_fn=None, cache=None,
                         rank_fn=None):
        """Execute many range scans in one pass over the tree.

        Each [lo, hi) produces the same (keys, vals) pair a per-call
        ``range_scan`` would, but the shared work is batched: the
        memtable is snapshotted/sorted once, per-level slice bounds and
        sequential-read charges are computed vectorized across all
        ranges, each range's slices are combined with a REMIX-style
        sorted-view merge (no per-scan lexsort), and LRR/GLORAN validity
        filtering runs once over the concatenated candidates of every
        range.  ``validity_fn(keys, seqs) -> dead mask`` optionally
        replaces the GLORAN probe (``repro.engine`` supplies the Pallas
        interval-kernel path), exactly like ``get_batch``; ``cache``
        optionally absorbs the data-block charges of each level's slices
        (scan-resident blocks stop paying I/O, see
        ``SSTable.range_slice_many``); ``rank_fn`` optionally replaces
        how each two-way merge round computes output positions
        (``repro.engine`` supplies the Pallas merge-rank kernel — see
        ``lsm.merge.merge_two``).
        """
        ranges = [(int(lo), int(hi)) for lo, hi in ranges]
        nr = len(ranges)
        if nr == 0:
            return []
        los = np.array([r[0] for r in ranges], dtype=np.uint64)
        his = np.array([r[1] for r in ranges], dtype=np.uint64)
        mem = self._mem_sorted()
        m_lo = np.searchsorted(mem[0], los)
        m_hi = np.searchsorted(mem[0], his)
        # Frozen snapshots contribute one memory-resident slice each
        # (no I/O, like the active memtable); newest_wins resolves
        # versions by seq, so part order is immaterial.
        per_frozen = [(fz, np.searchsorted(fz.keys, los),
                       np.searchsorted(fz.keys, his))
                      for fz in self.frozen if len(fz.keys)]
        per_level = [lvl.range_slice_many(los, his, self.io, cache=cache)
                     for lvl in self.levels
                     if lvl is not None and len(lvl)]
        merged = []
        for j in range(nr):
            parts = [tuple(x[m_lo[j]:m_hi[j]] for x in mem)]
            parts += [(fz.keys[a[j]:b[j]], fz.seqs[a[j]:b[j]],
                       fz.types[a[j]:b[j]], fz.vals[a[j]:b[j]])
                      for fz, a, b in per_frozen]
            parts += [slices[j] for slices in per_level]
            merged.append(newest_wins(*merge_runs(parts, rank_fn=rank_fn)))
        live = [m[2] == PUT for m in merged]
        # Validity filtering, batched across every non-empty range.
        nz = [j for j in range(nr) if len(merged[j][0])]
        if nz and self.strategy in ("lrr", "gloran"):
            cat_keys = np.concatenate([merged[j][0] for j in nz])
            cat_seqs = np.concatenate([merged[j][1] for j in nz])
            if self.strategy == "lrr":
                dead = self._lrr_scan_dead(cat_keys, cat_seqs, his[nz])
            else:
                for j in nz:
                    # Iterators over each index level stream the areas
                    # overlapping the scan range (sorted + sequential).
                    self.gloran.charge_range_scan(
                        ranges[j][0], ranges[j][1], self.config.block_size)
                is_dead = validity_fn or self.gloran.is_deleted_batch
                dead = is_dead(cat_keys, cat_seqs)
            off = 0
            for j in nz:
                n = len(merged[j][0])
                live[j] &= ~dead[off:off + n]
                off += n
        return [(m[0][lv], m[3][lv]) for m, lv in zip(merged, live)]

    def _lrr_scan_dead(self, keys: np.ndarray, seqs: np.ndarray,
                       his: np.ndarray) -> np.ndarray:
        """Max-covering range-tombstone filter for scan candidates.

        ``his`` holds the scan upper bounds (one per range) so each
        level's tombstone-iterator charge — a sequential stream of the
        tombstones with start < hi, per range — matches the per-call
        path exactly.
        """
        rt_max = np.zeros(len(keys), dtype=np.uint64)
        for lo_, hi_, s_ in self.mem_rts:
            m = (keys >= lo_) & (keys < hi_)
            rt_max[m] = np.maximum(rt_max[m], np.uint64(s_))
        for fz in self.frozen:
            for lo_, hi_, s_ in fz.rts:  # memory-resident: no charge
                m = (keys >= lo_) & (keys < hi_)
                rt_max[m] = np.maximum(rt_max[m], np.uint64(s_))
        for rtb in self.level_rts:
            if len(rtb):
                cnts = np.searchsorted(rtb.starts, his)
                self.io.read_blocks(
                    int((1 + (cnts * self.config.range_tombstone_size) //
                         self.config.block_size).sum()), tag="rt_scan")
                rt_max = np.maximum(rt_max, rtb.max_covering_batch(keys))
        return rt_max > seqs

    # -------------------------------------------------- flush / compaction
    def flush(self) -> None:
        if not self.mem and not self.mem_rts:
            return
        if self.scheduler is not None:
            # Background mode: seal (cheap — the cached columnar
            # snapshot) and let the scheduler flush/compact at the next
            # drain point.  The foreground thread never pays the
            # cascade unless the frozen soft limit backpressures.
            self._seal()
            return
        with span("lsm.flush", entries=len(self.mem),
                  range_tombstones=len(self.mem_rts)):
            self._flush()

    def _seal(self) -> None:
        """Freeze the active memtable (and LRR buffer) into an
        immutable snapshot served by reads until a background flush
        job publishes it as a level-0 run."""
        with span("lsm.seal", entries=len(self.mem),
                  range_tombstones=len(self.mem_rts),
                  backlog=len(self.frozen)):
            mk, ms, mt, mv = self._mem_sorted()
            with self._struct_lock:
                self.frozen.append(FrozenMemtable(mk, ms, mt, mv,
                                                  self.mem_rts))
                self.mem = {}
                self._mem_snap = None
                self.mem_rts = []
                self.struct_epoch += 1
        self.scheduler.on_seal()

    def _flush_frozen_one(self) -> None:
        """Background flush job body: publish the oldest frozen
        snapshot as a level-0 run with exactly the inline ``_flush``
        charges (the snapshot holds the same sorted-unique rows the
        inline path would lexsort, so the run — bloom bits included —
        is byte-identical).  Capacity cascades are the scheduler's
        follow-up jobs, not run here."""
        with self._struct_lock:
            if not self.frozen:
                return
            fz = self.frozen.pop(0)
            self.struct_epoch += 1
        if len(fz.keys):
            self._sstable_seed += 1
            run = build_sstable(fz.keys, fz.seqs, fz.types, fz.vals,
                                self.config, io=self.io,
                                seed=self._sstable_seed, presorted=True)
            self._merge_into(0, run)
        if self.strategy == "lrr" and fz.rts:
            arr = np.array(fz.rts, dtype=np.uint64)
            rtb = RangeTombstoneBlock(arr[:, 0], arr[:, 1], arr[:, 2],
                                      self.config)
            self._ensure_rt(0)
            self.level_rts[0] = self.level_rts[0].merge(rtb)
            self.io.write_sequential(self.level_rts[0].nbytes,
                                     tag="rt_flush")

    def _flush(self) -> None:
        if self.mem:
            # The cached sorted columnar snapshot IS the run content:
            # unique keys (dict semantics), key-sorted — no per-entry
            # python loop, no lexsort in build_sstable (presorted).
            mk, ms, mt, mv = self._mem_sorted()
            self.mem.clear()
            self._mem_snap = None
            self._sstable_seed += 1
            run = build_sstable(mk, ms, mt, mv, self.config, io=self.io,
                                seed=self._sstable_seed, presorted=True)
            self._merge_into(0, run)
        if self.strategy == "lrr" and self.mem_rts:
            arr = np.array(self.mem_rts, dtype=np.uint64)
            self.mem_rts = []
            rtb = RangeTombstoneBlock(arr[:, 0], arr[:, 1], arr[:, 2],
                                      self.config)
            self._ensure_rt(0)
            self.level_rts[0] = self.level_rts[0].merge(rtb)
            self.io.write_sequential(self.level_rts[0].nbytes, tag="rt_flush")
        self._cascade()

    def _ensure_rt(self, i: int) -> None:
        while len(self.level_rts) <= i:
            self.level_rts.append(RangeTombstoneBlock.empty(self.config))

    def _merge_rows(self, a: tuple, b: tuple) -> tuple:
        """Key-ordered union of two sorted runs (cross-run duplicates
        adjacent), with output positions through the engine's gated
        merge-rank kernel hook when installed — bit-identical to the
        host searchsorted pair, and (after the presorted newest-wins
        dedup in ``build_sstable``) to the legacy concatenate+lexsort."""
        return merge_two(a, b, rank_fn=self.compaction_rank_fn)

    def _publish_level(self, i: int, run: SSTable | None) -> None:
        """Atomically install a level's new run (epoch bump under the
        structure lock, so concurrent snapshot readers never observe a
        half-applied compaction)."""
        with self._struct_lock:
            self.levels[i] = run
            self.struct_epoch += 1

    def _track_compaction(self, i: int, nbytes: int) -> None:
        self.compaction_bytes[i] = self.compaction_bytes.get(i, 0) + \
            int(nbytes)

    def _merge_into(self, i: int, run: SSTable) -> None:
        while len(self.levels) <= i:
            self.levels.append(None)
        self._ensure_rt(i)
        if self.levels[i] is None or len(self.levels[i]) == 0:
            self._publish_level(i, run)
            return
        dst = self.levels[i]
        self.io.read_sequential(dst.nbytes + run.nbytes, tag="compaction")
        self._track_compaction(i, dst.nbytes + run.nbytes)
        keys, seqs, typs, vals = self._merge_rows(
            (run.keys, run.seqs, run.types, run.vals),
            (dst.keys, dst.seqs, dst.types, dst.vals))
        self._sstable_seed += 1
        merged = build_sstable(keys, seqs, typs, vals, self.config,
                               io=self.io, seed=self._sstable_seed,
                               presorted=True)
        self._publish_level(i, merged)

    def _is_bottom(self, i: int) -> bool:
        return all(self.levels[j] is None or len(self.levels[j]) == 0
                   for j in range(i + 1, len(self.levels)))

    def _cascade(self) -> None:
        i = 0
        while i < len(self.levels):
            lvl = self.levels[i]
            if lvl is not None and len(lvl) > self.config.level_capacity(i):
                self._compact(i)
            i += 1

    def _compact(self, i: int) -> None:
        """Merge level i into level i+1 (leveling)."""
        with span("lsm.compact", level=i, entries=len(self.levels[i])):
            self._compact_impl(i)

    def _compact_impl(self, i: int) -> None:
        src = self.levels[i]
        self._publish_level(i, None)
        while len(self.levels) <= i + 1:
            self.levels.append(None)
        self._ensure_rt(i + 1)
        dst = self.levels[i + 1]
        self.io.read_sequential(
            src.nbytes + (dst.nbytes if dst is not None else 0),
            tag="compaction")
        self._track_compaction(
            i + 1, src.nbytes + (dst.nbytes if dst is not None else 0))
        # Key-ordered union through the merge-rank path (kernel-gated);
        # duplicates stay adjacent for the presorted newest-wins dedup
        # in build_sstable — the delete masks below see the same rows
        # (elementwise) the legacy concatenate order did.
        if dst is not None and len(dst):
            keys, seqs, typs, vals = self._merge_rows(
                (src.keys, src.seqs, src.types, src.vals),
                (dst.keys, dst.seqs, dst.types, dst.vals))
        else:
            keys, seqs, typs, vals = (src.keys, src.seqs, src.types,
                                      src.vals)
        bottom = self._is_bottom(i + 1)
        if self.strategy == "lrr":
            rtb = self.level_rts[i].merge(self.level_rts[i + 1])
            self.level_rts[i] = RangeTombstoneBlock.empty(self.config)
            if len(rtb):
                self.io.read_sequential(rtb.nbytes, tag="rt_compaction")
                self.rt_compaction_bytes[i + 1] = \
                    self.rt_compaction_bytes.get(i + 1, 0) + rtb.nbytes
                cov = rtb.max_covering_batch(keys)
                keep = ~(cov > seqs)
                keys, seqs, typs, vals = (keys[keep], seqs[keep], typs[keep],
                                          vals[keep])
            if bottom:
                # Range tombstones expire at the bottommost level.
                self.level_rts[i + 1] = RangeTombstoneBlock.empty(self.config)
            else:
                self.level_rts[i + 1] = rtb
                self.io.write_sequential(rtb.nbytes, tag="rt_compaction")
                if len(rtb):
                    self.rt_compaction_bytes[i + 1] = \
                        self.rt_compaction_bytes.get(i + 1, 0) + rtb.nbytes
        elif self.strategy == "gloran" and self.gloran is not None and bottom:
            # Stream-merge against the global index: one sequential pass.
            idx = self.gloran.index
            for lvl in getattr(idx, "levels", []):
                if lvl is not None and hasattr(lvl, "scan_io"):
                    self.io.read_blocks(lvl.scan_io(), tag="gloran_compact")
            dead = self.gloran.is_deleted_batch(keys, seqs)
            keep = ~dead
            keys, seqs, typs, vals = (keys[keep], seqs[keep], typs[keep],
                                      vals[keep])
        self._sstable_seed += 1
        merged = build_sstable(keys, seqs, typs, vals, self.config,
                               io=self.io, seed=self._sstable_seed,
                               presorted=True)
        if bottom and len(merged):
            # Point tombstones expire at the bottommost level.
            keep = merged.types != TOMBSTONE
            if not keep.all():
                self._sstable_seed += 1
                merged = build_sstable(merged.keys[keep], merged.seqs[keep],
                                       merged.types[keep], merged.vals[keep],
                                       self.config, io=None,
                                       seed=self._sstable_seed,
                                       presorted=True)
        self._publish_level(i + 1, merged)
        if self.strategy == "gloran" and bottom:
            # GC watermark: everything below it now lives in the bottom
            # level and has had range deletes applied.
            self.gloran.on_bottom_compaction(self._watermark(i + 1))

    def _watermark(self, bottom_idx: int) -> int:
        w = self.seq
        if self.mem:
            w = min(w, min(s for s, _, _ in self.mem.values()))
        for fz in self.frozen:
            # Sealed-but-unflushed entries are above the bottom level:
            # they hold the GC floor down exactly like the memtable.
            if len(fz.seqs):
                w = min(w, fz.min_seq)
        for j in range(bottom_idx):
            lvl = self.levels[j]
            if lvl is not None and len(lvl):
                w = min(w, lvl.min_seq)
        return w

    # ---------------------------------------------------------------- misc
    @property
    def num_entries(self) -> int:
        return len(self.mem) + sum(len(f) for f in self.frozen) + sum(
            len(l) for l in self.levels if l is not None)

    @property
    def disk_bytes(self) -> int:
        data = sum(l.nbytes for l in self.levels if l is not None)
        rt = sum(r.nbytes for r in self.level_rts)
        idx = self.gloran.disk_bytes if self.gloran else 0
        return data + rt + idx

    @property
    def memory_bytes(self) -> int:
        mem = (len(self.mem) + sum(len(f) for f in self.frozen)) * \
            self.config.entry_size
        blooms = sum(l.bloom.nbytes for l in self.levels if l is not None)
        fences = sum(
            l.data_blocks() * self.config.key_size
            for l in self.levels if l is not None)
        g = self.gloran.memory_bytes if self.gloran else 0
        return mem + blooms + fences + g

    def stats(self) -> dict:
        return {
            "entries": self.num_entries,
            "levels": [len(l) if l is not None else 0 for l in self.levels],
            "frozen": [len(f) for f in self.frozen],
            "struct_epoch": self.struct_epoch,
            "seq": self.seq,
            "disk_bytes": self.disk_bytes,
            "memory_bytes": self.memory_bytes,
            "io": self.io.snapshot(),
        }
