from .serve_loop import ServeLoop, ServeStats, SessionRegistry
from .straggler import StragglerConfig, StragglerDetector
from .train_loop import (TrainLoopConfig, TrainResult, TransientFailure,
                         run_training)

__all__ = ["ServeLoop", "ServeStats", "SessionRegistry", "StragglerConfig",
           "StragglerDetector", "TrainLoopConfig", "TrainResult",
           "TransientFailure", "run_training"]
