"""Batched serving loop with a GLORAN-backed session state registry.

The paper's technique as serving infrastructure: an inference fleet keeps
per-session state records (KV-cache page ownership, prefix-cache entries,
session metadata) in an LSM key-value store.  Sessions expire in RANGES —
"drop everything for tenant T", "expire all sessions started before the
deploy" — which is exactly the range-delete workload that poisons point
lookups under RocksDB-style range tombstones (§3).  With GLORAN the
registry's point lookups (one per scheduled token batch per session) stay
fast regardless of expiry churn.

Keys: (session_id << 16 | page_idx).  ``expire_session`` / ``expire_range``
are single range deletes; the decode scheduler's page lookups are typed
``OpBatch`` gets submitted through the engine — ``lookup_submit`` returns
the ``PendingBatch`` so a decode step can run while the registry shards
execute (plan/submit/collect pipelining).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gloran import GloranConfig
from ..engine import Engine, EngineConfig, OpBatch, PendingBatch
from ..lsm import LSMConfig
from ..models import Transformer, tree_init
from ..obs import span

PAGE_BITS = 16


@dataclass
class ServeStats:
    tokens_generated: int = 0
    registry_lookups: int = 0
    registry_io_reads: int = 0
    registry_stall_seconds: float = 0.0  # blocked on in-flight lookups
    expired_sessions: int = 0
    wall_seconds: float = 0.0


class SessionRegistry:
    """Engine-backed session/page registry with range-delete expiry.

    Lookups, registrations, and expiries execute through a sharded
    batched query ``Engine``; ``num_shards=1`` (the default) preserves
    the original single-tree behavior while still running the batched
    read path.
    """

    def __init__(self, strategy: str = "gloran",
                 lsm_config: LSMConfig | None = None,
                 gloran_config: GloranConfig | None = None,
                 num_shards: int = 1,
                 engine_config: EngineConfig | None = None):
        # The registry's ``tree`` property (and the strategy-comparison
        # harnesses built on it) introspect the backing LSMTree
        # directly, so the default engine stays in-process even under a
        # REPRO_ENGINE_PROCS environment; pass an explicit
        # ``engine_config`` to serve from worker processes.
        self.engine = Engine(
            num_shards=num_shards, strategy=strategy,
            lsm_config=lsm_config or LSMConfig(buffer_capacity=4096,
                                               key_size=16, value_size=48),
            gloran_config=gloran_config,
            config=engine_config or EngineConfig(procs=0))

    @property
    def tree(self):
        """The backing LSM-tree — only well-defined unsharded."""
        assert self.engine.num_shards == 1, \
            "registry is sharded; use .engine for per-shard access"
        return self.engine.shards[0].tree

    @property
    def io_reads(self) -> int:
        return self.engine.io_reads

    @staticmethod
    def key(session_id: int, page: int = 0) -> int:
        return (session_id << PAGE_BITS) | page

    def register(self, session_id: int, pages: np.ndarray,
                 values: np.ndarray) -> None:
        keys = (np.uint64(session_id) << np.uint64(PAGE_BITS)) | \
            np.asarray(pages, dtype=np.uint64)
        self.engine.put_batch(keys, np.asarray(values, dtype=np.uint64))

    def lookup(self, session_ids: np.ndarray,
               pages: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        keys = (np.asarray(session_ids, np.uint64) << np.uint64(PAGE_BITS)) \
            | np.asarray(pages, dtype=np.uint64)
        return self.engine.get_batch(keys)

    def lookup_submit(self, session_ids: np.ndarray,
                      pages: np.ndarray) -> PendingBatch:
        """Non-blocking ``lookup``: submit the page-lookup batch and
        return its ``PendingBatch`` so the caller can overlap other work
        (the decode step) with registry execution; collect with
        ``.get_results()``."""
        keys = (np.asarray(session_ids, np.uint64) << np.uint64(PAGE_BITS)) \
            | np.asarray(pages, dtype=np.uint64)
        return self.engine.submit(OpBatch.gets(keys))

    def expire_session(self, session_id: int) -> None:
        lo = session_id << PAGE_BITS
        self.engine.range_delete(lo, lo + (1 << PAGE_BITS))

    def expire_range(self, first_session: int, last_session: int) -> None:
        """Expire [first, last) sessions with ONE range delete."""
        self.engine.range_delete(first_session << PAGE_BITS,
                                 last_session << PAGE_BITS)

    def expire_spans(self, spans) -> None:
        """Expire many [first, last) session spans as ONE batched
        range-delete — one routed engine call, e.g. the reaper draining
        a whole eviction backlog per scheduler tick."""
        self.engine.range_delete_batch(
            [(int(f) << PAGE_BITS, int(l) << PAGE_BITS)
             for f, l in spans])

    def live_pages(self, session_id: int) -> tuple[np.ndarray, np.ndarray]:
        """(pages, values) still live for one session — an engine range
        scan over the session's key slab (session migration / debugging
        reads the registry this way)."""
        lo = session_id << PAGE_BITS
        keys, vals = self.engine.range_scan(lo, lo + (1 << PAGE_BITS))
        return keys & np.uint64((1 << PAGE_BITS) - 1), vals

    def live_pages_batch(self, session_ids) -> list:
        """Batched ``live_pages``: one engine ``range_scan_batch`` for
        many sessions; returns one (pages, values) pair per session."""
        res = self.engine.range_scan_batch(
            [(int(s) << PAGE_BITS, (int(s) + 1) << PAGE_BITS)
             for s in session_ids])
        mask = np.uint64((1 << PAGE_BITS) - 1)
        return [(k & mask, v) for k, v in res]

    def flush(self) -> None:
        self.engine.flush()


class ServeLoop:
    """Greedy batched decode over a small model + the session registry."""

    def __init__(self, model: Transformer, batch: int, max_len: int,
                 registry: SessionRegistry, seed: int = 0):
        self.model = model
        self.batch = batch
        self.max_len = max_len
        self.registry = registry
        self.params = tree_init(model.param_specs(), jax.random.key(seed),
                                model.dtype)
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos))
        self.stats = ServeStats()

    def run(self, prompts: np.ndarray, steps: int,
            session_ids: np.ndarray) -> np.ndarray:
        """prompts: (B, P) int32; returns (B, steps) generated tokens.
        Each decode step consults the registry for every live session
        (page lookups), as a production scheduler would."""
        t0 = time.perf_counter()
        b, p_len = prompts.shape
        assert b == self.batch
        cache = self.model.init_cache(b, self.max_len,
                                      dtype=self.model.dtype)
        # Teacher-forced prompt feed (simple; prefill path covers bulk).
        for t in range(p_len):
            logits, cache = self._decode(self.params,
                                         jnp.asarray(prompts[:, t:t + 1]),
                                         cache, t)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = []
        for t in range(steps):
            # Plan/submit the step's page lookups, decode while the
            # registry shards execute, then collect — the engine's
            # pipelining overlaps the two (serial engines execute the
            # lookup inside lookup_submit; collection is then free).
            io0 = self.registry.io_reads
            pending = self.registry.lookup_submit(
                session_ids, np.full(b, t % 4, dtype=np.uint64))
            with span("serve.decode", step=t, batch=b):
                logits, cache = self._decode(self.params, tok, cache,
                                             p_len + t)
            t_wait = time.perf_counter()
            with span("serve.collect", step=t):
                pending.get_results()
            self.stats.registry_stall_seconds += \
                time.perf_counter() - t_wait
            self.stats.registry_lookups += b
            self.stats.registry_io_reads += \
                self.registry.io_reads - io0
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
            out.append(np.asarray(tok[:, 0]))
            self.stats.tokens_generated += b
        self.stats.wall_seconds += time.perf_counter() - t0
        return np.stack(out, axis=1)
