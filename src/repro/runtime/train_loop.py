"""Fault-tolerant training driver.

Wraps the jitted train_step with the production concerns:
  * periodic async checkpoints (atomic; keep-last-k),
  * restart recovery (params/opt/pipeline/step restored from latest),
  * step retry on transient failures + failure injection for tests,
  * preemption handling (SIGTERM -> blocking final checkpoint),
  * straggler detection hooks (per-host durations -> mitigation callback).

The same loop drives the CPU end-to-end example (reduced config) and — on
real hardware — the full configs; nothing here is smoke-test-only.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import TokenPipeline
from ..models import Transformer, tree_init
from ..launch.steps import make_train_step
from ..optim.optimizer import OptimizerConfig, make_optimizer
from .straggler import StragglerDetector


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    max_retries: int = 3
    log_every: int = 10
    microbatch: int = 1


class TransientFailure(Exception):
    """Simulated recoverable fault (node flake, collective timeout)."""


@dataclass
class TrainResult:
    final_step: int
    losses: list = field(default_factory=list)
    retries: int = 0
    resumed_from: int | None = None
    preempted: bool = False
    straggler_events: list = field(default_factory=list)


def run_training(model: Transformer, pipeline: TokenPipeline,
                 loop_cfg: TrainLoopConfig,
                 opt_cfg: OptimizerConfig | None = None,
                 failure_injector=None, rng_seed: int = 0,
                 host_durations_fn=None) -> TrainResult:
    """failure_injector(step) -> bool: raise TransientFailure when True.
    host_durations_fn(step, real_duration) -> list[float]: per-host step
    times (tests inject stragglers)."""
    opt_cfg = opt_cfg or OptimizerConfig(name=model.cfg.optimizer,
                                         warmup_steps=10, decay_steps=1000)
    init_fn, _ = make_optimizer(opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatch=loop_cfg.microbatch),
                      donate_argnums=(0, 1))
    ckpt = CheckpointManager(loop_cfg.checkpoint_dir,
                             keep=loop_cfg.keep_checkpoints)
    detector = StragglerDetector(n_hosts=max(1, pipeline.cfg.n_hosts))
    result = TrainResult(final_step=0)

    # ---------------------------------------------------------- bootstrap
    params_t = model.param_specs()
    params = tree_init(params_t, jax.random.key(rng_seed), model.dtype)
    opt_state = init_fn(params)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore({"params": params, "opt": opt_state},
                                    step=latest)
        params, opt_state = state["params"], state["opt"]
        pipeline.restore(extra["pipeline"])
        start_step = int(extra["step"])
        result.resumed_from = start_step

    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_sigterm)

    try:
        step = start_step
        while step < loop_cfg.total_steps:
            batch = pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            attempts = 0
            while True:
                try:
                    if failure_injector is not None and \
                            failure_injector(step):
                        raise TransientFailure(f"injected @ step {step}")
                    t0 = time.perf_counter()
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                    loss = float(metrics["loss"])
                    dur = time.perf_counter() - t0
                    break
                except TransientFailure:
                    attempts += 1
                    result.retries += 1
                    if attempts > loop_cfg.max_retries:
                        raise
            durations = (host_durations_fn(step, dur)
                         if host_durations_fn else [dur])
            flagged = detector.observe(step, durations)
            if flagged:
                result.straggler_events.extend(
                    detector.events[-len(flagged):])
            result.losses.append(loss)
            step += 1
            result.final_step = step
            if step % loop_cfg.checkpoint_every == 0 or \
                    step == loop_cfg.total_steps or preempted["flag"]:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          extra={"step": step,
                                 "pipeline": pipeline.state()},
                          blocking=preempted["flag"])
            if preempted["flag"]:
                result.preempted = True
                break
        ckpt.wait()
        return result
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        try:
            # Durability even on the failure path: a crash must not lose
            # checkpoints already queued (the restart depends on them).
            ckpt.wait()
        except Exception:
            pass
