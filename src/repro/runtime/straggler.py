"""Straggler detection: per-host step-time anomaly tracking.

At multi-pod scale a single slow host gates every synchronous collective.
The detector keeps an EMA + variance of per-host step durations and flags
hosts whose latest step exceeds mean + k*sigma of the fleet (and a
relative floor).  The train loop consumes flags to trigger mitigation
(re-replication / hot-spare swap in a real deployment; here: logged events
+ a mitigation callback hook, unit-tested with a simulated clock).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerConfig:
    ema_alpha: float = 0.2
    z_threshold: float = 3.0
    rel_threshold: float = 1.5  # also require 1.5x fleet mean
    min_samples: int = 5


@dataclass
class StragglerDetector:
    n_hosts: int
    config: StragglerConfig = field(default_factory=StragglerConfig)

    def __post_init__(self):
        self.ema = [0.0] * self.n_hosts
        self.var = [0.0] * self.n_hosts
        self.samples = 0
        self.events: list[dict] = []

    def observe(self, step: int, durations: list[float]) -> list[int]:
        """durations[i]: step wall time reported by host i.  Returns the
        list of flagged host ids."""
        assert len(durations) == self.n_hosts
        a = self.config.ema_alpha
        for i, d in enumerate(durations):
            if self.samples == 0:
                self.ema[i] = d
                self.var[i] = 0.0
            else:
                delta = d - self.ema[i]
                self.ema[i] += a * delta
                self.var[i] = (1 - a) * (self.var[i] + a * delta * delta)
        self.samples += 1
        if self.samples < self.config.min_samples:
            return []
        fleet_mean = sum(self.ema) / self.n_hosts
        fleet_var = sum((e - fleet_mean) ** 2
                        for e in self.ema) / self.n_hosts
        sigma = max(fleet_var ** 0.5, 1e-9)
        flagged = []
        for i, d in enumerate(durations):
            z = (d - fleet_mean) / sigma
            if z > self.config.z_threshold and \
                    d > self.config.rel_threshold * fleet_mean:
                flagged.append(i)
                self.events.append({"step": step, "host": i,
                                    "duration": d, "z": z,
                                    "fleet_mean": fleet_mean})
        return flagged
