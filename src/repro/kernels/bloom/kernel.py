"""Pallas kernel: batched Bloom-filter probe.

Design (TPU adaptation of the paper's per-level filter probes): the bit
array stays resident in VMEM — LSM filters at 10 bits/key are ~1.2 MB per
million keys, comfortably inside the ~16 MB VMEM of a v5e core — and the
query stream is tiled over the grid in (rows x 128)-lane blocks so the VPU
processes 128 probes per lane step.  All hashing is 32-bit (murmur3-style
finalizer), bit-identical to the host-side ``repro.core.eve.BloomBits``.

Larger filters are chunked at the ops layer (each chunk owns a disjoint
word range, so per-chunk probes AND together).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _mix32(x: jnp.ndarray, seed) -> jnp.ndarray:
    """murmur3-style finalizer on uint32 (matches core.eve.mix32)."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _bloom_probe_kernel(keys_ref, words_ref, out_ref, *, m_bits: int,
                        seeds: tuple[int, ...]):
    """One grid step: probe a (rows, 128) tile of folded uint32 keys."""
    keys = keys_ref[...]  # (rows, LANES) uint32
    words = words_ref[...].reshape(-1)  # full filter in VMEM
    hit = jnp.ones(keys.shape, dtype=jnp.bool_)
    for seed in seeds:  # n_hashes is small + static: unrolled
        pos = _mix32(keys, seed) % jnp.uint32(m_bits)
        w = jnp.take(words, (pos >> jnp.uint32(5)).astype(jnp.int32), axis=0)
        bit = (w >> (pos & jnp.uint32(31))) & jnp.uint32(1)
        hit = hit & (bit == jnp.uint32(1))
    out_ref[...] = hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m_bits", "seeds", "block_rows",
                                             "interpret"))
def bloom_probe_pallas(keys32: jnp.ndarray, words: jnp.ndarray, *,
                       m_bits: int, seeds: tuple[int, ...],
                       block_rows: int = 8,
                       interpret: bool = True) -> jnp.ndarray:
    """keys32: (n_rows, 128) uint32 folded keys; words: (n_words,) uint32.

    Returns int32 {0,1} of shape (n_rows, 128)."""
    n_rows = keys32.shape[0]
    assert keys32.shape[1] == LANES
    assert n_rows % block_rows == 0
    grid = (n_rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_bloom_probe_kernel, m_bits=m_bits, seeds=seeds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((words.shape[0],), lambda i: (0,)),  # whole filter
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.int32),
        interpret=interpret,
    )(keys32, words)
