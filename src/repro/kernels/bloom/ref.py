"""Pure-jnp oracle for the Bloom probe kernel (identical 32-bit math)."""

from __future__ import annotations

import jax.numpy as jnp


def mix32_ref(x: jnp.ndarray, seed) -> jnp.ndarray:
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def bloom_probe_ref(keys32: jnp.ndarray, words: jnp.ndarray, *, m_bits: int,
                    seeds: tuple[int, ...]) -> jnp.ndarray:
    """keys32: any-shape uint32; words: (n_words,) uint32 -> int32 {0,1}."""
    hit = jnp.ones(keys32.shape, dtype=jnp.bool_)
    for seed in seeds:
        pos = mix32_ref(keys32, seed) % jnp.uint32(m_bits)
        w = jnp.take(words, (pos >> jnp.uint32(5)).astype(jnp.int32), axis=0)
        bit = (w >> (pos & jnp.uint32(31))) & jnp.uint32(1)
        hit = hit & (bit == jnp.uint32(1))
    return hit.astype(jnp.int32)
