"""Public jit'd wrapper for the Bloom probe kernel.

Handles padding to (rows x 128) tiles, interpret-mode selection (CPU
container -> interpret=True; on real TPU backends the compiled path), and
chunking of filters too large for VMEM: the filter's word array is split
into equal word ranges; a probe whose position falls outside a chunk's
range is treated as pass for that chunk, and per-chunk verdicts AND
together — identical semantics to one big filter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...obs import span
from .kernel import LANES, bloom_probe_pallas
from .ref import mix32_ref

# ~4 MB of uint32 words per chunk keeps the filter + tiles well under VMEM.
MAX_WORDS_PER_CALL = 1 << 20


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def bloom_probe(keys32, words, *, m_bits: int, seeds: tuple[int, ...],
                block_rows: int = 8, interpret: bool | None = None,
                device=None):
    """Batched Bloom probe: returns bool (n,) for uint32 folded keys.

    keys32: (n,) uint32; words: (n_words,) uint32 bit array; m_bits: filter
    size in bits; seeds: per-hash 32-bit seeds.  ``device`` commits the
    query upload to one XLA device (pre-uploaded registry words are
    committed there already), pinning the launch per shard.
    """
    with span("kernel.bloom", n=int(np.shape(keys32)[0])):
        return _bloom_probe(keys32, words, m_bits=m_bits, seeds=seeds,
                            block_rows=block_rows, interpret=interpret,
                            device=device)


def _bloom_probe(keys32, words, *, m_bits, seeds, block_rows, interpret,
                 device):
    if interpret is None:
        interpret = _default_interpret()
    if device is not None:
        keys32 = jax.device_put(np.asarray(keys32, np.uint32), device)
    else:
        keys32 = jnp.asarray(keys32, dtype=jnp.uint32)
    # Pre-uploaded device words (e.g. the engine registry's per-run
    # copies) pass through untouched: no host->device copy per probe.
    if not isinstance(words, jax.Array):
        words = jnp.asarray(words, dtype=jnp.uint32)
    n = keys32.shape[0]
    tile = block_rows * LANES
    n_pad = -n % tile
    keys_p = jnp.pad(keys32, (0, n_pad)).reshape(-1, LANES)

    if words.shape[0] <= MAX_WORDS_PER_CALL:
        out = bloom_probe_pallas(keys_p, words, m_bits=m_bits,
                                 seeds=tuple(int(s) for s in seeds),
                                 block_rows=block_rows, interpret=interpret)
        return out.reshape(-1)[:n].astype(bool)

    # Chunked path: each call sees a word-range slice; positions outside
    # the slice pass trivially (handled by offsetting positions so they hit
    # an always-set guard word appended to the chunk).
    verdict = jnp.ones((keys_p.size,), dtype=bool)
    n_words = words.shape[0]
    for w0 in range(0, n_words, MAX_WORDS_PER_CALL):
        w1 = min(n_words, w0 + MAX_WORDS_PER_CALL)
        chunk = jnp.concatenate(
            [words[w0:w1], jnp.full((1,), 0xFFFFFFFF, dtype=jnp.uint32)])
        # Remap: positions whose word index is inside [w0, w1) probe the
        # chunk; others hit the guard word (always set).
        part = _chunk_probe(keys_p, chunk, w0, w1, m_bits,
                            tuple(int(s) for s in seeds), block_rows,
                            interpret)
        verdict = verdict & part.reshape(-1).astype(bool)
    return verdict[:n]


@functools.partial(jax.jit, static_argnames=("w0", "w1", "m_bits", "seeds",
                                             "block_rows", "interpret"))
def _chunk_probe(keys_p, chunk, w0, w1, m_bits, seeds, block_rows,
                 interpret):
    # Compute positions with the reference mixer, remap into chunk space,
    # then run the in-VMEM kernel against the chunk with identity "hash"
    # == precomputed positions.  To keep the kernel single-sourced we
    # evaluate the bit test directly here for the chunked fallback.
    hit = jnp.ones(keys_p.shape, dtype=jnp.bool_)
    for seed in seeds:
        pos = mix32_ref(keys_p, seed) % jnp.uint32(m_bits)
        widx = (pos >> jnp.uint32(5)).astype(jnp.int32)
        inside = (widx >= w0) & (widx < w1)
        guard = chunk.shape[0] - 1
        local = jnp.where(inside, widx - w0, guard)
        w = jnp.take(chunk, local, axis=0)
        bit = (w >> (pos & jnp.uint32(31))) & jnp.uint32(1)
        hit = hit & (bit == jnp.uint32(1))
    return hit
