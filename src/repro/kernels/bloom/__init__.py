from .ops import bloom_probe

__all__ = ["bloom_probe"]
