"""Oracles for the fused lookup-cascade kernel (host numpy + pure jnp).

Both operate on the packed device-state layout built by the engine's
``DeviceFilterRegistry`` (see ``ops.cascade_lookup`` for the contract):
per-level key/seq/bloom-word arrays concatenated with dynamic offsets, a
GLORAN disjoint interval view likewise concatenated, and a query stream
of (exact u32 key, folded bloom hash, already-resolved seq/mask).

``cascade_np`` is the independent host oracle (numpy ``searchsorted`` +
the ``BloomBits`` bit test); ``cascade_flat`` is the pure-jnp
fixed-depth form that jit-compiles through XLA — it is the ``compiled``
dispatch path on CPU CI and the math template for the Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.eve import mix32 as mix32_np
from ..bloom.ref import mix32_ref


def cascade_np(qkey, qhash, qseq, qres,
               lkeys, lseqs, key_off, key_cnt, words, word_off, mbits,
               seeds, glo_lo, glo_hi, glo_smin, glo_smax, gl_off, gl_cnt):
    """Host oracle. Returns (bloom_mask, hit_mask, gl_mask, pos).

    ``bloom_mask``/``hit_mask``/``gl_mask`` are int32 per-key bitmasks
    (bit l = verdict at packed level l); ``pos`` is (L, n) int32 of
    level-local candidate indices ``min(searchsorted(keys_l, q), n_l-1)``
    — exactly the index the per-level host path derives before charging
    a data-block read.
    """
    qkey = np.asarray(qkey, np.uint32)
    n = len(qkey)
    L = len(key_off)
    G = len(gl_off)
    bloom_mask = np.zeros(n, np.int32)
    hit_mask = np.zeros(n, np.int32)
    gl_mask = np.zeros(n, np.int32)
    pos = np.zeros((L, n), np.int32)
    resolved = np.asarray(qres).astype(bool).copy()
    res_seq = np.asarray(qseq, np.uint32).copy()
    for l in range(L):
        o, c = int(key_off[l]), int(key_cnt[l])
        seg = np.asarray(lkeys[o:o + c], np.uint32)
        idx = np.searchsorted(seg, qkey)
        idxc = np.minimum(idx, c - 1).astype(np.int32)
        pos[l] = idxc
        # Bloom probe against this level's word segment — the host
        # filter's own mixer (core.eve.mix32), so the oracle agrees
        # with ``BloomBits`` by construction.
        maybe = np.ones(n, bool)
        for h in range(seeds.shape[1]):
            p = mix32_np(qhash, seeds[l, h]) % np.uint32(mbits[l])
            w = np.asarray(words)[int(word_off[l])
                                  + (p >> np.uint32(5)).astype(np.int64)]
            maybe &= ((w >> (p & np.uint32(31))) & np.uint32(1)) == 1
        hit = maybe & (seg[idxc] == qkey)
        bloom_mask |= np.where(maybe, np.int32(1 << l), 0)
        hit_mask |= np.where(hit, np.int32(1 << l), 0)
        newly = hit & ~resolved
        res_seq = np.where(newly, np.asarray(lseqs, np.uint32)[o + idxc],
                           res_seq)
        resolved |= hit
    for g in range(G):
        o, c = int(gl_off[g]), int(gl_cnt[g])
        lo = np.asarray(glo_lo[o:o + c], np.uint32)
        i = np.searchsorted(lo, qkey, side="right").astype(np.int64) - 1
        ic = np.maximum(i, 0)
        cov = ((i >= 0) & (c > 0)
               & (qkey < np.asarray(glo_hi)[o + ic])
               & (np.asarray(glo_smin)[o + ic] <= res_seq)
               & (res_seq < np.asarray(glo_smax)[o + ic]))
        gl_mask |= np.where(cov, np.int32(1 << g), 0)
    return bloom_mask, hit_mask, gl_mask, pos


def cascade_flat(qkey, qhash, qseq, qres,
                 lkeys, lseqs, key_off, key_cnt, words, word_off, mbits,
                 seeds, glo_lo, glo_hi, glo_smin, glo_smax, gl_off, gl_cnt,
                 *, L: int, H: int, G: int,
                 key_pad: tuple, word_pad: tuple, gl_pad: tuple):
    """Pure-jnp cascade over flat (n,) query arrays; same outputs as
    ``cascade_np``.

    The *padded* per-level segment sizes (``key_pad``/``word_pad``/
    ``gl_pad``, pow2 each) are static, so every level search is a
    static slice + native ``jnp.searchsorted`` — an order of magnitude
    faster on CPU XLA than a hand-rolled fixed-depth loop, with retraces
    still bounded by the pow2 padding.  True counts / m_bits stay
    dynamic inputs: sentinel padding (0xFFFFFFFF keys, zero words) never
    perturbs a u32-gated query, so only the clamp needs the real size.
    The ``key_off``/``word_off``/``gl_off`` device arrays (used by the
    Pallas form, where operands arrive pre-concatenated) are accepted
    but unused here — offsets are rederived from the static pads."""
    qkey = jnp.asarray(qkey, jnp.uint32)
    qhash = jnp.asarray(qhash, jnp.uint32)
    resolved = jnp.asarray(qres).astype(bool)
    res_seq = jnp.asarray(qseq, jnp.uint32)
    zero = jnp.zeros(qkey.shape, jnp.int32)
    bloom_mask, hit_mask, gl_mask = zero, zero, zero
    pos = []
    koff = [0]
    for p in key_pad[:-1]:
        koff.append(koff[-1] + int(p))
    woff = [0]
    for p in word_pad[:-1]:
        woff.append(woff[-1] + int(p))
    goff = [0]
    for p in gl_pad[:-1]:
        goff.append(goff[-1] + int(p))
    for l in range(L):
        o, p = koff[l], int(key_pad[l])
        kseg = jax.lax.slice_in_dim(lkeys, o, o + p)
        sseg = jax.lax.slice_in_dim(lseqs, o, o + p)
        cnt = key_cnt[l].astype(jnp.int32)
        idx = jnp.searchsorted(kseg, qkey).astype(jnp.int32)
        idxc = jnp.minimum(idx, cnt - 1)
        pos.append(idxc)
        wseg = jax.lax.slice_in_dim(words, woff[l],
                                    woff[l] + int(word_pad[l]))
        maybe = jnp.ones(qkey.shape, bool)
        for h in range(H):
            hp = mix32_ref(qhash, seeds[l, h]) % mbits[l]
            w = jnp.take(wseg, (hp >> jnp.uint32(5)).astype(jnp.int32),
                         axis=0)
            maybe &= ((w >> (hp & jnp.uint32(31))) & jnp.uint32(1)) == 1
        hit = maybe & (jnp.take(kseg, idxc, axis=0) == qkey)
        bloom_mask |= jnp.where(maybe, jnp.int32(1 << l), 0)
        hit_mask |= jnp.where(hit, jnp.int32(1 << l), 0)
        newly = hit & ~resolved
        res_seq = jnp.where(newly, jnp.take(sseg, idxc, axis=0), res_seq)
        resolved = resolved | hit
    for g in range(G):
        o, p = goff[g], int(gl_pad[g])
        seg = jax.lax.slice_in_dim(glo_lo, o, o + p)
        cnt = gl_cnt[g].astype(jnp.int32)
        i = jnp.searchsorted(seg, qkey, side="right").astype(jnp.int32) - 1
        ic = jnp.maximum(i, 0)
        cov = ((i >= 0) & (cnt > 0)
               & (qkey < jnp.take(
                   jax.lax.slice_in_dim(glo_hi, o, o + p), ic, axis=0))
               & (jnp.take(jax.lax.slice_in_dim(glo_smin, o, o + p),
                           ic, axis=0) <= res_seq)
               & (res_seq < jnp.take(
                   jax.lax.slice_in_dim(glo_smax, o, o + p), ic, axis=0)))
        gl_mask |= jnp.where(cov, jnp.int32(1 << g), 0)
    return (bloom_mask, hit_mask, gl_mask,
            jnp.stack(pos) if pos else jnp.zeros((0,) + qkey.shape,
                                                 jnp.int32))
