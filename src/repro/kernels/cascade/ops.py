"""Public dispatch for the fused lookup-cascade kernel.

``CascadeState`` is the device-resident packed filter state built once
per tree shape by the engine's ``DeviceFilterRegistry`` (per-level key/
seq/bloom-word arrays pow2-padded and concatenated, the GLORAN disjoint
interval view likewise) — uploads happen at pack time, NOT per lookup.
``cascade_lookup`` pads the query stream to (rows x 128) tiles and runs
either the Pallas kernel (interpret off-TPU, compiled on TPU) or, with
``compiled=True``, the jit'd pure-XLA form of the same math — the same
fallback pattern as ``kernels.merge``, so CPU CI exercises a compiled
artifact while TPUs compile the Pallas kernel itself.

VMEM budget: packs whose key/word/area totals exceed the ``MAX_PACK_*``
limits are left to the per-level chunked kernels (the registry declines
to build them), keeping every launch's resident state under VMEM.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...obs import span
from .kernel import LANES, cascade_pallas
from .ref import cascade_flat


def pack_bytes(key_slots: int, word_slots: int, area_slots: int) -> int:
    """Resident operand bytes of a pack: u32 keys+seqs, u32 words, and
    four u32 interval columns (one budget formula for gate + docs)."""
    return 8 * key_slots + 4 * word_slots + 16 * area_slots

MAX_PACK_KEYS = 1 << 20  # u32 keys+seqs: 8 MB resident
MAX_PACK_WORDS = 1 << 20  # 4 MB of packed filter words
MAX_PACK_AREAS = 1 << 20  # 4 arrays x 4 B x 1 Mi = 16 MB / 4
# Joint ceiling on one launch's resident operand bytes: the per-
# dimension limits alone could admit ~28 MB combined, past the ~16 MB
# VMEM of most TPU generations; the registry declines any pack whose
# keys+seqs (8 B/slot) + words (4 B) + interval columns (16 B/area)
# exceed this, so the sum stays under VMEM with tile/output headroom.
MAX_PACK_BYTES = 12 << 20


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@dataclass
class CascadeState:
    """Packed device arrays + static dims of one cascade-eligible tree.

    Built by the registry; every array is a ``jax.Array`` already on
    device, so a lookup uploads only its (rows x 128) query tiles."""

    lkeys: jax.Array      # (K,) u32 concat per-level keys (pow2-padded)
    lseqs: jax.Array      # (K,) u32 matching entry seqs
    key_off: jax.Array    # (L,) i32 segment offsets
    key_cnt: jax.Array    # (L,) i32 true (unpadded) level sizes
    words: jax.Array      # (W,) u32 concat bloom words (pow2-padded)
    word_off: jax.Array   # (L,) i32
    mbits: jax.Array      # (L,) u32 per-level filter bit counts
    seeds: jax.Array      # (L, H) u32 per-level hash seeds
    glo_lo: jax.Array     # (A,) u32 GLORAN disjoint view (clamped u32)
    glo_hi: jax.Array
    glo_smin: jax.Array
    glo_smax: jax.Array
    gl_off: jax.Array     # (G,) i32
    gl_cnt: jax.Array     # (G,) i32
    L: int
    H: int
    G: int
    steps_keys: int       # fixed binary-search depth (Pallas form)
    steps_gl: int
    key_pad: tuple        # static pow2 per-level padded sizes (XLA form)
    word_pad: tuple
    gl_pad: tuple


_cascade_xla = jax.jit(cascade_flat, static_argnames=(
    "L", "H", "G", "key_pad", "word_pad", "gl_pad"))


def cascade_lookup(qkey32, qhash32, qseq32, qres, state: CascadeState, *,
                   block_rows: int = 8, interpret: bool | None = None,
                   compiled: bool | None = False, device=None):
    """One fused launch for a batch of point lookups.

    qkey32: (n,) uint32 exact keys (u32-gated by the caller); qhash32:
    (n,) uint32 ``fold64to32`` bloom inputs; qseq32/qres: (n,) seqs and
    resolved flags of entries already answered by the memtable stage.

    ``compiled=None`` auto-selects the dispatch: the jit'd XLA form
    off-TPU (the compiled artifact CPU CI exercises), the Pallas kernel
    on TPU.  ``device`` commits the query tiles to one XLA device so
    the launch runs there (the state arrays are committed by the
    registry; committed operands pin placement) — per-shard device
    execution without a per-call transfer of the packed state.

    Returns numpy ``(maybe, hit, gl_cov, pos)``: (n, L) bool Bloom and
    exact-match verdicts per level, (n, G) bool GLORAN per-level
    coverage of (key, resolved seq), and (n, L) int64 level-local
    candidate positions.
    """
    with span("kernel.cascade", n=len(qkey32), levels=state.L,
              gl_levels=state.G):
        return _cascade_lookup(qkey32, qhash32, qseq32, qres, state,
                               block_rows=block_rows, interpret=interpret,
                               compiled=compiled, device=device)


def _cascade_lookup(qkey32, qhash32, qseq32, qres, state, *,
                    block_rows, interpret, compiled, device):
    if compiled is None:
        compiled = _default_interpret()
    if interpret is None:
        interpret = _default_interpret()
    n = len(qkey32)
    tile = block_rows * LANES
    m = _next_pow2_mult(n, tile)
    qk = np.zeros(m, np.uint32)
    qh = np.zeros(m, np.uint32)
    qs = np.zeros(m, np.uint32)
    qr = np.zeros(m, np.int32)
    qk[:n] = qkey32
    qh[:n] = qhash32
    qs[:n] = qseq32
    qr[:n] = np.asarray(qres, bool)[:n]
    if device is not None:
        qk, qh, qs, qr = (jax.device_put(q, device)
                          for q in (qk, qh, qs, qr))
    st = state
    if compiled:
        bloom, hit, gl, pos = _cascade_xla(
            qk, qh, qs, qr, st.lkeys, st.lseqs, st.key_off, st.key_cnt,
            st.words, st.word_off, st.mbits, st.seeds, st.glo_lo,
            st.glo_hi, st.glo_smin, st.glo_smax, st.gl_off, st.gl_cnt,
            L=st.L, H=st.H, G=st.G, key_pad=st.key_pad,
            word_pad=st.word_pad, gl_pad=st.gl_pad)
        bloom = np.asarray(bloom)
        hit = np.asarray(hit)
        gl = np.asarray(gl)
        pos = np.asarray(pos).reshape(st.L, m)
    else:
        r = m // LANES
        one = jnp.zeros(1, jnp.int32)
        # Pallas rejects zero-length block operands; with G=0 the gl
        # stage is compiled out, so placeholders are never read.
        gl_off = st.gl_off if st.G else one
        gl_cnt = st.gl_cnt if st.G else one
        bloom, hit, gl, pos = cascade_pallas(
            qk.reshape(r, LANES), qh.reshape(r, LANES),
            qs.reshape(r, LANES), qr.reshape(r, LANES),
            st.lkeys, st.lseqs, st.key_off, st.key_cnt, st.words,
            st.word_off, st.mbits, st.seeds, st.glo_lo, st.glo_hi,
            st.glo_smin, st.glo_smax, gl_off, gl_cnt,
            L=st.L, H=st.H, G=st.G, steps_keys=st.steps_keys,
            steps_gl=st.steps_gl, block_rows=block_rows,
            interpret=interpret)
        bloom = np.asarray(bloom).reshape(-1)
        hit = np.asarray(hit).reshape(-1)
        gl = np.asarray(gl).reshape(-1)
        pos = np.asarray(pos).reshape(st.L, m)
    lbits = np.arange(st.L, dtype=np.int32)
    maybe = ((bloom[:n, None] >> lbits) & 1).astype(bool)
    hitm = ((hit[:n, None] >> lbits) & 1).astype(bool)
    if st.G:
        gbits = np.arange(st.G, dtype=np.int32)
        gl_cov = ((gl[:n, None] >> gbits) & 1).astype(bool)
    else:
        gl_cov = np.zeros((n, 0), bool)
    return maybe, hitm, gl_cov, pos[:, :n].T.astype(np.int64)


def _next_pow2_mult(n: int, tile: int) -> int:
    """Smallest pow2 multiple of ``tile`` >= n (bounds distinct compiled
    query shapes to O(log max-batch))."""
    m = tile
    while m < n:
        m <<= 1
    return m
