"""Pallas kernel: the fused device-resident lookup cascade.

One launch answers, for a tile of point-lookup keys, every read-path
filter question the LSM host loop would otherwise ask level by level:

  * per SSTable level — Bloom verdict (same 32-bit mixing as
    ``core.eve.BloomBits``) against the level's word segment of one
    packed VMEM-resident word array, plus the fence/candidate position
    ``min(searchsorted(keys_l, q), n_l - 1)`` via a fixed-depth binary
    search over the packed key array (this is the exact index whose
    block the host charges and reads);
  * resolution — the first level whose candidate is an exact key match
    supplies the entry's sequence number (query-stream inputs carry
    memtable-resolved seqs so earlier stages keep priority);
  * per GLORAN DR-tree level — the disjoint-interval point-stab verdict
    of (key, resolved seq), the same rectangle test as
    ``kernels.interval``.

The grid walks (key tiles); levels are unrolled statically inside the
body because resolution order is a cross-level carry (level l+1's
resolved seq depends on level l's hit).  All per-level metadata
(offsets, counts, m_bits, seeds) is dynamic input, so compiled shapes
are keyed only on the padded pack sizes — O(log) distinct across
compactions, exactly like the interval kernel's pow2 padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _mix32(x: jnp.ndarray, seed) -> jnp.ndarray:
    """murmur3-style finalizer on uint32 (matches core.eve.mix32)."""
    x = x.astype(jnp.uint32) ^ seed.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _search(keys, arr, off, cnt, steps: int, leq: bool):
    """Fixed-depth lower/upper-bound over ``arr[off:off+cnt]`` (global
    converged left bound; data-independent iteration count)."""
    left = jnp.full(keys.shape, off, dtype=jnp.int32)
    right = jnp.full(keys.shape, off + cnt, dtype=jnp.int32)
    for _ in range(steps):
        active = left < right
        mid = (left + right) // 2
        midc = jnp.clip(mid, 0, arr.shape[0] - 1)
        v = jnp.take(arr, midc, axis=0)
        go_right = (v <= keys) if leq else (v < keys)
        left = jnp.where(active & go_right, mid + 1, left)
        right = jnp.where(active & ~go_right, mid, right)
    return left


def _cascade_kernel(qkey_ref, qhash_ref, qseq_ref, qres_ref,
                    lkeys_ref, lseqs_ref, key_off_ref, key_cnt_ref,
                    words_ref, word_off_ref, mbits_ref, seeds_ref,
                    glo_lo_ref, glo_hi_ref, glo_smin_ref, glo_smax_ref,
                    gl_off_ref, gl_cnt_ref,
                    bloom_ref, hit_ref, gl_ref, pos_ref, *,
                    L: int, H: int, G: int, steps_keys: int, steps_gl: int):
    qkey = qkey_ref[...]  # (rows, LANES) uint32 exact keys
    qhash = qhash_ref[...]  # folded-64to32 bloom inputs
    resolved = qres_ref[...] != 0
    res_seq = qseq_ref[...]
    lkeys = lkeys_ref[...].reshape(-1)
    lseqs = lseqs_ref[...].reshape(-1)
    words = words_ref[...].reshape(-1)
    zero = jnp.zeros(qkey.shape, jnp.int32)
    bloom_mask, hit_mask, gl_mask = zero, zero, zero
    for l in range(L):  # level count is small + static: unrolled
        off = key_off_ref[l]
        cnt = key_cnt_ref[l]
        left = _search(qkey, lkeys, off, cnt, steps_keys, leq=False)
        idxc = jnp.minimum(left - off, cnt - 1)
        pos_ref[l, :, :] = idxc
        maybe = jnp.ones(qkey.shape, jnp.bool_)
        for h in range(H):
            p = _mix32(qhash, seeds_ref[l, h]) % mbits_ref[l]
            w = jnp.take(words, word_off_ref[l]
                         + (p >> jnp.uint32(5)).astype(jnp.int32), axis=0)
            maybe = maybe & (((w >> (p & jnp.uint32(31)))
                              & jnp.uint32(1)) == jnp.uint32(1))
        hit = maybe & (jnp.take(lkeys, off + idxc, axis=0) == qkey)
        bloom_mask = bloom_mask | jnp.where(maybe, jnp.int32(1 << l), 0)
        hit_mask = hit_mask | jnp.where(hit, jnp.int32(1 << l), 0)
        newly = hit & ~resolved
        res_seq = jnp.where(newly, jnp.take(lseqs, off + idxc, axis=0),
                            res_seq)
        resolved = resolved | hit
    if G:
        glo_lo = glo_lo_ref[...].reshape(-1)
        glo_hi = glo_hi_ref[...].reshape(-1)
        glo_smin = glo_smin_ref[...].reshape(-1)
        glo_smax = glo_smax_ref[...].reshape(-1)
        for g in range(G):
            off = gl_off_ref[g]
            cnt = gl_cnt_ref[g]
            left = _search(qkey, glo_lo, off, cnt, steps_gl, leq=True)
            i = left - off - 1
            ic = jnp.maximum(i, 0)
            cov = ((i >= 0) & (cnt > 0)
                   & (qkey < jnp.take(glo_hi, off + ic, axis=0))
                   & (jnp.take(glo_smin, off + ic, axis=0) <= res_seq)
                   & (res_seq < jnp.take(glo_smax, off + ic, axis=0)))
            gl_mask = gl_mask | jnp.where(cov, jnp.int32(1 << g), 0)
    bloom_ref[...] = bloom_mask
    hit_ref[...] = hit_mask
    gl_ref[...] = gl_mask


@functools.partial(jax.jit, static_argnames=("L", "H", "G", "steps_keys",
                                             "steps_gl", "block_rows",
                                             "interpret"))
def cascade_pallas(qkey, qhash, qseq, qres,
                   lkeys, lseqs, key_off, key_cnt, words, word_off, mbits,
                   seeds, glo_lo, glo_hi, glo_smin, glo_smax, gl_off,
                   gl_cnt, *, L: int, H: int, G: int, steps_keys: int,
                   steps_gl: int, block_rows: int = 8,
                   interpret: bool = True):
    """Query tiles: (rows, 128) uint32/int32; packed state: flat arrays.

    Returns (bloom_mask, hit_mask, gl_mask) int32 (rows, 128) bitmasks
    and pos int32 (L, rows, 128) level-local candidate indices."""
    rows = qkey.shape[0]
    assert qkey.shape[1] == LANES and rows % block_rows == 0
    assert 1 <= L <= 30 and 0 <= G <= 30
    grid = (rows // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    full = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0,) * arr.ndim)
    kern = functools.partial(_cascade_kernel, L=L, H=H, G=G,
                             steps_keys=steps_keys, steps_gl=steps_gl)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tile, tile, tile, tile,
                  full(lkeys), full(lseqs), full(key_off), full(key_cnt),
                  full(words), full(word_off), full(mbits), full(seeds),
                  full(glo_lo), full(glo_hi), full(glo_smin),
                  full(glo_smax), full(gl_off), full(gl_cnt)],
        out_specs=[tile, tile, tile,
                   pl.BlockSpec((L, block_rows, LANES),
                                lambda i: (0, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((L, rows, LANES), jnp.int32)],
        interpret=interpret,
    )(qkey, qhash, qseq, qres, lkeys, lseqs, key_off, key_cnt, words,
      word_off, mbits, seeds, glo_lo, glo_hi, glo_smin, glo_smax, gl_off,
      gl_cnt)
