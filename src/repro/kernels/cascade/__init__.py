"""Fused device-resident lookup cascade: one launch for every level's
Bloom + fence + GLORAN interval filters (see kernel.py for design)."""

from .ops import (CascadeState, MAX_PACK_AREAS, MAX_PACK_KEYS,
                  MAX_PACK_WORDS, cascade_lookup)

__all__ = ["CascadeState", "cascade_lookup", "MAX_PACK_KEYS",
           "MAX_PACK_WORDS", "MAX_PACK_AREAS"]
