"""Pallas kernel: blocked causal/sliding-window GQA flash attention.

Online-softmax formulation over a (batch x heads, q-blocks, kv-blocks) grid
with running (m, l, acc) VMEM scratch; the kv-block axis is the innermost
"arbitrary" axis so scratch carries across it.  MXU-aligned tiles
(block_q x head_dim and block_k x head_dim, multiples of 128 lanes) keep
the working set in VMEM.  GQA is expressed in the K/V BlockSpec index maps
(kv head = q head // group) so KV is never materialized per q-head.

Used by the serving/prefill path; training uses the differentiable jnp
reference (ref.py) — the kernel targets the inference hot spot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  q_len: int, kv_len: int, block_q: int, block_k: int,
                  n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = (k_pos < kv_len) & (q_pos < q_len)
    if causal:
        # Queries are the LAST q_len positions of the kv stream (supports
        # prefill continuation); align query absolute position.
        mask &= k_pos <= (q_pos + (kv_len - q_len))
    if window is not None:
        mask &= k_pos > (q_pos + (kv_len - q_len) - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]  # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "q_len", "kv_len",
                     "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, scale: float, causal: bool,
                           window: int | None, q_len: int, kv_len: int,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D); Sq/Skv padded to blocks."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert sq % block_q == 0 and skv % block_k == 0
    nq, nk = sq // block_q, skv // block_k
    grid = (b * hq, nq, nk)

    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d),
        lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0))
    out_spec = pl.BlockSpec((1, 1, block_q, d),
                            lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0))

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, q_len=q_len, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, n_kv_blocks=nk),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
