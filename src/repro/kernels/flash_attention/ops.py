"""Public wrapper: flash attention with framework (B, S, H, D) layout.

Pads sequence lengths to block multiples (mask-safe), transposes to the
kernel's (B, H, S, D) layout, and picks interpret mode off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, scale=None, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    if interpret is None:
        interpret = _default_interpret()
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if scale is None:
        scale = float(d) ** -0.5
    block_q = min(block_q, max(8, sq))
    block_k = min(block_k, max(8, skv))
    pq = -sq % block_q
    pk = -skv % block_k
    qt = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    o = flash_attention_pallas(qt, kt, vt, scale=scale, causal=causal,
                               window=window, q_len=sq, kv_len=skv,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.transpose(0, 2, 1, 3)[:, :sq]
