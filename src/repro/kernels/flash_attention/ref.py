"""Pure-jnp oracle (and differentiable training path) for attention.

Semantics: causal over the *suffix alignment* — queries are the last
``q_len`` positions of the kv stream (supports decode/continuation), with
optional sliding window of size ``window`` (attend to positions in
(pos - window, pos]).  GQA via head-group repetition.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, scale=None, causal=True, window=None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0.0, 1.0, denom)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
