"""Public jit'd wrapper: batched DR-tree point-stab queries.

Pads the query stream to (rows x 128) tiles and chunks VMEM-oversized
levels by key range (disjointness makes per-chunk ORs exact).  Sentinel
padding (lo=hi=0) never covers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...obs import span
from .kernel import LANES, interval_query_pallas

MAX_AREAS_PER_CALL = 1 << 20  # 4 arrays x 4 B x 1 Mi = 16 MB VMEM budget/4


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def interval_query(keys32, seqs32, lo, hi, smin, smax, *,
                   block_rows: int = 8, interpret: bool | None = None,
                   device=None):
    """Returns bool (n,): is (key, seq) covered by the disjoint level?

    ``device`` commits the query upload to one XLA device (pre-uploaded
    level columns are committed there already), pinning the launch per
    shard."""
    with span("kernel.interval", n=int(np.shape(keys32)[0]),
              areas=int(np.shape(lo)[0])):
        return _interval_query(keys32, seqs32, lo, hi, smin, smax,
                               block_rows=block_rows, interpret=interpret,
                               device=device)


def _interval_query(keys32, seqs32, lo, hi, smin, smax, *,
                    block_rows, interpret, device):
    if interpret is None:
        interpret = _default_interpret()
    if device is not None:
        keys32 = jax.device_put(np.asarray(keys32, np.uint32), device)
        seqs32 = jax.device_put(np.asarray(seqs32, np.uint32), device)
    else:
        keys32 = jnp.asarray(keys32, jnp.uint32)
        seqs32 = jnp.asarray(seqs32, jnp.uint32)
    # Pre-uploaded device columns (the executor's cached u32 level
    # views) pass through untouched: no host->device copy per probe.
    as_dev = lambda a: a if isinstance(a, jax.Array) else \
        jnp.asarray(a, jnp.uint32)
    lo = as_dev(lo)
    hi = as_dev(hi)
    smin = as_dev(smin)
    smax = as_dev(smax)

    n = keys32.shape[0]
    tile = block_rows * LANES
    n_pad = -n % tile
    keys_p = jnp.pad(keys32, (0, n_pad)).reshape(-1, LANES)
    seqs_p = jnp.pad(seqs32, (0, n_pad)).reshape(-1, LANES)

    m = lo.shape[0]
    if m == 0:
        return jnp.zeros((n,), dtype=bool)
    out = jnp.zeros(keys_p.shape, dtype=jnp.int32)
    for a0 in range(0, m, MAX_AREAS_PER_CALL):
        a1 = min(m, a0 + MAX_AREAS_PER_CALL)
        out = out | interval_query_pallas(
            keys_p, seqs_p, lo[a0:a1], hi[a0:a1], smin[a0:a1], smax[a0:a1],
            block_rows=block_rows, interpret=interpret)
    return out.reshape(-1)[:n].astype(bool)
