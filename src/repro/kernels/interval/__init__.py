from .ops import interval_query

__all__ = ["interval_query"]
