"""Pallas kernel: batched point-stab query over a disjoint DR-tree level.

This is the TPU-native form of the DR-tree descent (paper §4.2): because
disjointized areas are key-sorted and non-overlapping, "which node covers
key v" is a single lower-bound binary search — no multi-child descent.  The
level's four arrays (lo, hi, smin, smax) are VMEM-resident; a grid of
(rows x 128) query tiles runs a fixed-depth vectorized binary search on the
VPU, then one gather + rectangle test per query.

Levels larger than VMEM are chunked at the ops layer: chunks own disjoint
key ranges, so per-chunk verdicts OR together.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _interval_kernel(keys_ref, seqs_ref, lo_ref, hi_ref, smin_ref, smax_ref,
                     out_ref, *, n: int, steps: int):
    keys = keys_ref[...]  # (rows, LANES) uint32
    seqs = seqs_ref[...]
    lo = lo_ref[...].reshape(-1)
    hi = hi_ref[...].reshape(-1)
    smin = smin_ref[...].reshape(-1)
    smax = smax_ref[...].reshape(-1)

    # Vectorized lower-bound: idx = (# of lo[j] <= key) - 1, via fixed-depth
    # binary search (steps = ceil(log2(n)) iterations, data-independent).
    left = jnp.zeros(keys.shape, dtype=jnp.int32)
    right = jnp.full(keys.shape, n, dtype=jnp.int32)

    def body(_, lr):
        left, right = lr
        active = left < right  # fixed-depth loop: freeze once converged
        mid = (left + right) // 2
        midc = jnp.clip(mid, 0, n - 1)
        go_right = jnp.take(lo, midc, axis=0) <= keys
        left = jnp.where(active & go_right, mid + 1, left)
        right = jnp.where(active & ~go_right, mid, right)
        return left, right

    left, right = jax.lax.fori_loop(0, steps, body, (left, right))
    idx = left - 1
    idxc = jnp.maximum(idx, 0)
    covered = (idx >= 0) \
        & (keys < jnp.take(hi, idxc, axis=0)) \
        & (jnp.take(smin, idxc, axis=0) <= seqs) \
        & (seqs < jnp.take(smax, idxc, axis=0))
    out_ref[...] = covered.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def interval_query_pallas(keys32, seqs32, lo, hi, smin, smax, *,
                          block_rows: int = 8,
                          interpret: bool = True) -> jnp.ndarray:
    """keys32/seqs32: (rows, 128) uint32; level arrays: (n,) uint32.

    Returns int32 {0,1} (rows, 128): is (key, seq) covered by the level?"""
    n = lo.shape[0]
    rows = keys32.shape[0]
    assert rows % block_rows == 0
    steps = max(1, math.ceil(math.log2(n + 1)) + 1)  # converge + safety
    grid = (rows // block_rows,)
    full = lambda arr: pl.BlockSpec((arr.shape[0],), lambda i: (0,))
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_interval_kernel, n=n, steps=steps),
        grid=grid,
        in_specs=[tile, tile, full(lo), full(hi), full(smin), full(smax)],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(keys32, seqs32, lo, hi, smin, smax)
