"""Pure-jnp oracle for the interval point-stab kernel."""

from __future__ import annotations

import jax.numpy as jnp


def interval_query_ref(keys32, seqs32, lo, hi, smin, smax) -> jnp.ndarray:
    """keys/seqs any-shape uint32; level arrays (n,) uint32 sorted by lo,
    key-disjoint.  Returns int32 {0,1}."""
    idx = jnp.searchsorted(lo, keys32.reshape(-1), side="right").astype(
        jnp.int32) - 1
    idx = idx.reshape(keys32.shape)
    idxc = jnp.maximum(idx, 0)
    covered = (idx >= 0) \
        & (keys32 < jnp.take(hi, idxc, axis=0)) \
        & (jnp.take(smin, idxc, axis=0) <= seqs32) \
        & (seqs32 < jnp.take(smax, idxc, axis=0))
    return covered.astype(jnp.int32)
