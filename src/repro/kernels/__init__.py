"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package follows the kernel.py (pl.pallas_call + BlockSpec) /
ops.py (jit'd public wrapper) / ref.py (pure-jnp oracle) layout and is
validated in interpret mode against the oracle across shape/dtype sweeps.

  bloom            batched Bloom-filter probe (SSTable filters, RAE/EVE)
  interval         batched point-stab query over disjoint DR-tree levels
  merge            tournament merge-rank over sorted runs (scan merge-back)
  cascade          fused all-levels bloom + fence + GLORAN lookup cascade
  flash_attention  blocked causal/windowed GQA attention (serving prefill)
  ssd              Mamba2 state-space-duality chunked scan
"""

from .bloom.ops import bloom_probe
from .cascade.ops import CascadeState, cascade_lookup
from .interval.ops import interval_query
from .merge.ops import merge_ranks
from .flash_attention.ops import flash_attention
from .ssd.ops import ssd_chunked_scan

__all__ = ["bloom_probe", "interval_query", "merge_ranks",
           "CascadeState", "cascade_lookup",
           "flash_attention", "ssd_chunked_scan"]
