"""Oracles for the merge-rank kernel (host numpy + pure jnp)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def merge_ranks_np(ka: np.ndarray, kb: np.ndarray):
    """Host oracle: the exact position pair ``lsm.merge.merge_two``
    computes.  ``pa[i]`` is the merged-output slot of ``ka[i]``, ``pb``
    likewise; ties across runs place a-entries first."""
    na, nb = len(ka), len(kb)
    pa = np.arange(na) + np.searchsorted(kb, ka, side="left")
    pb = np.arange(nb) + np.searchsorted(ka, kb, side="right")
    return pa, pb


def merge_ranks_ref(ka, kb):
    """Pure-jnp oracle (jit-compilable): same convention as
    ``merge_ranks_np``."""
    ka = jnp.asarray(ka)
    kb = jnp.asarray(kb)
    pa = jnp.arange(ka.shape[0], dtype=jnp.int32) + \
        jnp.searchsorted(kb, ka, side="left").astype(jnp.int32)
    pb = jnp.arange(kb.shape[0], dtype=jnp.int32) + \
        jnp.searchsorted(ka, kb, side="right").astype(jnp.int32)
    return pa, pb
