"""Public dispatch for the merge-rank kernel.

Pads the query side to (rows x 128) tiles, chunks VMEM-oversized
resident runs (contiguous sorted slices — per-chunk counts add), and
offers a jit'd XLA fallback (``compiled=True``) for backends where
Pallas can only interpret (CPU): there the searchsorted pair compiles
through XLA instead, so CI exercises a compiled artifact everywhere
while TPUs compile the Pallas kernel itself.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...obs import span
from .kernel import LANES, merge_rank_pallas
from .ref import merge_ranks_ref

# 4 B x 1 Mi = 4 MB resident run per call keeps run + tiles under VMEM.
MAX_KEYS_PER_CALL = 1 << 20


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


_merge_ranks_xla = jax.jit(merge_ranks_ref)


def _as_dev(arr, device, dtype=jnp.uint32):
    """Upload one operand, committed to ``device`` when one is given.

    Merge rounds have no persistent device-resident state (both runs
    arrive as host numpy every call), so unlike the filter kernels the
    merge path strictly needs explicit placement to run per shard —
    uncommitted uploads would all land on the default device."""
    if device is not None:
        return jax.device_put(np.asarray(arr, np.uint32), device)
    return jnp.asarray(arr, dtype)


def _rank(queries: np.ndarray, arr: np.ndarray, *, leq: bool,
          block_rows: int, interpret: bool, device) -> np.ndarray:
    """Counts of ``arr`` elements preceding each query (chunk-summed)."""
    q32 = _as_dev(queries, device)
    n = q32.shape[0]
    tile = block_rows * LANES
    n_pad = -n % tile
    q = jnp.pad(q32, (0, n_pad)).reshape(-1, LANES)
    total = jnp.zeros(q.shape, dtype=jnp.int32)
    m = arr.shape[0]
    for a0 in range(0, m, MAX_KEYS_PER_CALL):
        a1 = min(m, a0 + MAX_KEYS_PER_CALL)
        total = total + merge_rank_pallas(
            q, _as_dev(arr[a0:a1], device), leq=leq,
            block_rows=block_rows, interpret=interpret)
    return np.asarray(total).reshape(-1)[:n]


def merge_ranks(ka: np.ndarray, kb: np.ndarray, *, block_rows: int = 8,
                interpret: bool | None = None,
                compiled: bool = False, device=None):
    """Merged-output positions of two key-sorted uint32 runs.

    Returns ``(pa, pb)`` int64 numpy arrays: ``pa[i]`` is the slot of
    ``ka[i]`` in the merged order, ``pb`` likewise; ties across runs
    place a-entries first — bit-exact with the host searchsorted pair in
    ``lsm.merge.merge_two`` (duplicates within and across runs allowed).

    ``compiled=True`` routes through the jit'd XLA path instead of the
    Pallas kernel; the default Pallas path interprets off-TPU.
    ``device`` commits both runs to one XLA device so the launch runs
    there (per-shard placement).
    """
    ka = np.asarray(ka)
    kb = np.asarray(kb)
    with span("kernel.merge", n=len(ka) + len(kb)):
        return _merge_ranks(ka, kb, block_rows=block_rows,
                            interpret=interpret, compiled=compiled,
                            device=device)


def _merge_ranks(ka, kb, *, block_rows, interpret, compiled, device):
    na, nb = len(ka), len(kb)
    if interpret is None:
        interpret = _default_interpret()
    if compiled:
        pa, pb = _merge_ranks_xla(_as_dev(ka, device),
                                  _as_dev(kb, device))
        return (np.asarray(pa).astype(np.int64),
                np.asarray(pb).astype(np.int64))
    ra = _rank(ka, kb, leq=False, block_rows=block_rows,
               interpret=interpret, device=device)
    rb = _rank(kb, ka, leq=True, block_rows=block_rows,
               interpret=interpret, device=device)
    return (np.arange(na, dtype=np.int64) + ra,
            np.arange(nb, dtype=np.int64) + rb)
