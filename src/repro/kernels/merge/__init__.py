"""Device-side sorted-view merge: tournament merge-rank kernel.

An LSM range scan merges one sorted slice per run.  The position of
every element in the merged output is its *rank*: its own index plus
the count of elements from the other run that precede it — exactly the
searchsorted pair ``lsm.merge.merge_two`` computes on the host.  This
package lifts that rank computation onto device as a Pallas kernel
(fixed-depth vectorized binary search per query tile, the same shape as
``kernels.interval``), so the k-way tournament's O(n log n) compare
work runs on the VPU and the host only scatters.
"""

from .ops import merge_ranks
from .ref import merge_ranks_ref

__all__ = ["merge_ranks", "merge_ranks_ref"]
