"""Pallas kernel: merge-rank (vectorized binary search) over a sorted run.

One tournament-merge round of two sorted runs needs, per element, the
count of elements of the *other* run that precede it (strictly or
non-strictly, depending on the tie side).  That count is a lower/upper
bound binary search — data-independent depth, so it vectorizes exactly
like the interval point-stab kernel: the resident run is VMEM-whole, a
grid of (rows x 128) query tiles runs a fixed-depth search on the VPU.

Runs larger than VMEM are chunked at the ops layer: a sorted run's
chunks are contiguous sorted slices, so per-chunk counts ADD together.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _rank_kernel(q_ref, arr_ref, out_ref, *, n: int, steps: int, leq: bool):
    q = q_ref[...]  # (rows, LANES) uint32 queries
    arr = arr_ref[...].reshape(-1)  # (n,) sorted uint32

    # Fixed-depth binary search: left converges to the count of arr
    # elements < q (leq=False, searchsorted 'left') or <= q (leq=True,
    # searchsorted 'right').
    left = jnp.zeros(q.shape, dtype=jnp.int32)
    right = jnp.full(q.shape, n, dtype=jnp.int32)

    def body(_, lr):
        left, right = lr
        active = left < right  # freeze converged lanes
        mid = (left + right) // 2
        midc = jnp.clip(mid, 0, n - 1)
        v = jnp.take(arr, midc, axis=0)
        go_right = (v <= q) if leq else (v < q)
        left = jnp.where(active & go_right, mid + 1, left)
        right = jnp.where(active & ~go_right, mid, right)
        return left, right

    left, right = jax.lax.fori_loop(0, steps, body, (left, right))
    out_ref[...] = left


@functools.partial(jax.jit, static_argnames=("leq", "block_rows",
                                             "interpret"))
def merge_rank_pallas(q, arr, *, leq: bool, block_rows: int = 8,
                      interpret: bool = True) -> jnp.ndarray:
    """q: (rows, 128) uint32 queries; arr: (n,) sorted uint32.

    Returns int32 (rows, 128): per query, the count of ``arr`` elements
    strictly below it (``leq=False``) or at-or-below it (``leq=True``)
    — bit-exact with ``np.searchsorted(arr, q, side='left'/'right')``.
    """
    n = arr.shape[0]
    rows = q.shape[0]
    assert rows % block_rows == 0
    steps = max(1, math.ceil(math.log2(n + 1)) + 1)  # converge + safety
    grid = (rows // block_rows,)
    full = pl.BlockSpec((n,), lambda i: (0,))
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_rank_kernel, n=n, steps=steps, leq=leq),
        grid=grid,
        in_specs=[tile, full],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(q, arr)
