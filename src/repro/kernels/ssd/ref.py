"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Semantics (Mamba2, arXiv:2405.21060): per head h with state size N,
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T     (N x P state)
    y_t = C_t @ h_t
``ssd_ref`` materializes the quadratic dual form (for tests);
``ssd_chunked_ref`` is the chunked linear-time algorithm in plain jnp —
the differentiable training path and the oracle for the Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """Quadratic reference.

    x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative); B, C: (b, s, n).
    Returns y: (b, s, h, p).  (Single B/C group shared across heads.)
    """
    b, s, h, p = x.shape
    da = dt * A[None, None, :]  # (b,s,h)
    cum = jnp.cumsum(da, axis=1)
    # G[t, u] = exp(cum_t - cum_u) for u <= t.
    G = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b,t,u,h)
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    CB = jnp.einsum("btn,bun->btu", C.astype(jnp.float32),
                    B.astype(jnp.float32))
    M = jnp.where(causal[None, :, :, None], G * CB[:, :, :, None], 0.0)
    xdt = x.astype(jnp.float32) * dt[..., None]
    y = jnp.einsum("btuh,buhp->bthp", M, xdt)
    return y.astype(x.dtype)


def _chunk_intra(x, dac, dt, Bc, Cc):
    """Intra-chunk dual form + end-of-chunk state (jnp; mirrors kernel.py).

    x: (q, p); dac: (q,) inclusive cumsum of dt*A within chunk; dt: (q,);
    Bc, Cc: (q, n).  Returns (y_intra (q, p), state (n, p)).
    """
    CB = Cc.astype(jnp.float32) @ Bc.astype(jnp.float32).T  # (q,q)
    L = jnp.exp(dac[:, None] - dac[None, :])
    L = jnp.where(jnp.tril(jnp.ones(L.shape, dtype=bool)), L, 0.0)
    M = CB * L * dt[None, :]
    y_intra = M @ x.astype(jnp.float32)
    decay_to_end = jnp.exp(dac[-1] - dac)
    state = (Bc.astype(jnp.float32) * (decay_to_end * dt)[:, None]).T \
        @ x.astype(jnp.float32)
    return y_intra, state


def ssd_chunked_ref(x, dt, A, B, C, *, chunk: int = 64,
                    return_final: bool = False):
    """Linear-time chunked SSD in jnp (differentiable; oracle for kernel).

    Shapes as in ``ssd_ref``; s must be a multiple of ``chunk``.
    ``return_final=True`` also returns the end-of-sequence recurrent state
    h (b, h, n, p) — needed by prefill to seed decode.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    da = (dt * A[None, None, :]).astype(jnp.float32)
    dac = jnp.cumsum(da.reshape(b, nc, chunk, h), axis=2)  # (b,nc,q,h)

    xq = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtq = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bq = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cq = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    # Intra-chunk dual form, vectorized over (b, nc, h) with einsums.
    CB = jnp.einsum("bctn,bcun->bctu", Cq, Bq)  # (b,nc,q,q)
    L = jnp.exp(dac[:, :, :, None, :] - dac[:, :, None, :, :])  # (b,nc,t,u,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    M = jnp.where(causal[None, None, :, :, None],
                  CB[..., None] * L * dtq[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", M, xq)
    # End-of-chunk states.
    decay_to_end = jnp.exp(dac[:, :, -1:, :] - dac)  # (b,nc,q,h)
    states = jnp.einsum("bcun,bcuh,bcuhp->bchnp", Bq, decay_to_end * dtq, xq)
    chunk_decay = jnp.exp(dac[:, :, -1, :])  # (b, nc, h)

    def scan_fn(hprev, inp):
        st, dec = inp  # (b,h,n,p), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), dtype=jnp.float32)
    h_final, hprevs = jax.lax.scan(
        scan_fn, h0, (states.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (b,nc,h,n,p)
    # Inter-chunk contribution: y_t += (C_t * exp(dac_t)) @ h_prev_chunk.
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cq, jnp.exp(dac), hprevs)
    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    if return_final:
        return y, h_final
    return y
