"""Pallas kernel: Mamba2 SSD intra-chunk dual form + chunk states.

TPU adaptation of the SSD algorithm: the quadratic *intra-chunk* piece is
an MXU-friendly (chunk x chunk) matmul per (batch x head, chunk) grid cell
with all operands VMEM-resident; the strictly-sequential inter-chunk state
recurrence stays outside the kernel (a tiny lax.scan over nc steps in
ops.py) — recomputing it inside the kernel would serialize the grid.

Grid: (B*H, n_chunks).  Per cell:
  y_intra = ((C B^T) .* L) @ (x * dt),  L[t,u] = exp(dac_t - dac_u) (u<=t)
  state   = (B .* (exp(dac_last - dac) * dt))^T @ x        (N x P)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dac_ref, dt_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0].astype(jnp.float32)  # (q, p)
    dac = dac_ref[0, 0].astype(jnp.float32)  # (q, 1)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (q, 1)
    Bc = b_ref[0, 0].astype(jnp.float32)  # (q, n)
    Cc = c_ref[0, 0].astype(jnp.float32)  # (q, n)
    q = x.shape[0]

    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q,q)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(col <= row, jnp.exp(dac - dac.reshape(1, q)), 0.0)
    M = CB * L * dt.reshape(1, q)
    y_ref[0, 0] = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    decay_to_end = jnp.exp(dac[q - 1, 0] - dac)  # (q,1)
    Bw = Bc * (decay_to_end * dt)
    st_ref[0, 0] = jax.lax.dot_general(
        Bw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunks_pallas(xq, dac, dtq, Bq, Cq, *, interpret: bool = True):
    """xq: (BH, nc, q, p); dac/dtq: (BH, nc, q, 1); Bq/Cq: (BH, nc, q, n).

    Returns (y_intra (BH, nc, q, p) f32, states (BH, nc, n, p) f32)."""
    bh, nc, q, p = xq.shape
    n = Bq.shape[-1]
    grid = (bh, nc)
    blk = lambda shp: pl.BlockSpec((1, 1) + shp, lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[blk((q, p)), blk((q, 1)), blk((q, 1)), blk((q, n)),
                  blk((q, n))],
        out_specs=[blk((q, p)), blk((n, p))],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nc, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xq, dac, dtq, Bq, Cq)
