"""Public wrapper: chunked SSD scan (Pallas intra-chunk + host-level
inter-chunk recurrence).

``use_kernel=False`` (default off-TPU training) routes everything through
the differentiable jnp reference; ``use_kernel=True`` uses the Pallas
kernel for the intra-chunk dual form and states.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_chunks_pallas
from .ref import ssd_chunked_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd_chunked_scan(x, dt, A, B, C, *, chunk: int = 64,
                     use_kernel: bool = False, interpret: bool | None = None,
                     return_final: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, n).

    Returns y: (b, s, h, p), plus the final recurrent state when
    ``return_final=True``."""
    if not use_kernel:
        return ssd_chunked_ref(x, dt, A, B, C, chunk=chunk,
                               return_final=return_final)
    if interpret is None:
        interpret = _default_interpret()
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    da = (dt * A[None, None, :]).astype(jnp.float32)
    dac = jnp.cumsum(da.reshape(b, nc, chunk, h), axis=2)

    # Pack to (B*H, nc, q, ...) for the kernel grid.
    xq = x.reshape(b, nc, chunk, h, p).transpose(0, 3, 1, 2, 4) \
        .reshape(b * h, nc, chunk, p)
    dacq = dac.transpose(0, 3, 1, 2).reshape(b * h, nc, chunk, 1)
    dtq = dt.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2) \
        .reshape(b * h, nc, chunk, 1).astype(jnp.float32)
    Bq = jnp.broadcast_to(
        B.reshape(b, 1, nc, chunk, n),
        (b, h, nc, chunk, n)).reshape(b * h, nc, chunk, n)
    Cq = jnp.broadcast_to(
        C.reshape(b, 1, nc, chunk, n),
        (b, h, nc, chunk, n)).reshape(b * h, nc, chunk, n)

    y_intra, states = ssd_chunks_pallas(xq, dacq, dtq, Bq, Cq,
                                        interpret=interpret)

    chunk_decay = jnp.exp(dacq[:, :, -1, 0])  # (BH, nc)

    def scan_fn(hprev, inp):
        st, dec = inp  # (BH, n, p), (BH,)
        hnew = hprev * dec[:, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b * h, n, p), dtype=jnp.float32)
    h_final, hprevs = jax.lax.scan(scan_fn, h0,
                                   (states.transpose(1, 0, 2, 3),
                                    chunk_decay.transpose(1, 0)))
    hprevs = hprevs.transpose(1, 0, 2, 3)  # (BH, nc, n, p)
    Cw = Cq * jnp.exp(dacq)  # (BH, nc, q, n)
    y_inter = jnp.einsum("kcqn,kcnp->kcqp", Cw, hprevs)
    y = (y_intra + y_inter).reshape(b, h, nc, chunk, p) \
        .transpose(0, 2, 3, 1, 4).reshape(b, s, h, p).astype(x.dtype)
    if return_final:
        return y, h_final.reshape(b, h, n, p)
    return y
