from .ops import ssd_chunked_scan

__all__ = ["ssd_chunked_scan"]
