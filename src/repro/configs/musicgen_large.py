"""MusicGen-Large: decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.  The EnCodec audio
frontend is a STUB: input_specs() supplies precomputed frame embeddings
(B, S, d_model); the backbone + LM head over the codebook vocab are real.
Pure full attention -> long_500k is skipped (DESIGN.md §long_500k).
"""

from .base import ModelConfig

config = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    stub_frontend="audio",
    source="arXiv:2306.05284; hf",
)
