"""Architecture registry: the 10 assigned archs + the paper-system config.

Usage: ``get_config("mixtral-8x7b")`` / ``--arch mixtral-8x7b`` in the
launchers.  ``ARCHS`` lists every id; each module defines ``config``.
"""

from .base import SHAPES, ModelConfig, MoEConfig, ShapeConfig, SSMConfig, smoke

from . import (chatglm3_6b, gemma3_1b, h2o_danube3_4b, kimi_k2_1t_a32b,
               mamba2_130m, minitron_8b, mixtral_8x7b, musicgen_large,
               paligemma_3b, zamba2_7b)

_REGISTRY = {
    m.config.name: m.config
    for m in (musicgen_large, mixtral_8x7b, kimi_k2_1t_a32b, minitron_8b,
              h2o_danube3_4b, chatglm3_6b, gemma3_1b, mamba2_130m,
              zamba2_7b, paligemma_3b)
}

ARCHS = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return _REGISTRY[name]


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "MoEConfig", "ShapeConfig",
           "SSMConfig", "get_config", "smoke"]
