"""Architecture + shape configuration dataclasses.

One ``ModelConfig`` describes any member of the assigned architecture pool
(dense / MoE / SSM / hybrid / VLM / audio backbones); ``ShapeConfig`` is one
input-shape cell; ``smoke()`` derives the reduced same-family config used by
CPU smoke tests (FULL configs are only ever lowered via ShapeDtypeStructs).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 14336  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    shared_expert: bool = False  # kimi-style shared expert alongside routed


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64  # P per SSM head
    expand: int = 2  # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    window: int | None = None  # sliding-window size (SWA archs)
    local_global: int | None = None  # gemma3: N local layers per 1 global
    local_window: int | None = None  # window of the local layers
    hybrid_attn_every: int | None = None  # zamba2: shared attn period
    rope_fraction: float = 1.0  # chatglm applies RoPE to half the dims
    stub_frontend: str | None = None  # 'audio' | 'vision' (embeddings input)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # Distribution knobs (see DESIGN.md):
    optimizer: str = "adamw"  # kimi-k2 -> "adafactor"
    remat: str = "full"  # full | none
    scan_layers: bool = True
    sharding_overrides: dict = field(default_factory=dict)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (bounded attention state or
        attention-free; see DESIGN.md §long_500k skips)."""
        return (self.family in ("ssm", "hybrid") or self.window is not None
                or self.local_global is not None)

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, l = self.d_model, self.n_layers
        hd = self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        if self.family == "ssm":
            attn = 0
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_expert * self.moe.n_experts
            if self.moe.shared_expert:
                ffn += 3 * d * self.moe.d_expert
            ffn += d * self.moe.n_experts  # router
        elif self.d_ff > 0:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 0
        ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            ssm = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d + \
                di * self.ssm.conv_width
        per_layer = attn + ffn + ssm + 2 * d
        if self.family == "hybrid":
            nm = l  # mamba layers
            na = max(1, l // (self.hybrid_attn_every or 6))
            per = ssm + 2 * d
            shared = attn + 3 * d * self.d_ff
            return emb + nm * per + shared + 2 * d
        return emb + l * per_layer + 2 * d

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        routed_all = 3 * d * self.moe.d_expert * self.moe.n_experts * l
        routed_act = 3 * d * self.moe.d_expert * self.moe.top_k * l
        return self.n_params() - routed_all + routed_act


@dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        # decode processes 1 new token/sequence against a seq_len cache
        return self.global_batch * (1 if self.kind == "decode"
                                    else self.seq_len)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 7),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_ff=256 if cfg.d_ff > 0 else 0,
        vocab=256,
        head_dim=32,
        window=min(cfg.window, 32) if cfg.window else None,
        local_window=min(cfg.local_window, 16) if cfg.local_window else None,
        local_global=cfg.local_global,
        hybrid_attn_every=3 if cfg.hybrid_attn_every else None,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=min(cfg.moe.n_experts, 8),
                              top_k=min(cfg.moe.top_k, 2), d_expert=64,
                              capacity_factor=2.0,
                              shared_expert=cfg.moe.shared_expert)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                              conv_width=4, chunk=16)
    kw["dtype"] = "float32"
    kw["sharding_overrides"] = {}
    return replace(cfg, **kw)
