"""Gemma3-1B: 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
local window 512.  Per-layer window vector drives the 5 local + 1 global
pattern through a single scanned stack.  4 heads < 16-way model axis ->
head_dim (256) carries the tensor-parallel shard.
"""

from .base import ModelConfig

config = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    local_global=5,
    local_window=512,
    sharding_overrides={"cache_dim": "model"},
    source="hf:google/gemma-3-1b-pt; unverified",
)
