"""Kimi K2: trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2;
unverified paper-table].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, one shared
expert.  ~1.03e12 params: AdamW fp32 state (~14 TB) cannot fit 512 v5e
chips, so this arch uses factored Adafactor states (DESIGN.md §4); note
the single-pod train cell is expected to exceed 16 GB/chip — params+grads
alone are 4.1 TB vs a 4 TB pod (recorded honestly in EXPERIMENTS.md).
"""

from .base import ModelConfig, MoEConfig

config = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=128,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  shared_expert=True),
    optimizer="adafactor",
    source="arXiv:2501.kimi2; unverified",
)
