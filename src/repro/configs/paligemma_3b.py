"""PaliGemma-3B: SigLIP vision encoder + Gemma LM [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216, head_dim=256.
The SigLIP frontend is a STUB: input_specs() supplies precomputed patch
embeddings; the Gemma backbone + head are real.  Full attention ->
long_500k skipped.  8 heads < 16-way model axis -> head_dim shards.
"""

from .base import ModelConfig

config = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    stub_frontend="vision",
    sharding_overrides={"cache_dim": "model"},
    source="arXiv:2407.07726; hf",
)
