"""Zamba2-7B: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 (78 Mamba2 layers in 13 groups of 6 + 3 tail layers; a
single SHARED attention+MLP block applied after each group — per-group
LoRA deltas omitted, noted in DESIGN.md), 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64.  112 SSD heads not 16-divisible -> unsharded;
shared-attn KV cache shards kv_heads (32/16=2).  long_500k runs with the
shared attention in ring-buffer window mode (DESIGN.md).
"""

from .base import ModelConfig, SSMConfig

config = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  chunk=128),
    sharding_overrides={"ssm_heads": None, "cache_dim": None,
                        "cache_heads": "model"},
    source="arXiv:2411.15242; unverified",
)
