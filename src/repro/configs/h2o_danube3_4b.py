"""H2O-Danube3-4B: llama+mistral mix with SWA [arXiv:2401.16818;
unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, window 4096.
head_dim=120: neither kv_heads(8) nor head_dim(120) divides the 16-way
model axis, so the KV cache shards its sequence dim over 'model'
(context-parallel decode) — see sharding_overrides.
"""

from .base import ModelConfig

config = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,
    window=4096,
    sharding_overrides={"cache_dim": None, "cache_seq": "model"},
    source="arXiv:2401.16818; unverified",
)
