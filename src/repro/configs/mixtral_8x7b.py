"""Mixtral-8x7B: sparse MoE, 8 experts top-2, SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, sliding
window 4096.  8 experts < 16-way model axis -> experts replicated, expert
FFN dim tensor-parallel instead (sharding_overrides).
"""

from .base import ModelConfig, MoEConfig

config = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
    sharding_overrides={"experts": None, "expert_out": "model"},
    source="arXiv:2401.04088; hf",
)
