"""ChatGLM3-6B: GQA kv=2, 2-d RoPE (half dims) [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.  rope_fraction=0.5
implements the 2-d RoPE (rotary on half the head dims).  Full attention ->
long_500k skipped.
"""

from .base import ModelConfig

config = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    head_dim=128,
    rope_fraction=0.5,
    source="arXiv:2406.12793; hf",
)
