"""Mamba2-130M: SSD state-space model, attention-free [arXiv:2405.21060;
unverified].

24L d_model=768, ssm_state=128, expand=2 (d_inner=1536, 24 SSD heads of
P=64), vocab=50280.  d_ff=0 (attention-free family).  vocab 50280 is not
16-divisible -> vocab replicated; 24 ssm heads not 16-divisible ->
ssm_heads unsharded, d_inner ('mlp') carries the model shard.
"""

from .base import ModelConfig, SSMConfig

config = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    head_dim=None,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=128),
    sharding_overrides={"vocab": None, "ssm_heads": None},
    source="arXiv:2405.21060; unverified",
)
