"""Production mesh factories + host-platform device placement helpers.

Everything here is a FUNCTION, not a module-level constant: importing
this module never touches jax device state (critical — smoke tests must
see 1 CPU device while the dry-run forces 512 host-platform devices via
XLA_FLAGS before any jax import).

Target: TPU v5e pods.  Single pod = 16x16 = 256 chips, axes
('data', 'model'); multi-pod = 2 x 16 x 16 = 512 chips with a leading
'pod' axis (data-parallel across pods over DCI, model/data parallel over
ICI within a pod).

Device placement for the sharded engine lives here too:
``ensure_host_devices(n)`` requests n host-platform XLA devices on CPU
hosts (a no-op when XLA_FLAGS already forces a count — callers can't
fight over it) and ``shard_devices(n)`` maps n engine shards round-robin
onto the devices that actually materialized.
"""

from __future__ import annotations

import os
import sys

import jax

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def forced_host_device_count() -> int | None:
    """The device count XLA_FLAGS already forces, or None."""
    for tok in os.environ.get("XLA_FLAGS", "").split():
        if tok.startswith(_FORCE_FLAG + "="):
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return None
    return None


def _backends_initialized() -> bool:
    """True once jax has created its XLA clients (the point after which
    the host-platform device count is locked for the process)."""
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge.backends_are_initialized())
    except Exception:  # pragma: no cover - old jax: assume locked
        return True


def ensure_host_devices(n: int) -> int:
    """Request ``n`` host-platform XLA devices (CPU hosts).

    Must run before jax's backends initialize — XLA locks the count at
    client creation.  An existing forced count in XLA_FLAGS is respected
    (never overwritten, so e.g. the dry-run's 512 and an engine's 4
    can't fight; first setting wins) and any other XLA_FLAGS content is
    preserved.  Returns the count that is (or will be) in effect.
    """
    existing = forced_host_device_count()
    if existing is not None:
        return existing
    if _backends_initialized():
        return len(jax.devices())  # too late to force: report reality
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = \
        (flags + " " if flags else "") + f"{_FORCE_FLAG}={int(n)}"
    return int(n)


def shard_devices(n: int, limit: int | None = None) -> list:
    """Home devices for ``n`` engine shards: round-robin over the default
    backend's devices (initializes jax backends — call
    ``ensure_host_devices`` first on CPU hosts that want more than one).
    ``limit`` restricts the pool to the first ``limit`` devices.
    """
    devs = jax.devices()
    if limit is not None:
        devs = devs[:max(1, min(int(limit), len(devs)))]
    return [devs[i % len(devs)] for i in range(int(n))]


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` only exists on newer jax; older releases default every
    axis to auto sharding anyway, so omitting it is equivalent.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


# v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
