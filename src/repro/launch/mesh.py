"""Production mesh factories.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (critical — smoke tests must see 1 CPU device
while the dry-run forces 512 host-platform devices via XLA_FLAGS before
any jax import).

Target: TPU v5e pods.  Single pod = 16x16 = 256 chips, axes
('data', 'model'); multi-pod = 2 x 16 x 16 = 512 chips with a leading
'pod' axis (data-parallel across pods over DCI, model/data parallel over
ICI within a pod).
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` only exists on newer jax; older releases default every
    axis to auto sharding anyway, so omitting it is equivalent.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


# v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
