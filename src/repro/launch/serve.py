"""Serving launcher CLI: batched decode + GLORAN session registry.

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --smoke
"""

from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHS, get_config, smoke as smoke_cfg
from ..models import Transformer
from ..runtime import ServeLoop, SessionRegistry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--registry", default="gloran",
                    choices=("gloran", "lrr"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    if cfg.stub_frontend is not None:
        raise SystemExit("stub-frontend archs serve via embeddings; use a "
                         "token arch for this CLI")
    model = Transformer(cfg)
    reg = SessionRegistry(strategy=args.registry)
    rng = np.random.default_rng(0)
    sessions = np.arange(args.batch, dtype=np.uint64)
    for s in sessions:
        reg.register(int(s), np.arange(8), np.arange(8))
    loop = ServeLoop(model, batch=args.batch, max_len=args.max_len,
                     registry=reg)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, 8)).astype(np.int32)
    out = loop.run(prompts, steps=args.steps, session_ids=sessions)
    tps = loop.stats.tokens_generated / max(loop.stats.wall_seconds, 1e-9)
    print(f"generated {out.shape}, {tps:.0f} tok/s, registry lookups "
          f"{loop.stats.registry_lookups}")


if __name__ == "__main__":
    main()
