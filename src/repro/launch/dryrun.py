from .mesh import ensure_host_devices
ensure_host_devices(512)
# ^ MUST precede jax backend init: jax locks the device count at first
# client creation (importing jax is fine — backends are lazy).  Routed
# through the shared helper so an XLA_FLAGS count already forced by the
# environment (e.g. an engine run's 4) is respected, never overwritten.
# This is dry-run only — smoke tests and benchmarks see the 1 real device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (shardings
compose, collectives partition, compile succeeds), prints
``memory_analysis()`` (does it fit 16 GB/chip?) and ``cost_analysis()``
(FLOPs/bytes for §Roofline), and records loop-aware collective bytes.

cost_analysis() counts while-loop (scan-over-layers) bodies ONCE, so we
additionally compile a single-layer unit step and combine:
    total ~= step(once-counted) + (L-1) * layer_unit
Collective bytes are loop-aware directly (trip counts parsed from HLO).

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k \
      --mesh multi --out results/
  python -m repro.launch.dryrun --all --mesh both --out results/
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis.roofline import analyze_compiled, collective_bytes
from ..configs import ARCHS, SHAPES, get_config
from ..models import Transformer, tree_abstract, tree_shardings
from ..models.params import ParamSpec, is_spec
from ..launch.mesh import make_production_mesh
from ..launch.steps import (adjust_rules_for_shape, batch_shardings,
                            input_specs, make_decode_step,
                            make_prefill_step, make_train_step,
                            opt_state_shardings, serve_cache_len)
from ..optim.optimizer import OptimizerConfig, make_optimizer


def planned_cells():
    """All 40 (arch x shape) cells; long_500k runs only for sub-quadratic
    archs (skips recorded, per DESIGN.md)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            skip = sname == "long_500k" and not cfg.sub_quadratic
            yield arch, sname, skip


def _drop_layer_dim(specs, mesh, rules):
    """Single-layer slices of stacked specs (for the layer-unit compile)."""
    def f(s: ParamSpec):
        if s.axes and s.axes[0] in ("layers", "groups"):
            return ParamSpec(s.shape[1:], s.axes[1:], s.init, s.scale)
        return s
    return jax.tree.map(f, specs, is_leaf=is_spec)


def model_flops(cfg, shape) -> float:
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    return 2.0 * n * shape.tokens


def lower_cell(arch: str, shape_name: str, mesh_name: str,
               microbatch: int = 1, donate: bool = True,
               variants: tuple[str, ...] = ()) -> dict:
    """variants: §Perf hillclimb knobs —
      mb<k>     gradient accumulation over k microbatches
      ctxcache  context-parallel decode KV cache (seq dim over 'model')
      seqpar    sequence-parallel residual stream (seq over 'model')
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Transformer(cfg)
    specs = model.param_specs()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    adjust_rules_for_shape(model, shape, mesh)
    for v in variants:
        if v.startswith("mb"):
            microbatch = int(v[2:])
        elif v == "ctxcache":
            prev = model.rules.rules.get("cache_seq") or ()
            model.rules = model.rules.with_overrides(
                cache_dim=None,
                cache_seq=tuple(dict.fromkeys(("model",) + tuple(prev))))
        elif v == "seqpar":
            model.rules = model.rules.with_overrides(act_seq="model")
        elif v == "cponly":
            # Small-d archs: TP psums of (tokens x d) dwarf the compute.
            # Drop tensor parallelism entirely; use the 'model' axis for
            # context parallelism (seq-sharded residual; attention
            # all-gathers only the tiny kv=1 heads).
            model.rules = model.rules.with_overrides(
                act_seq="model", q_heads=None, head_dim=None,
                kv_heads=None, mlp=None)
        elif v == "moedecode":
            # Decode: capacity is tiny (C ~ 40), so sharding it is useless
            # and XLA all-gathers expert weights instead; shard the
            # dispatch buffer's d_model dim to match the weights' FSDP
            # axis -> contraction goes local + KB-scale psum.
            model.rules = model.rules.with_overrides(
                expert_in=None, expert_d="data")
        elif v == "nofsdp":
            # Serving: keep weights resident (model-sharded only); ZeRO
            # re-gathers per step are pure waste without a backward pass.
            model.rules = model.rules.with_overrides(embed_fsdp=None)
        else:
            raise ValueError(f"unknown variant {v}")
    rules = model.rules
    params_abs = tree_abstract(specs, jnp.dtype(cfg.dtype))
    params_sh = tree_shardings(specs, mesh, rules)
    batch_abs = input_specs(cfg, shape, model, microbatch=microbatch)
    batch_sh = batch_shardings(cfg, shape, mesh, rules, model)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_cfg = OptimizerConfig(name=cfg.optimizer)
        init_fn, _ = make_optimizer(opt_cfg)
        opt_abs = jax.eval_shape(init_fn, params_abs)
        opt_sh = opt_state_shardings(cfg.optimizer, specs, mesh, rules)
        step = make_train_step(model, opt_cfg, microbatch=microbatch)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1) if donate else ()).lower(
                    params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(model)
        with mesh:
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)) \
                .lower(params_abs, batch_abs)
    else:  # decode
        _, ring = serve_cache_len(cfg, shape)
        step = make_decode_step(model, ring=ring)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, batch_sh["token"],
                              batch_sh["cache"], batch_sh["pos"]),
                donate_argnums=(2,) if donate else ()).lower(
                    params_abs, batch_abs["token"], batch_abs["cache"],
                    jax.ShapeDtypeStruct((), jnp.int32))
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    rep = analyze_compiled(compiled, arch=arch, shape=shape_name,
                           mesh_name=mesh_name, chips=chips,
                           model_flops=model_flops(cfg, shape))

    # ---- layer-unit compile: recover scan-body flops/bytes x L ----------
    unit = _layer_unit(model, cfg, shape, mesh, rules, specs)
    if unit is not None:
        u_flops, u_bytes, n_units = unit  # per-device -> global (x chips)
        rep.hlo_flops += u_flops * chips * max(0, n_units - 1)
        rep.hlo_bytes += u_bytes * chips * max(0, n_units - 1)

    out = rep.to_dict()
    out.update({
        "_migrated_global": True,  # metrics are global (x chips) already
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "microbatch": microbatch,
        "fits_16g": (out["memory_per_device"].get("temp_bytes", 0) +
                     out["memory_per_device"].get("argument_bytes", 0))
        < 16e9 if out["memory_per_device"] else None,
        "params": int(cfg.n_params()),
        "active_params": int(cfg.n_active_params()),
    })
    return out


def _multi_unit(model, cfg, shape, mesh, rules, layer_specs, classes,
                unit_fwd_for, b, s):
    """Weighted per-window-class layer units: sum(count_w x unit_w),
    reported as (flops, bytes, n_units=2) so the caller's x(n-1) yields
    the weighted total minus one (approximating the once-counted body)."""
    from ..models import tree_abstract, tree_shardings
    total_f, total_b = 0.0, 0.0
    lp_abs = tree_abstract(layer_specs, jnp.dtype(cfg.dtype))
    lp_sh = tree_shardings(layer_specs, mesh, rules)
    x_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    x_sh = jax.sharding.NamedSharding(
        mesh, rules.spec(("batch", "act_seq", "embed"),
                         tuple(mesh.axis_names)))
    for wval, count in classes:
        fwd = unit_fwd_for(wval)
        if shape.kind == "train":
            def unit(lp, x, _f=fwd):
                def g(lp_, x_):
                    return _f(lp_, x_).astype(jnp.float32).sum()
                return jax.grad(g, argnums=(0, 1))(lp, x)
        else:
            unit = fwd
        try:
            with mesh:
                c = jax.jit(unit, in_shardings=(lp_sh, x_sh)).lower(
                    lp_abs, x_abs).compile()
            ca = c.cost_analysis() or {}
            total_f += float(ca.get("flops", 0.0)) * count
            total_b += float(ca.get("bytes accessed", 0.0)) * count
        except Exception:
            traceback.print_exc()
            return None
    # Caller adds unit x (n_units - 1); encode the weighted sum directly.
    return total_f, total_b, 2


def _layer_unit(model, cfg, shape, mesh, rules, specs):
    """Compile one scanned-layer body (fwd, or fwd+bwd for train) and
    return (flops, bytes, n_units) per device."""
    try:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            s = 1
        x_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
        x_sh = jax.sharding.NamedSharding(
            mesh, rules.spec(("batch", None, "embed"),
                             tuple(mesh.axis_names)))
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            layer_specs = _drop_layer_dim(specs["layers"], mesh, rules)
            # Heterogeneous stacks (gemma3 local:global): weight units per
            # distinct window class so banded local layers are costed
            # correctly, not as full attention.
            import numpy as _np
            wins = _np.asarray(model._window_vector())
            classes = [(int(w), int((wins == w).sum()))
                       for w in _np.unique(wins)]
            n_units = cfg.n_layers

            def unit_fwd_for(wval):
                def unit_fwd(lp, x):
                    import jax.numpy as jnp2
                    pos = jnp2.broadcast_to(
                        jnp2.arange(s, dtype=jnp2.int32)[None], (b, s))
                    out, _ = model._block_dense(x, lp, jnp2.int32(wval),
                                                pos, None, None)
                    return out
                return unit_fwd

            if len(classes) > 1:
                return _multi_unit(model, cfg, shape, mesh, rules,
                                   layer_specs, classes, unit_fwd_for, b, s)
            unit_fwd = unit_fwd_for(classes[0][0])
        elif cfg.family == "ssm":
            layer_specs = _drop_layer_dim(specs["layers"]["mamba"], mesh,
                                          rules)
            n_units = cfg.n_layers

            def unit_fwd(lp, x):
                out, _ = model._block_mamba(x, lp, None)
                return out
        else:  # hybrid: one group (inner scan of `per` mamba + shared attn)
            per = cfg.hybrid_attn_every or 6
            n_units = cfg.n_layers // per
            gspecs = _drop_layer_dim(specs["groups"], mesh, rules)
            shared = {"shared_attn": specs["shared_attn"],
                      "shared_mlp": specs["shared_mlp"]}
            layer_specs = {"group": gspecs, **shared}

            def unit_fwd(lp, x):
                import jax.numpy as jnp2
                pos = jnp2.broadcast_to(
                    jnp2.arange(s, dtype=jnp2.int32)[None], (b, s))
                fake_params = {"groups": jax.tree.map(
                    lambda a: a[None], lp["group"]),
                    "shared_attn": lp["shared_attn"],
                    "shared_mlp": lp["shared_mlp"]}
                return model._hybrid_forward(fake_params, x, pos)

        lp_abs = tree_abstract(layer_specs, jnp.dtype(cfg.dtype))
        lp_sh = tree_shardings(layer_specs, mesh, rules)

        if shape.kind == "train":
            def unit(lp, x):
                def f(lp_, x_):
                    return unit_fwd(lp_, x_).astype(jnp.float32).sum()
                return jax.grad(f, argnums=(0, 1))(lp, x)
        else:
            unit = unit_fwd

        with mesh:
            c = jax.jit(unit, in_shardings=(lp_sh, x_sh)).lower(
                lp_abs, x_abs).compile()
        ca = c.cost_analysis() or {}
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)), n_units)
    except Exception:
        traceback.print_exc()
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--variants", default="",
                    help="comma list: mb8,ctxcache,seqpar")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    variants = tuple(v for v in args.variants.split(",") if v)

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a, s, skip in planned_cells() if not skip]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip cached] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = lower_cell(arch, shape, mesh_name,
                                 microbatch=args.microbatch,
                                 variants=variants)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"  ok: compile={res['compile_s']}s "
                      f"flops={res['hlo_flops']:.3e} "
                      f"coll={res['coll_bytes']:.3e} "
                      f"bottleneck={res['bottleneck']} "
                      f"mem={res['memory_per_device']}", flush=True)
            except Exception as e:
                failures += 1
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
