"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --smoke --steps 50

--smoke runs the reduced same-family config on local devices (CPU);
without it the FULL config is used (requires real accelerators; on this
container use repro.launch.dryrun to exercise full configs).
"""

from __future__ import annotations

import argparse

from ..configs import ARCHS, get_config, smoke as smoke_cfg
from ..data import PipelineConfig, TokenPipeline
from ..models import Transformer, count_params
from ..optim import OptimizerConfig
from ..runtime import TrainLoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    model = Transformer(cfg)
    print(f"{cfg.name} [{cfg.family}] "
          f"params={count_params(model.param_specs()) / 1e6:.1f}M")
    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, global_batch=args.global_batch, seq_len=args.seq,
        seed=0, emit_embeddings=cfg.stub_frontend is not None,
        d_model=cfg.d_model))
    res = run_training(model, pipe, TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=max(10, args.steps // 4),
        checkpoint_dir=args.ckpt_dir, microbatch=args.microbatch),
        opt_cfg=OptimizerConfig(name=cfg.optimizer, warmup_steps=10,
                                decay_steps=args.steps))
    print(f"done: steps={res.final_step} loss {res.losses[0]:.3f} -> "
          f"{res.losses[-1]:.3f} retries={res.retries}")


if __name__ == "__main__":
    main()
