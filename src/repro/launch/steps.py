"""Step builders: train_step / prefill_step / decode_step + input_specs.

These are the functions the dry-run lowers and the runtime drivers jit.
``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input (no device allocation); ``*_shardings`` return the matching
NamedSharding trees for pjit in_shardings.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import ModelConfig, ShapeConfig
from ..models import Transformer, tree_abstract, tree_shardings
from ..models.layers import cross_entropy_loss
from ..models.moe import moe_aux_loss
from ..models.params import ParamSpec, is_spec
from ..models.sharding import ShardingRules
from ..optim.optimizer import OptimizerConfig, make_optimizer


# --------------------------------------------------------------- geometry
def serve_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> tuple[int, bool]:
    """(cache_len, ring): SWA archs decode against a ring buffer of the
    window; hybrids switch their shared attention to a 4096 ring for
    long_500k (DESIGN.md)."""
    if cfg.family == "hybrid":
        if shape.name == "long_500k":
            return 4096, True
        return shape.seq_len, False
    if cfg.window is not None and cfg.local_global is None:
        return min(cfg.window, shape.seq_len), True
    return shape.seq_len, False


def adjust_rules_for_shape(model: Transformer, shape: ShapeConfig,
                           mesh) -> None:
    """Divisibility-aware rule adjustment for a concrete (shape x mesh).

    long_500k has global_batch=1: batch can't shard over ('pod','data').
    Fall back to replicated batch and recover parallelism from the cache
    sequence dim (context-parallel decode) — 'data' is otherwise idle in
    a batch-1 decode."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = model.rules.rules.get("batch") or ()
    shards = 1
    for a in batch_axes:
        shards *= sizes.get(a, 1)
    if shards > 1 and shape.global_batch % shards != 0:
        cache_seq = model.rules.rules.get("cache_seq") or ()
        new_seq = tuple(a for a in ("data",) + tuple(cache_seq)
                        if a in sizes)
        model.rules = model.rules.with_overrides(
            batch=None, cache_batch=None, cache_seq=new_seq or None)


# ------------------------------------------------------------ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Transformer,
                microbatch: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.stub_frontend is not None:
            data = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        else:
            data = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        data["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return data
    if shape.kind == "prefill":
        if cfg.stub_frontend is not None:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len cache.
    cache_len, _ = serve_cache_len(cfg, shape)
    cache = jax.eval_shape(lambda: model.init_cache(b, cache_len))
    if cfg.stub_frontend is not None:
        tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
    else:
        tok = jax.ShapeDtypeStruct((b, 1), i32)
    return {"token": tok, "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    rules: ShardingRules, model: Transformer):
    """NamedShardings matching input_specs."""
    ax = tuple(mesh.axis_names)
    bspec = rules.spec(("batch", None), ax)
    bspec3 = rules.spec(("batch", None, "embed"), ax)
    if shape.kind == "train":
        out = {"labels": NamedSharding(mesh, bspec)}
        if cfg.stub_frontend is not None:
            out["embeds"] = NamedSharding(mesh, bspec3)
        else:
            out["tokens"] = NamedSharding(mesh, bspec)
        return out
    if shape.kind == "prefill":
        if cfg.stub_frontend is not None:
            return {"embeds": NamedSharding(mesh, bspec3)}
        return {"tokens": NamedSharding(mesh, bspec)}
    cache_axes = model.cache_logical_axes()
    cache_sh = jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec(axes, ax)), cache_axes,
        is_leaf=lambda x: isinstance(x, tuple))
    if cfg.stub_frontend is not None:
        tok = NamedSharding(mesh, rules.spec(("batch", None, "embed"), ax))
    else:
        tok = NamedSharding(mesh, rules.spec(("batch", None), ax))
    return {"token": tok, "cache": cache_sh,
            "pos": NamedSharding(mesh, PartitionSpec())}


def opt_state_shardings(opt_name: str, specs, mesh, rules: ShardingRules):
    """Optimizer state shards like its parameter (reduced dims dropped)."""
    ax = tuple(mesh.axis_names)

    scalar = NamedSharding(mesh, PartitionSpec())
    if opt_name == "adamw":
        like_param = jax.tree.map(
            lambda s: NamedSharding(mesh, rules.spec(s.axes, ax)), specs,
            is_leaf=is_spec)
        return {"mu": like_param, "nu": like_param, "step": scalar}

    def factored(s: ParamSpec):
        if len(s.shape) >= 2:
            return {"vr": NamedSharding(mesh, rules.spec(s.axes[:-1], ax)),
                    "vc": NamedSharding(
                        mesh, rules.spec(s.axes[:-2] + s.axes[-1:], ax))}
        return {"v": NamedSharding(mesh, rules.spec(s.axes, ax))}

    return {"f": jax.tree.map(factored, specs, is_leaf=is_spec),
            "step": scalar}


# ------------------------------------------------------------------ steps
def make_train_step(model: Transformer, opt_cfg: OptimizerConfig,
                    microbatch: int = 1, aux_loss_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``microbatch > 1`` accumulates gradients over sequential
    microbatches (deferred psum: one optimizer update per global batch)."""
    _, update_fn = make_optimizer(opt_cfg)
    cfg = model.cfg

    def loss_fn(params, data):
        kw = {}
        if "tokens" in data:
            kw["tokens"] = data["tokens"]
        else:
            kw["embeds"] = data["embeds"]
        logits = model.forward_train(params, **kw)
        loss = cross_entropy_loss(logits, data["labels"])
        return loss

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatch, b // microbatch) +
                                 x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, data):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, data)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = lsum / microbatch
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, info = update_fn(params, grads, opt_state)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Transformer):
    def prefill_step(params, batch):
        return model.prefill(params, **batch)

    return prefill_step


def make_decode_step(model: Transformer, ring: bool = False):
    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, ring=ring)

    return decode_step
