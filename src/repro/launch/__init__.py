"""Launchers: mesh factories, dry-run, train/serve CLIs."""
