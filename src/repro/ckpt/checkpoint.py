"""Fault-tolerant checkpointing: atomic, async, elastic.

Layout per step:  <dir>/step_<N>/
    manifest.json   step, mesh shape, pipeline state, rng, leaf index
    arrays.npz      flattened param/optimizer leaves (host-gathered)

Guarantees used by the train loop:
  * atomicity — written to step_<N>.tmp then os.rename'd; a crash mid-save
    never corrupts the latest checkpoint;
  * async — saves run on a writer thread off the step path;
  * keep-last-k — bounded disk;
  * elastic restore — arrays are saved unsharded; ``restore`` re-shards
    onto whatever mesh the new job brings up (different pod/host count).

Single-process container note: on a real cluster each host writes its
addressable shards (Orbax-style); here host-gather is the honest
single-host equivalent and the manifest/atomicity/resume logic is the
production part under test.
"""

from __future__ import annotations

import json
import os
import queue
import threading

import jax
import numpy as np

# Atomic-publication discipline shared with the durability subsystem
# (level manifest, store snapshots) — one implementation, three users.
from ..durable.atomic import (atomic_publish_dir, clear_stale_tmp,
                              keep_last_k, list_versions, versioned_name)

_PREFIX = "step_"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()
        self._error = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state: dict, extra: dict | None = None,
             blocking: bool = False) -> None:
        """state: pytree of arrays (params/opt); extra: JSON-serializable
        (pipeline state, rng seeds, mesh info)."""
        # Materialize to host BEFORE queueing (donated buffers may be
        # overwritten by the next step).
        leaves = [(k, np.asarray(v)) for k, v in
                  _flatten_with_paths(state)]
        job = (step, leaves, extra or {})
        if blocking:
            self._write(job)
        else:
            self._q.put(job)

    def _drain(self):
        while True:
            job = self._q.get()
            try:
                self._write(job)
            except Exception as e:  # surfaced on next wait()
                self._error = e
            self._q.task_done()

    def wait(self):
        self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, job):
        step, leaves, extra = job
        final = os.path.join(self.dir, versioned_name(_PREFIX, step))
        tmp = final + ".tmp"
        clear_stale_tmp(tmp)
        os.makedirs(tmp)
        arrays = {f"a{i}": v for i, (_, v) in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": [k for k, _ in leaves],
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        atomic_publish_dir(tmp, final)
        keep_last_k(self.dir, _PREFIX, self.keep)

    # ------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        return list_versions(self.dir, _PREFIX)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple[dict, dict]:
        """Restore into the structure of ``template``; re-shard with
        ``shardings`` (elastic: any mesh).  Returns (state, extra)."""
        if step is None:
            step = self.latest_step()
            assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
        tmpl_leaves = _flatten_with_paths(template)
        restored = []
        for key, tmpl in tmpl_leaves:
            arr = by_key[key]
            assert tuple(arr.shape) == tuple(tmpl.shape), \
                f"{key}: {arr.shape} != {tmpl.shape}"
            restored.append(arr.astype(tmpl.dtype))
        treedef = jax.tree_util.tree_structure(template)
        state = jax.tree_util.tree_unflatten(treedef, restored)
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            flat_st = treedef.flatten_up_to(state)
            state = jax.tree_util.tree_unflatten(
                treedef,
                [jax.device_put(a, s) for a, s in zip(flat_st, flat_sh)])
        return state, manifest["extra"]
