"""Process-parallel shard execution over shared-memory columnar rings.

The GIL serializes pure-host numpy work (routing, memtable
searchsorted, merge-back, staging folds) and CPU XLA devices share one
thread pool, so pipelined *threads* buy nothing on compute-bound
workloads.  This module moves each shard's ``LSMTree`` +
``ShardExecutor`` into a **worker process** and ships ``ShardPlan``s to
it over ``multiprocessing.shared_memory`` as raw OpBatch columns — no
pickle anywhere on the hot path.

Transport
---------
Each worker owns two SPSC byte rings (one shm segment per direction)
plus two one-way pipes carrying fixed-size tokens.  A ring frame is

    RING_HEADER ("<IBQ": payload_len u32 | mtype u8 | seq u64) | payload

— the WAL frame discipline from ``durable/wal.py`` (length prefix,
type byte, sequence number) minus the crc: the pipe token *is* the
integrity check, naming the exact (mtype, seq, offset, length) the
receiver must find at that ring position.  Frames never wrap: a writer
that would cross the ring edge pads to it and starts at offset 0, so
every payload is one contiguous slice (zero-copy ``np.frombuffer``
decodes).  The reader publishes a consumed watermark (absolute byte
offset, first 8 bytes of the segment); the writer blocks when
``written - consumed`` would exceed capacity.

A plan request's payload is the columnar wire image of the shard plan:

    PLAN_HEADER | step_kinds u8[n_steps] | step_lens u32[n_steps]
                | keys u64[n] | vals u64[n] | los u64[n] | his u64[n]

exactly the arrays a WAL BATCH frame carries, plus step boundaries so
the worker rebuilds the same ``PlanStep`` run structure the planner
emitted.  The reply ships result columns (found/vals for gets,
length-prefixed sorted runs for scans) plus a small JSON aux blob with
the shard's cumulative IOStats / entries / KernelCounters snapshot —
cumulative, not deltas, so the parent's mirrors are **idempotent**
(absorbing the same reply twice cannot double-count).

Ordering / durability invariants (all preserved from the in-process
path): one request pipe per worker + a single-threaded worker loop
gives per-shard FIFO; the worker's ``ShardExecutor`` appends the plan
to its own WAL stream *before* executing it, and the reply token is the
ack — WAL-append-before-ack holds exactly as in-process.  Structure
edits (flush/compaction/GC) are shipped back as described level records
and replayed into the parent's manifest in reply order.
"""

from __future__ import annotations

import json
import os
import struct
import threading
import time
import traceback
from dataclasses import dataclass, replace
from multiprocessing import shared_memory

import numpy as np

from .plan import OP_GET, OP_PUT, OP_RANGE_SCAN, PlanStep, ShardPlan

# ---------------------------------------------------------------- wire

# Pipe token: mtype u8 | seq u64 | ring offset u64 | total length u64 |
# send timestamp f64 (perf_counter — CLOCK_MONOTONIC, comparable across
# processes on Linux, feeding the enqueue->dequeue latency histogram).
TOKEN = struct.Struct("<BQQQd")
# Ring frame prefix: payload_len u32 | mtype u8 | seq u64 (the WAL
# frame-header discipline; crc is replaced by the token cross-check).
RING_HEADER = struct.Struct("<IBQ")
# Plan request: shard u32 | plan seq i64 | n ops u32 | n steps u32 |
# flags u8 (bit0 = tracing on: ship spans back with the reply).
PLAN_HEADER = struct.Struct("<IqIIB")
# Plan reply: shard u32 | plan seq i64 | shard wall f64 |
# n payloads u32 | aux (JSON) length u32.
REP_HEADER = struct.Struct("<IqdII")
# Per-payload prefix inside a reply: op kind u8 | n rows u32.
PAYLOAD_HEADER = struct.Struct("<BI")

MSG_PLAN = 1
MSG_FLUSH = 2
MSG_SCHED = 3
MSG_STATS = 4
MSG_CLOSE = 5
MSG_ERR = 6

FLAG_TRACE = 1


class ShmRing:
    """Single-producer single-consumer byte ring over one shm segment.

    Layout: 16-byte header (consumed watermark u64 at [0:8], written by
    the *reader*; [8:16] reserved) followed by ``capacity`` data bytes.
    Offsets are absolute monotonic byte counters; ``abs % capacity``
    maps into the data region.  Frames are contiguous (pad-to-edge on
    wrap), so a reader always gets one flat slice.
    """

    HDR = 16

    def __init__(self, capacity: int = 0, *, name: str | None = None,
                 create: bool = False):
        if create:
            self.shm = shared_memory.SharedMemory(
                create=True, size=self.HDR + int(capacity))
            self.shm.buf[:self.HDR] = b"\x00" * self.HDR
            self._owner = True
        else:
            self.shm = shared_memory.SharedMemory(name=name)
            self._owner = False
        self.capacity = self.shm.size - self.HDR
        self.written = 0        # writer-local absolute byte counter

    @property
    def name(self) -> str:
        return self.shm.name

    # Reader side -----------------------------------------------------
    def consume_to(self, abs_off: int, total: int) -> None:
        """Publish that everything up to the end of the frame at
        ``abs_off`` has been copied out (covers any pad before it)."""
        self.shm.buf[0:8] = int(abs_off + total).to_bytes(8, "little")

    def read(self, abs_off: int, total: int, mtype: int,
             seq: int) -> bytes:
        """Copy one frame's payload out of the ring, cross-checking the
        ring header against the token that named it."""
        pos = self.HDR + (abs_off % self.capacity)
        raw = bytes(self.shm.buf[pos:pos + total])
        plen, mt, sq = RING_HEADER.unpack_from(raw, 0)
        if (mt, sq, plen) != (mtype, seq, total - RING_HEADER.size):
            raise RuntimeError(
                f"shm ring corruption at offset {abs_off}: frame header "
                f"(type={mt}, seq={sq}, len={plen}) does not match token "
                f"(type={mtype}, seq={seq}, len={total - RING_HEADER.size})")
        return raw[RING_HEADER.size:]

    # Writer side -----------------------------------------------------
    def _consumed(self) -> int:
        return int.from_bytes(bytes(self.shm.buf[0:8]), "little")

    def _wait_space(self, upto: int) -> None:
        while upto - self._consumed() > self.capacity:
            time.sleep(20e-6)

    def write(self, mtype: int, seq: int,
              parts: list[bytes]) -> tuple[int, int]:
        """Append one frame; returns its (absolute offset, total length)
        for the pipe token.  Blocks while the ring is full."""
        payload_len = sum(len(p) for p in parts)
        total = RING_HEADER.size + payload_len
        if total > self.capacity:
            raise RuntimeError(
                f"plan frame of {total} bytes exceeds the shm ring "
                f"capacity ({self.capacity}); raise "
                "EngineConfig.proc_ring_bytes or split the batch")
        pos = self.written % self.capacity
        if pos + total > self.capacity:     # pad to edge, never wrap
            self.written += self.capacity - pos
            pos = 0
        self._wait_space(self.written + total)
        off = self.HDR + pos
        buf = self.shm.buf
        buf[off:off + RING_HEADER.size] = RING_HEADER.pack(
            payload_len, mtype, seq)
        at = off + RING_HEADER.size
        for p in parts:
            buf[at:at + len(p)] = p
            at += len(p)
        abs_off = self.written
        self.written += total
        return abs_off, total

    # Lifecycle -------------------------------------------------------
    def close(self) -> None:
        try:
            self.shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        if self._owner:
            try:
                self.shm.unlink()
            except (OSError, FileNotFoundError):
                pass


# ------------------------------------------------------ plan encoding

def encode_plan(shard: int, sp: ShardPlan, flags: int) -> list[bytes]:
    """Flatten a ShardPlan into the columnar wire image (see module
    docstring).  ``idx`` is NOT shipped — positions are implied by step
    order, and the parent re-associates replies with its own plan."""
    n = sp.n_ops
    n_steps = len(sp.steps)
    step_kinds = np.empty(n_steps, np.uint8)
    step_lens = np.empty(n_steps, np.uint32)
    keys = np.zeros(n, np.uint64)
    vals = np.zeros(n, np.uint64)
    los = np.zeros(n, np.uint64)
    his = np.zeros(n, np.uint64)
    o = 0
    for i, st in enumerate(sp.steps):
        ln = len(st)
        step_kinds[i] = st.kind
        step_lens[i] = ln
        if st.keys is not None:
            keys[o:o + ln] = st.keys
        if st.vals is not None:
            vals[o:o + ln] = st.vals
        if st.los is not None:
            los[o:o + ln] = st.los
            his[o:o + ln] = st.his
        o += ln
    return [PLAN_HEADER.pack(int(shard), int(sp.seq), n, n_steps, flags),
            step_kinds.tobytes(), step_lens.tobytes(), keys.tobytes(),
            vals.tobytes(), los.tobytes(), his.tobytes()]


def decode_plan(payload: bytes) -> tuple[ShardPlan, int]:
    """Worker-side inverse of ``encode_plan`` (synthesizes positional
    ``idx`` runs; the parent never sees them)."""
    shard, seq, n, n_steps, flags = PLAN_HEADER.unpack_from(payload, 0)
    at = PLAN_HEADER.size
    step_kinds = np.frombuffer(payload, np.uint8, n_steps, at)
    at += n_steps
    step_lens = np.frombuffer(payload, np.uint32, n_steps, at)
    at += 4 * n_steps
    cols = []
    for _ in range(4):
        cols.append(np.frombuffer(payload, np.uint64, n, at).copy())
        at += 8 * n
    keys, vals, los, his = cols
    steps, o = [], 0
    for k, ln in zip(step_kinds.tolist(), step_lens.tolist()):
        idx = np.arange(o, o + ln, dtype=np.int64)
        if k in (OP_RANGE_SCAN, 3):                 # OP_RANGE_DELETE = 3
            steps.append(PlanStep(kind=int(k), idx=idx,
                                  los=los[o:o + ln], his=his[o:o + ln]))
        else:
            steps.append(PlanStep(
                kind=int(k), idx=idx, keys=keys[o:o + ln],
                vals=vals[o:o + ln] if k == OP_PUT else None))
        o += ln
    return ShardPlan(shard=int(shard), steps=steps, seq=int(seq)), flags


def encode_reply(shard: int, seq: int, wall: float, payloads: list,
                 aux: dict) -> list[bytes]:
    parts: list[bytes] = []
    for pl in payloads:
        if pl[0] == OP_GET:
            _, _idx, found, vals = pl
            parts.append(PAYLOAD_HEADER.pack(OP_GET, len(found)))
            parts.append(np.ascontiguousarray(
                found, dtype=np.uint8).tobytes())
            parts.append(np.ascontiguousarray(
                vals, dtype=np.uint64).tobytes())
        else:
            _, _idx, results = pl
            lens = np.fromiter((len(k) for k, _v in results),
                               np.uint32, len(results))
            parts.append(PAYLOAD_HEADER.pack(OP_RANGE_SCAN, len(results)))
            parts.append(lens.tobytes())
            for k, v in results:
                parts.append(np.ascontiguousarray(k, np.uint64).tobytes())
                parts.append(np.ascontiguousarray(v, np.uint64).tobytes())
    auxb = json.dumps(aux, default=str).encode()
    head = REP_HEADER.pack(int(shard), int(seq), float(wall),
                           len(payloads), len(auxb))
    return [head, *parts, auxb]


def decode_reply(data: bytes,
                 result_steps: list[PlanStep]) -> tuple[list, float, dict]:
    """Parent-side inverse: rebuild the payload contract the collector
    expects, re-attaching the parent plan's own ``idx`` arrays (replies
    arrive in step order — the worker executes steps in order)."""
    shard, seq, wall, n_payloads, aux_len = REP_HEADER.unpack_from(data, 0)
    at = REP_HEADER.size
    payloads = []
    for i in range(n_payloads):
        kind, n = PAYLOAD_HEADER.unpack_from(data, at)
        at += PAYLOAD_HEADER.size
        st = result_steps[i]
        if kind == OP_GET:
            found = np.frombuffer(data, np.uint8, n, at).astype(bool)
            at += n
            vals = np.frombuffer(data, np.uint64, n, at).copy()
            at += 8 * n
            payloads.append((OP_GET, st.idx, found, vals))
        else:
            lens = np.frombuffer(data, np.uint32, n, at)
            at += 4 * n
            results = []
            for ln in lens.tolist():
                k = np.frombuffer(data, np.uint64, ln, at).copy()
                at += 8 * ln
                v = np.frombuffer(data, np.uint64, ln, at).copy()
                at += 8 * ln
                results.append((k, v))
            payloads.append((OP_RANGE_SCAN, st.idx, results))
    aux = json.loads(data[at:at + aux_len]) if aux_len else {}
    return payloads, float(wall), aux


# ---------------------------------------------------------- wal locks

def _acquire_stream_lock(wal_dir: str, shard: int, owner: str) -> str:
    """Exclusive per-stream lockfile (O_CREAT|O_EXCL): two workers —
    or two engines — claiming the same WAL stream is a configuration
    error that would interleave their frames, so fail fast and name the
    holder.  A lock whose pid is dead is stolen (crashed owner)."""
    from ..durable.wal import shard_dir
    d = shard_dir(wal_dir, shard)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, "LOCK")
    body = f"{os.getpid()} {owner}".encode()
    while True:
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            os.write(fd, body)
            os.close(fd)
            return path
        except FileExistsError:
            try:
                pid = int(open(path).read().split()[0])
            except (ValueError, IndexError, OSError):
                pid = 0
            if pid and _pid_alive(pid):
                raise RuntimeError(
                    f"WAL stream shard-{shard:03d} under {wal_dir} is "
                    f"already owned by live process {pid}; two workers "
                    "sharing one wal_dir stream would interleave frames "
                    "— give each engine its own wal_dir") from None
            try:                         # stale lock: owner is gone
                os.unlink(path)
            except FileNotFoundError:
                pass


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


class _StructureSink:
    """Worker-side stand-in for the parent's LevelManifest: buffers
    described structural edits (flush/compaction/GC level records) so
    each reply ships them home, where they replay into the real
    manifest in ack order."""

    def __init__(self):
        self.pending: list[tuple[dict, str]] = []

    def record_structure(self, shard: int, tree, *, reason: str) -> int:
        from ..durable.manifest import describe_tree
        self.pending.append((describe_tree(tree), reason))
        return len(self.pending)

    def drain(self) -> list[list]:
        out, self.pending = self.pending, []
        return [[d, r] for d, r in out]


# --------------------------------------------------------- worker side

@dataclass
class WorkerSpec:
    """Everything a spawned worker needs to rebuild its shard slab —
    pickled once at spawn (flat dataclasses + primitives only; the
    spawn-safety test round-trips it)."""

    worker_id: int
    shard_ids: tuple
    device_ids: tuple           # XLA device ids (None = unpinned)
    host_devices: int           # forced host platform device count
    strategy: str
    lsm_config: object          # LSMConfig
    gloran_config: object       # GloranConfig | None
    engine_config: object       # EngineConfig (procs/wal_dir cleared)
    background: bool
    wal_dir: str | None
    replay: bool                # replay existing frames before serving
    trace: bool


class _WorkerHost:
    """Owns the worker's executors; dispatches decoded messages."""

    def __init__(self, spec: WorkerSpec):
        from ..lsm import LSMTree
        from ..lsm.scheduler import CompactionScheduler
        from .executor import ShardExecutor
        if spec.trace:
            from ..obs.tracer import Tracer, set_tracer, tracing_enabled
            if not tracing_enabled():
                set_tracer(Tracer())
        devs = None
        if any(d is not None for d in spec.device_ids):
            from ..launch.mesh import ensure_host_devices
            ensure_host_devices(spec.host_devices)
            import jax
            devs = jax.devices()
        cfg = spec.engine_config
        self.spec = spec
        self.executors: dict[int, object] = {}
        self.sinks: dict[int, _StructureSink] = {}
        self.locks: list[str] = []
        self.ready_info: dict[int, dict] = {}
        for s, dev_id in zip(spec.shard_ids, spec.device_ids):
            tree = LSMTree(spec.lsm_config, strategy=spec.strategy,
                           gloran_config=spec.gloran_config)
            dev = devs[dev_id % len(devs)] if (
                devs is not None and dev_id is not None) else None
            ex = ShardExecutor(tree, cfg, device=dev)
            if spec.background:
                ex.attach_scheduler(CompactionScheduler(
                    tree, max_frozen=cfg.max_frozen,
                    tombstone_trigger=cfg.tombstone_trigger))
            info = {"frames": 0, "desc": None}
            if spec.wal_dir:
                from ..durable.manifest import describe_tree
                from ..durable.wal import WalReader, WalWriter, shard_dir
                frames = []
                if spec.replay:
                    from ..durable.recovery import replay_frame
                    reader = WalReader(spec.wal_dir, s)
                    frames = reader.read_frames()
                    reader.truncate_torn_tail()
                    for fr in frames:
                        replay_frame(ex, fr)
                    ex.run_scheduler("recover")
                    info["frames"] = len(frames)
                    info["desc"] = describe_tree(tree)
                self.locks.append(
                    _acquire_stream_lock(spec.wal_dir, s,
                                         f"worker-{spec.worker_id}"))
                w = WalWriter(spec.wal_dir, s,
                              segment_bytes=cfg.wal_segment_bytes,
                              fsync=cfg.fsync)
                if frames:
                    # Position at the durable tail: appends continue
                    # the stream, rotation accounting stays exact.
                    w.frames_appended = len(frames)
                    d = shard_dir(spec.wal_dir, s)
                    w.bytes_written = sum(
                        os.path.getsize(os.path.join(d, f))
                        for f in os.listdir(d)
                        if f.startswith("seg-") and f.endswith(".wal"))
                sink = _StructureSink()
                ex.attach_durability(w, sink, s)
                self.sinks[s] = sink
            self.executors[s] = ex
            self.ready_info[s] = info

    # Aux blob shipped with every reply: CUMULATIVE shard ledgers (the
    # parent overwrites its mirrors — idempotent by construction).
    def _aux(self, shard: int, extra: dict | None = None) -> dict:
        ex = self.executors[shard]
        aux = {
            "io": [int(ex.tree.io.reads), int(ex.tree.io.writes)],
            "entries": int(ex.tree.num_entries),
            "kernels": ex.kernels.snapshot(),
            "structs": (self.sinks[shard].drain()
                        if shard in self.sinks else []),
        }
        if extra:
            aux.update(extra)
        return aux

    def handle_plan(self, payload: bytes, dq_s: float) -> list[bytes]:
        sp, flags = decode_plan(payload)
        ex = self.executors[sp.shard]
        payloads, wall = ex.run_plan(sp)
        extra: dict = {"dq_s": dq_s}
        if flags & FLAG_TRACE:
            from ..obs.tracer import Tracer, get_tracer, set_tracer
            tr = get_tracer()
            if not tr.enabled:
                set_tracer(Tracer())
            elif isinstance(tr, Tracer):
                extra["spans"] = tr.drain()
        return encode_reply(sp.shard, sp.seq, wall, payloads,
                            self._aux(sp.shard, extra))

    def handle_flush(self, payload: bytes) -> list[bytes]:
        req = json.loads(payload)
        s = int(req["shard"])
        self.executors[s].flush()
        return [json.dumps(self._aux(s), default=str).encode()]

    def handle_sched(self, payload: bytes) -> list[bytes]:
        req = json.loads(payload)
        s = int(req["shard"])
        self.executors[s].run_scheduler(req.get("reason", "sched"))
        return [json.dumps(self._aux(s), default=str).encode()]

    def handle_stats(self, payload: bytes) -> list[bytes]:
        req = json.loads(payload)
        s = int(req["shard"])
        full = self.executors[s].stats_full()
        full["aux"] = self._aux(s)
        return [json.dumps(full, default=str).encode()]

    def close(self) -> None:
        for ex in self.executors.values():
            ex.close()
        for path in self.locks:
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass


def _worker_main(spec: WorkerSpec, cmd_r, rsp_w, req_name: str,
                 rep_name: str) -> None:
    """Spawn entry point: build the shard slab, handshake READY over the
    pipe (plain JSON — init happens once), then serve ring frames until
    MSG_CLOSE or pipe EOF."""
    req = rep = None
    try:
        req = ShmRing(name=req_name)
        rep = ShmRing(name=rep_name)
        host = _WorkerHost(spec)
        ready = {"ok": True, "pid": os.getpid(),
                 "shards": {str(s): i for s, i in host.ready_info.items()}}
    except Exception:
        ready = {"ok": False, "error": traceback.format_exc()}
    try:
        rsp_w.send_bytes(json.dumps(ready, default=str).encode())
    except (BrokenPipeError, OSError):
        return
    if not ready["ok"]:
        return

    def reply(mtype: int, seq: int, parts: list[bytes]) -> None:
        off, total = rep.write(mtype, seq, parts)
        rsp_w.send_bytes(TOKEN.pack(mtype, seq, off, total,
                                    time.perf_counter()))

    try:
        while True:
            try:
                tok = cmd_r.recv_bytes()
            except (EOFError, OSError):
                break
            mtype, seq, off, total, t_send = TOKEN.unpack(tok)
            t_recv = time.perf_counter()
            payload = req.read(off, total, mtype, seq)
            req.consume_to(off, total)
            try:
                if mtype == MSG_PLAN:
                    parts = host.handle_plan(payload, t_recv - t_send)
                elif mtype == MSG_FLUSH:
                    parts = host.handle_flush(payload)
                elif mtype == MSG_SCHED:
                    parts = host.handle_sched(payload)
                elif mtype == MSG_STATS:
                    parts = host.handle_stats(payload)
                elif mtype == MSG_CLOSE:
                    host.close()
                    reply(MSG_CLOSE, seq, [b"{}"])
                    break
                else:
                    raise RuntimeError(f"unknown message type {mtype}")
                reply(mtype, seq, parts)
            except Exception:
                reply(MSG_ERR, seq, [json.dumps(
                    {"error": traceback.format_exc()}).encode()])
    finally:
        if req is not None:
            req.close()
        if rep is not None:
            rep.close()


# --------------------------------------------------------- parent side

class _Slot:
    __slots__ = ("event", "mtype", "data")

    def __init__(self):
        self.event = threading.Event()
        self.mtype = 0
        self.data = None


class ProcWorker:
    """Parent handle for one worker process: rings, pipes, request
    correlation.  ``request`` is thread-safe (many shard threads share
    a worker); replies are matched by seq on the receiver thread."""

    def __init__(self, spec: WorkerSpec, ctx, ring_bytes: int):
        self.spec = spec
        self.req = ShmRing(ring_bytes, create=True)
        self.rep = ShmRing(ring_bytes, create=True)
        self._cmd_r, self._cmd_w = ctx.Pipe(duplex=False)
        self._rsp_r, self._rsp_w = ctx.Pipe(duplex=False)
        self.proc = ctx.Process(
            target=_worker_main,
            args=(spec, self._cmd_r, self._rsp_w,
                  self.req.name, self.rep.name),
            daemon=True, name=f"repro-shard-worker-{spec.worker_id}")
        self._send_lock = threading.Lock()
        self._seq = 0
        self._pending: dict[int, _Slot] = {}
        self._recv_thread = None
        self._dead: str | None = None
        self._closed = False
        self.ready: dict | None = None
        self.bytes_sent = 0
        self.bytes_received = 0
        self.requests = 0

    # Startup ---------------------------------------------------------
    def launch(self) -> None:
        self.proc.start()
        self._cmd_r.close()         # child ends, parent copies
        self._rsp_w.close()

    def wait_ready(self, timeout: float = 180.0) -> dict:
        if not self._rsp_r.poll(timeout):
            self.terminate()
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} did not come up "
                f"within {timeout}s")
        try:
            ready = json.loads(self._rsp_r.recv_bytes())
        except (EOFError, OSError) as e:
            self.terminate()
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} exited during "
                f"startup ({e.__class__.__name__}); spawn re-imports "
                "__main__ — guard script entry points with "
                "if __name__ == '__main__'") from None
        if not ready.get("ok"):
            self.terminate()
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} failed to start:\n"
                f"{ready.get('error')}")
        self.ready = ready
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"procpool-recv-{self.spec.worker_id}")
        self._recv_thread.start()
        return ready

    # Receive ---------------------------------------------------------
    def _recv_loop(self) -> None:
        while True:
            try:
                tok = self._rsp_r.recv_bytes()
            except (EOFError, OSError):
                self._fail("worker response pipe closed")
                return
            mtype, seq, off, total, _t = TOKEN.unpack(tok)
            try:
                data = self.rep.read(off, total, mtype, seq)
            except Exception as e:          # corruption: poison everything
                self._fail(str(e))
                return
            self.rep.consume_to(off, total)
            self.bytes_received += total
            slot = self._pending.pop(seq, None)
            if slot is not None:
                slot.mtype = mtype
                slot.data = data
                slot.event.set()
            if mtype == MSG_CLOSE:
                return

    def _fail(self, msg: str) -> None:
        self._dead = msg
        while self._pending:
            _seq, slot = self._pending.popitem()
            slot.event.set()

    # Request ---------------------------------------------------------
    def request(self, mtype: int, parts: list[bytes]) -> bytes:
        if self._dead:
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} is gone: "
                f"{self._dead}")
        slot = _Slot()
        with self._send_lock:
            self._seq += 1
            seq = self._seq
            self._pending[seq] = slot
            off, total = self.req.write(mtype, seq, parts)
            self.bytes_sent += total
            self.requests += 1
            try:
                self._cmd_w.send_bytes(
                    TOKEN.pack(mtype, seq, off, total,
                               time.perf_counter()))
            except (BrokenPipeError, OSError) as e:
                self._pending.pop(seq, None)
                raise RuntimeError(
                    f"shard worker {self.spec.worker_id} died "
                    f"(command pipe): {e}") from None
        while not slot.event.wait(timeout=1.0):
            if self._dead or not self.proc.is_alive():
                self._pending.pop(seq, None)
                raise RuntimeError(
                    f"shard worker {self.spec.worker_id} died: "
                    f"{self._dead or 'process exited'}")
        if slot.data is None:
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} died: "
                f"{self._dead or 'no reply'}")
        if slot.mtype == MSG_ERR:
            err = json.loads(slot.data)
            raise RuntimeError(
                f"shard worker {self.spec.worker_id} error:\n"
                f"{err.get('error')}")
        return slot.data

    # Shutdown --------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.proc.is_alive() and not self._dead:
            try:
                self.request(MSG_CLOSE, [b"{}"])
            except RuntimeError:
                pass
        self.proc.join(timeout=10)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=5)
        if self._recv_thread is not None:
            self._recv_thread.join(timeout=5)
        for p in (self._cmd_w, self._rsp_r):
            try:
                p.close()
            except OSError:
                pass
        for ring in (self.req, self.rep):
            ring.close()
            ring.unlink()

    def terminate(self) -> None:
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=5)
        for ring in (self.req, self.rep):
            ring.close()
            ring.unlink()


class ProcShard:
    """Engine-facing proxy for a shard living in a worker process.

    Mirrors the ``ShardExecutor`` surface the engine and collector use
    (``run_plan``, ``flush``, ``run_scheduler``, ``stats_full``, the
    I/O/entries/kernels accessors).  Mirror values refresh from each
    reply's cumulative aux blob — overwrite, never accumulate, so
    repeated ``stats()`` calls are idempotent.  Direct ``.tree`` access
    is impossible by design (the tree lives in another process)."""

    def __init__(self, shard_id: int, worker: ProcWorker,
                 pool: "ProcPool"):
        self.shard_id = int(shard_id)
        self.worker = worker
        self.pool = pool
        self.manifest = None        # parent-side manifest (attach below)
        self.wal = None             # WAL lives in the worker
        self.scheduler = None       # ditto; run_scheduler round-trips
        self._io = (0, 0)
        self._entries = 0
        self._kern: dict = {}

    @property
    def tree(self):
        raise RuntimeError(
            f"shard {self.shard_id} runs in worker process "
            f"{self.worker.spec.worker_id} (EngineConfig.procs / "
            "REPRO_ENGINE_PROCS); its LSMTree is not addressable from "
            "the parent — use engine.stats() / stats_full(), or build "
            "the engine with procs=0 for in-process introspection")

    # Mirrors ---------------------------------------------------------
    @property
    def io_reads(self) -> int:
        return self._io[0]

    @property
    def io_writes(self) -> int:
        return self._io[1]

    @property
    def num_entries(self) -> int:
        return self._entries

    @property
    def kernels(self):
        from .stats import KernelCounters
        return KernelCounters.from_snapshot(self._kern)

    def _apply_aux(self, aux: dict) -> None:
        io = aux.get("io")
        if io is not None:
            self._io = (int(io[0]), int(io[1]))
        if "entries" in aux:
            self._entries = int(aux["entries"])
        if "kernels" in aux:
            self._kern = aux["kernels"]
        dq = aux.get("dq_s")
        if dq is not None:
            self.pool.dequeue_hist.record(max(0.0, float(dq)))
        if self.manifest is not None:
            for desc, reason in aux.get("structs") or []:
                self.manifest.record_structure_desc(
                    self.shard_id, desc, reason=reason)
        spans = aux.get("spans")
        if spans:
            from ..obs.tracer import get_tracer
            tr = get_tracer()
            if getattr(tr, "absorb", None):
                tr.absorb(
                    spans, pid=self.worker.proc.pid,
                    process_name=(f"shard-worker-"
                                  f"{self.worker.spec.worker_id}"))

    # Execution -------------------------------------------------------
    def run_plan(self, sp: ShardPlan) -> tuple[list, float]:
        from ..obs.tracer import tracing_enabled
        flags = FLAG_TRACE if tracing_enabled() else 0
        result_steps = [st for st in sp.steps
                        if st.kind in (OP_GET, OP_RANGE_SCAN)]
        data = self.worker.request(
            MSG_PLAN, encode_plan(self.shard_id, sp, flags))
        payloads, wall, aux = decode_reply(data, result_steps)
        self._apply_aux(aux)
        return payloads, wall

    def _control(self, mtype: int, req: dict) -> dict:
        data = self.worker.request(
            mtype, [json.dumps(req).encode()])
        out = json.loads(data)
        self._apply_aux(out.get("aux", out))
        return out

    def flush(self) -> None:
        self._control(MSG_FLUSH, {"shard": self.shard_id})

    def run_scheduler(self, reason: str = "sched") -> None:
        if self.worker._closed or self.worker._dead:
            return
        self._control(MSG_SCHED, {"shard": self.shard_id,
                                  "reason": reason})

    def stats_full(self) -> dict:
        full = self._control(MSG_STATS, {"shard": self.shard_id})
        full.pop("aux", None)
        # JSON stringifies the int level keys; normalize back so the
        # engine's aggregation code is mode-blind.
        lsm = full.get("lsm")
        if lsm:
            for k in ("compaction_bytes", "rt_compaction_bytes",
                      "rt_density"):
                if lsm.get(k):
                    lsm[k] = {int(i): v for i, v in lsm[k].items()}
        return full

    def cache_snapshot(self) -> dict:
        return self.stats_full()["cache"]

    def close(self) -> None:      # pool owns worker shutdown
        pass


class ProcPool:
    """The worker fleet: spawns ``procs`` processes (shards assigned
    round-robin, ``shard % procs``), hands out ``ProcShard`` proxies,
    and aggregates transport counters."""

    def __init__(self, *, num_shards: int, procs: int, strategy: str,
                 lsm_config, gloran_config, config, background: bool,
                 device_ids: list, host_devices: int,
                 wal_dir: str | None = None, replay: bool = False):
        import multiprocessing as mp
        from ..obs.hist import LatencyHistogram
        from ..obs.tracer import tracing_enabled
        ctx = mp.get_context("spawn")
        self.procs = int(procs)
        self.num_shards = int(num_shards)
        self._closed = False
        ring_bytes = int(config.proc_ring_bytes)
        # Workers run their shards in-process, serially, without their
        # own WAL config (the spec's wal_dir drives stream ownership
        # explicitly) — the parent engine owns routing and pipelining.
        worker_cfg = replace(config, procs=0, wal_dir=None, devices=0,
                             scheduler=False, pipeline=False)
        trace = tracing_enabled()
        self.workers: list[ProcWorker] = []
        for w in range(self.procs):
            shard_ids = tuple(s for s in range(self.num_shards)
                              if s % self.procs == w)
            spec = WorkerSpec(
                worker_id=w, shard_ids=shard_ids,
                device_ids=tuple(device_ids[s] for s in shard_ids),
                host_devices=host_devices, strategy=strategy,
                lsm_config=lsm_config, gloran_config=gloran_config,
                engine_config=worker_cfg, background=background,
                wal_dir=wal_dir, replay=replay, trace=trace)
            self.workers.append(ProcWorker(spec, ctx, ring_bytes))
        try:
            for pw in self.workers:         # spawn concurrently...
                pw.launch()
            for pw in self.workers:         # ...then gate on READY
                pw.wait_ready()
        except Exception:
            self.close()
            raise
        self.shards = [ProcShard(s, self.workers[s % self.procs], self)
                       for s in range(self.num_shards)]
        self.dequeue_hist = LatencyHistogram()
        self.frames_replayed = 0
        self.recovered_descs: dict[int, dict] = {}
        for pw in self.workers:
            for s, info in (pw.ready or {}).get("shards", {}).items():
                self.frames_replayed += int(info.get("frames") or 0)
                if info.get("desc"):
                    self.recovered_descs[int(s)] = info["desc"]
        self._closed = False

    def transport_snapshot(self) -> dict:
        return {
            "workers": self.procs,
            "requests": sum(w.requests for w in self.workers),
            "bytes_sent": sum(w.bytes_sent for w in self.workers),
            "bytes_received": sum(w.bytes_received for w in self.workers),
            "dequeue_latency_us": self.dequeue_hist.snapshot(),
        }

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for pw in self.workers:
            pw.close()
