"""Per-shard batched execution with the fused Pallas filter stage.

``ShardExecutor`` owns one ``LSMTree`` and drives its canonical batched
read path (``LSMTree.get_batch``) with four hooks swapped in:

  cascade_fn   THE preferred read path: one fused launch of the
               ``repro.kernels.cascade`` kernel answers every level's
               Bloom + fence questions and the GLORAN per-level interval
               verdicts from persistent device state (the shard's
               ``DeviceFilterRegistry`` — uploaded once per SSTable /
               index epoch, invalidated on compaction).  Gated by
               ``kernel_min_batch`` and u32 eligibility; when it
               declines, the per-level hooks below serve the lookup
               instead, with identical results and I/O charges,
  bloom_fn     SSTable filter probes through the ``repro.kernels.bloom``
               Pallas kernel (bit-exact with ``BloomBits.might_contain``)
               once the sub-batch and filter are big enough to pay for a
               launch,
  cache        data-block reads charged through the shard's read-through
               ``BlockCache`` so hot blocks stop costing I/O,
  validity_fn  GLORAN validity probing where each LSM-DRtree level is
               queried with one ``interval_query`` Pallas launch instead
               of a per-key ``covers`` descent — the disjoint level
               arrays are clamped into u32 working space (exact for
               u32-range queries) and padded to power-of-two tiles so
               jit re-traces stay bounded by O(log) distinct shapes,
               not one per compaction,
  rank_fn      scan merge-back positions through the
               ``repro.kernels.merge`` merge-rank kernel (bit-exact with
               the host searchsorted pair) once a two-way round's runs
               are big enough to pay for a launch.

Range-delete plan steps stay columnar end-to-end: the step's clipped
``los``/``his`` arrays flow untouched through
``LSMTree.range_delete_arrays`` into the GLORAN staging buffer's
vectorized batch append.

The control flow stays single-sourced in ``LSMTree`` / ``GloranIndex`` /
``LSMDRTree``; hooks only replace HOW a verdict is computed, never what
is charged for it — except the block cache, whose whole point is
skipping charges for resident blocks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.eve import fold64to32
from ..kernels.bloom.ops import bloom_probe
from ..kernels.cascade.ops import cascade_lookup
from ..kernels.interval.ops import interval_query
from ..kernels.merge.ops import merge_ranks
from ..lsm.tree import CascadeVerdict, LSMTree
from ..obs import span
from .cache import BlockCache
from .plan import (KIND_NAMES, OP_DELETE, OP_GET, OP_PUT, OP_RANGE_DELETE,
                   OP_RANGE_SCAN, ShardPlan)
# _U32_LIMIT / _next_pow2 are shared with the registry: both kernel
# paths must gate and pad identically for cascade parity to hold.
from .registry import DeviceFilterRegistry, _next_pow2, _U32_LIMIT
from .stats import KernelCounters
# Submodule import (not the package) keeps the engine <-> durable import
# graph acyclic; durable.manifest depends only on durable.atomic.
from ..durable.manifest import structure_fingerprint
from ..durable.wal import FRAME_BATCH

_QUERY_TILE = 1024  # block_rows(8) x LANES(128): one grid row


@dataclass
class EngineConfig:
    """Knobs of the batched execution layer (not the LSM itself)."""

    partition: str = "hash"  # "hash" | "range" key partitioning
    pipeline: bool | None = None  # concurrent shard plans; None = env
    cache_blocks: int = 0  # per-shard block cache capacity; 0 = off
    use_bloom_kernel: bool = True
    use_interval_kernel: bool = True
    use_merge_kernel: bool = True
    use_cascade_kernel: bool = True  # fused all-levels lookup cascade
    cascade_compiled: bool | None = None  # None = auto (non-TPU -> XLA)
    kernel_min_batch: int = 256  # sub-batch size worth a kernel launch
    kernel_min_areas: int = 64  # DR-tree level size worth a launch
    kernel_min_filter: int = 512  # SSTable entries worth a launch
    kernel_min_merge: int = 1024  # total keys in a 2-way merge round
    interpret: bool | None = None  # None = auto (non-TPU -> interpret)
    # Per-shard XLA devices: None = env (REPRO_ENGINE_DEVICES; unset =
    # auto: use up to num_shards of the available devices, or fall back
    # to the single-device path on 1-device hosts); 0 = forced off (the
    # ungated legacy path); N = pin shards round-robin over the first
    # min(N, available) devices.
    devices: int | None = None
    # Timed-I/O mode: seconds a shard worker sleeps per simulated I/O
    # block its plan step charged (0.0 = off, the default — I/O stays
    # count-only).  With it on, measured wall includes the store's
    # modeled device waits, and those waits OVERLAP across pipelined
    # shard workers (sleep releases the GIL) exactly as concurrent NVMe
    # queues would — the wall-clock benchmark mode.
    io_wait_s: float = 0.0
    # Durability: a WAL directory turns on per-shard write-ahead logging
    # plus the level manifest (see ``repro.durable``).  Batches are
    # acknowledged only after their write ops are appended (and, under
    # the "batch" policy, fsynced).  ``fsync`` is one of "batch" |
    # "rotate" | "never" (see ``durable.wal.FSYNC_POLICIES``).
    wal_dir: str | None = None
    fsync: str = "batch"
    wal_segment_bytes: int = 4 << 20
    # Background delete-aware compaction scheduling (lsm/scheduler.py):
    # None = env (REPRO_ENGINE_BG_COMPACT; unset/0 = off — the inline
    # flush path, byte-identical to the scheduler-less engine).  With it
    # on, a full memtable seals into an immutable snapshot and flush +
    # cascade run as background jobs at the deterministic drain points,
    # so put batches stop carrying compaction on their wall clock.
    scheduler: bool | None = None
    # Soft limit on sealed-but-unflushed memtables per shard; sealing
    # past it backpressures (runs due jobs on the sealing thread,
    # counted as a stall).
    max_frozen: int = 4
    # Lethe-style proactive compaction trigger: a level whose estimated
    # range-tombstone density reaches this fraction is compacted down
    # ahead of overflow (None = capacity-driven only, the parity
    # default — proactive compaction intentionally diverges from the
    # inline level shapes to reclaim GLORAN garbage early).
    tombstone_trigger: float | None = None
    # Process-parallel shard execution (engine/procpool.py): None = env
    # (REPRO_ENGINE_PROCS; unset/0 = off — the in-process path,
    # byte-identical).  N spawns min(N, num_shards) worker processes,
    # shards assigned round-robin, ShardPlans shipped as shared-memory
    # columnar frames — real multi-core wall speedup on compute-bound
    # work the GIL otherwise serializes.
    procs: int | None = None
    # Capacity of each per-direction shared-memory transport ring.
    proc_ring_bytes: int = 32 << 20


class ShardExecutor:
    def __init__(self, tree: LSMTree, config: EngineConfig | None = None,
                 device=None):
        self.tree = tree
        self.config = config or EngineConfig()
        # The shard's home XLA device (None = default-device legacy
        # path).  Every kernel dispatch below passes it through, and the
        # registry commits its persistent packs to it, so this shard's
        # device compute — during which jax releases the GIL — runs
        # concurrently with other shards' instead of serializing on
        # device 0.
        self.device = device
        self.cache = BlockCache(self.config.cache_blocks)
        self.kernels = KernelCounters()
        # Device-resident packed filter state for the fused cascade AND
        # the per-level kernel fallback (per-SSTable pieces + GLORAN
        # interval views, structurally invalidated).
        self.registry = DeviceFilterRegistry(self.kernels, device=device)
        # Durability attachments (None = volatile shard; see
        # ``Engine._attach_durability`` / ``repro.durable``).  The WAL
        # writer is single-appender by construction: all appends happen
        # on this shard's worker thread (or the engine thread after a
        # drain), the existing per-shard FIFO.
        self.wal = None
        self.manifest = None
        self.shard_id = 0
        # Background compaction scheduler (None = inline flush path).
        self.scheduler = None
        # Compactions route their two-run merge through the gated
        # merge-rank kernel closure (bit-exact with the host
        # searchsorted pair — same hook the scan tournament uses).
        tree.compaction_rank_fn = self._rank_fn()

    def attach_durability(self, wal, manifest, shard_id: int) -> None:
        self.wal = wal
        self.manifest = manifest
        self.shard_id = int(shard_id)

    def attach_scheduler(self, scheduler) -> None:
        """Enable background mode: the tree seals instead of flushing
        inline, and this executor drains the job queue at every plan
        start / explicit flush (the deterministic points that keep
        results byte-identical to the inline path)."""
        self.scheduler = scheduler
        self.tree.scheduler = scheduler
        self.tree.io.enable_locking()

    def run_scheduler(self, reason: str = "sched") -> None:
        """Drain due background jobs, committing a manifest edit if the
        level structure moved (jobs mutate structure outside any plan,
        exactly like an explicit flush)."""
        if self.scheduler is None or not self.scheduler.has_work():
            return
        fp0 = (structure_fingerprint(self.tree)
               if self.manifest is not None else None)
        self.scheduler.run_due()
        self._maybe_record_structure(fp0, reason)

    def _log_plan(self, sp: ShardPlan) -> None:
        """Group commit: ONE WAL frame holding every write op of this
        shard plan (reads are not logged — replay re-derives any reads
        embedded in delete strategies from the rebuilt state).  Under
        the "batch" fsync policy the frame is durable before any step
        executes, so acknowledgement (which follows ``run_plan``)
        implies durability."""
        kinds, keys, vals, los, his = [], [], [], [], []
        for step in sp.steps:
            if step.kind not in (OP_PUT, OP_DELETE, OP_RANGE_DELETE):
                continue
            if step.kind == OP_RANGE_DELETE:
                n = len(step.los)
                z = np.zeros(n, np.uint64)
                keys.append(z)
                vals.append(z)
                los.append(step.los)
                his.append(step.his)
            else:
                n = len(step.keys)
                z = np.zeros(n, np.uint64)
                keys.append(step.keys)
                vals.append(step.vals if step.kind == OP_PUT else z)
                los.append(z)
                his.append(z)
            kinds.append(np.full(n, step.kind, np.uint8))
        if not kinds:
            return
        self.wal.append(FRAME_BATCH, sp.seq, np.concatenate(kinds),
                        np.concatenate(keys), np.concatenate(vals),
                        np.concatenate(los), np.concatenate(his))

    def _maybe_record_structure(self, fp0, reason: str) -> None:
        """Commit a manifest edit iff the durable structure moved."""
        if self.manifest is None:
            return
        if structure_fingerprint(self.tree) != fp0:
            self.manifest.record_structure(self.shard_id, self.tree,
                                           reason=reason)

    # ----------------------------------------------------------- writes
    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert a batch of (key, val) pairs into the shard's tree."""
        self.tree.put_batch(keys, vals)

    def delete_batch(self, keys: np.ndarray) -> None:
        """Point-delete a batch of keys (one tombstone each)."""
        self.tree.delete_batch(keys)

    def range_delete(self, lo: int, hi: int) -> None:
        """Delete [lo, hi) via the tree's configured strategy."""
        self.tree.range_delete(lo, hi)

    def range_delete_batch(self, ranges) -> None:
        """Apply a batch of [lo, hi) range deletes in request order
        (GLORAN absorbs the batch in one index/estimator call)."""
        self.tree.range_delete_batch(ranges)

    def range_delete_arrays(self, los: np.ndarray, his: np.ndarray) -> None:
        """Columnar batch range delete: the plan step's clipped bound
        arrays go straight into the tree (no tuple round trip)."""
        self.tree.range_delete_arrays(los, his)

    def flush(self) -> None:
        """Flush the shard's memtable (and LRR buffer) to level 0.

        Durable shards first log a FLUSH marker — the flush mutates
        level structure outside any plan, and replay must flush at the
        same point for level shapes to come back byte-identical — and
        commit a manifest edit if the level stack moved."""
        if self.wal is not None:
            self.wal.append_flush()
        fp0 = (structure_fingerprint(self.tree)
               if self.manifest is not None else None)
        self.tree.flush()
        if self.scheduler is not None:
            # Explicit flush is synchronous: the FLUSH frame above acks
            # only after the background flush durably publishes.
            self.scheduler.drain()
        self._maybe_record_structure(fp0, "flush")

    # ------------------------------------------------ uniform surface
    # The engine aggregates shards through these accessors ONLY, so an
    # in-process executor and a ``procpool.ProcShard`` proxy (whose tree
    # lives in a worker process) are interchangeable.
    @property
    def io_reads(self) -> int:
        return self.tree.io.reads

    @property
    def io_writes(self) -> int:
        return self.tree.io.writes

    @property
    def num_entries(self) -> int:
        return self.tree.num_entries

    def cache_snapshot(self) -> dict:
        return self.cache.snapshot()

    def stats_full(self) -> dict:
        """Every per-shard ledger ``engine.stats()`` rolls up, in one
        JSON-able document (the procpool STATS reply body)."""
        from ..lsm.scheduler import level_rt_density
        tree = self.tree
        return {
            "io": tree.io.snapshot(),
            "entries": int(tree.num_entries),
            "kernels": self.kernels.snapshot(),
            "cache": self.cache.snapshot(),
            "staging": (tree.gloran.buffer_snapshot()
                        if tree.gloran is not None else None),
            "sched": (self.scheduler.counters()
                      if self.scheduler is not None else None),
            "wal": self.wal.counters() if self.wal is not None else None,
            "lsm": {
                "compaction_bytes": {int(i): int(b) for i, b in
                                     tree.compaction_bytes.items()},
                "rt_compaction_bytes": {int(i): int(b) for i, b in
                                        tree.rt_compaction_bytes.items()},
                "rt_density": {i: round(level_rt_density(tree, i), 4)
                               for i in range(len(tree.levels))},
                "num_levels": len(tree.levels),
            },
        }

    def close(self) -> None:
        if self.wal is not None:
            self.wal.close()

    # ------------------------------------------------------- typed plans
    def run_plan(self, sp: ShardPlan) -> tuple[list, float]:
        """Execute one compiled ``ShardPlan`` in request order.

        Each ``PlanStep`` is one vectorized sub-batch on this shard's
        batched paths.  Returns ``(payloads, wall_seconds)`` where
        payloads carry the result-bearing steps — ``(OP_GET, idx, found,
        vals)`` and ``(OP_RANGE_SCAN, idx, [(keys, vals), ...])`` — for
        the engine's deterministic merge-back; ``wall_seconds`` is this
        shard's busy time (the pipeline's per-shard wall/stall metric).
        Thread-safe across shards: every touched structure (tree, cache,
        counters, I/O ledger) is shard-local.
        """
        t0 = time.perf_counter()
        payloads: list = []
        io_wait = self.config.io_wait_s
        with span("shard.plan", shard=sp.shard, batch=sp.seq,
                  steps=len(sp.steps), n_ops=sp.n_ops,
                  device="host" if self.device is None else
                  f"{self.device.platform}:{self.device.id}"):
            if self.wal is not None:
                with span("shard.wal_append", shard=sp.shard,
                          batch=sp.seq):
                    self._log_plan(sp)
            # Background jobs drain BEFORE the plan's steps: every plan
            # starts from the fully-caught-up state the inline path
            # would have reached, which is what keeps cross-plan
            # results, level shapes, and I/O ledgers byte-identical
            # with the scheduler on.
            self.run_scheduler()
            fp0 = (structure_fingerprint(self.tree)
                   if self.manifest is not None else None)
            for step in sp.steps:
                with span("shard." + KIND_NAMES[step.kind], n=len(step),
                          shard=sp.shard, batch=sp.seq):
                    io0 = self.tree.io.total if io_wait > 0.0 else 0
                    if step.kind == OP_PUT:
                        self.put_batch(step.keys, step.vals)
                    elif step.kind == OP_DELETE:
                        self.delete_batch(step.keys)
                    elif step.kind == OP_GET:
                        found, vals = self.get_batch(step.keys)
                        payloads.append((OP_GET, step.idx, found, vals))
                    elif step.kind == OP_RANGE_SCAN:
                        res = self.range_scan_batch(
                            list(zip(step.los.tolist(),
                                     step.his.tolist())))
                        payloads.append((OP_RANGE_SCAN, step.idx, res))
                    else:  # OP_RANGE_DELETE (bounds clipped per shard)
                        self.range_delete_arrays(step.los, step.his)
                    if io_wait > 0.0:
                        # Timed-I/O mode: serve the step's charged
                        # blocks as a real wait.  Charges are untouched
                        # (the ledger stays bit-identical); only wall
                        # time grows, and it overlaps across shard
                        # workers — sleep releases the GIL.
                        dio = self.tree.io.total - io0
                        if dio:
                            time.sleep(dio * io_wait)
            self._maybe_record_structure(fp0, "plan")
        return payloads, time.perf_counter() - t0

    # ------------------------------------------------------------ reads
    def _validity_fn(self):
        """The GLORAN validity hook: batched ``is_deleted`` verdicts with
        per-level probes routed through the interval Pallas kernel (when
        gating admits a launch).  None for non-GLORAN strategies."""
        t = self.tree
        if t.strategy == "gloran" and t.gloran is not None:
            return lambda k, s: t.gloran.is_deleted_batch(
                k, s, query_fn=self._query_drtree_level)
        return None

    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookups; (found, vals), order = request order.

        The fused cascade hook answers the whole filter stack in one
        launch when its gates admit the batch; the per-level bloom /
        interval hooks are the ungated fallback for the same call."""
        self.cache.op_class = "get"
        return self.tree.get_batch(
            np.asarray(keys, dtype=np.uint64),
            cache=self.cache if self.cache.enabled else None,
            bloom_fn=self._bloom_maybe,
            validity_fn=self._validity_fn(),
            cascade_fn=self._cascade)

    # --------------------------------------------------- cascade kernel
    def _cascade(self, keys: np.ndarray, resolved: np.ndarray,
                 seqs: np.ndarray) -> CascadeVerdict | None:
        """One fused launch for a lookup batch, or None to decline.

        Gates: the batch must be worth a launch (``kernel_min_batch``),
        the tree's packed view must exist (non-empty levels, u32-exact
        keys/seqs, within the VMEM pack budgets — see
        ``DeviceFilterRegistry``), and the query keys plus any
        memtable-resolved seqs must fit u32 working space.  A declined
        launch falls back to the per-level path with identical results.
        """
        cfg = self.config
        if not cfg.use_cascade_kernel or len(keys) < cfg.kernel_min_batch:
            return None
        view = self.registry.view(self.tree)
        if view is None:
            return None
        if int(keys.max()) >= _U32_LIMIT:
            return None
        if resolved.any() and int(seqs[resolved].max()) >= _U32_LIMIT:
            return None
        maybe, hit, gl_cov, pos = cascade_lookup(
            keys.astype(np.uint32), fold64to32(keys),
            seqs.astype(np.uint32), resolved, view.state,
            interpret=cfg.interpret, compiled=cfg.cascade_compiled,
            device=self.device)
        self.kernels.cascade_calls += 1
        self.kernels.cascade_queries += len(keys)
        return CascadeVerdict(slots=view.slots, maybe=maybe, hit=hit,
                              pos=pos,
                              gl_cov=gl_cov if view.has_gloran else None)

    def range_scan(self, lo: int, hi: int):
        """One range scan; (keys, vals) of the live entries in [lo, hi)."""
        return self.range_scan_batch([(lo, hi)])[0]

    def range_scan_batch(self, ranges) -> list:
        """Batched range scans through the tree's one-pass batch path,
        with GLORAN validity filtering on the kernel hook, merge-back
        positions on the merge-rank kernel hook, and slice charges
        absorbed by the shard's block cache; one (keys, vals) pair per
        requested [lo, hi), in request order."""
        self.cache.op_class = "range_scan"
        return self.tree.range_scan_batch(
            ranges, validity_fn=self._validity_fn(),
            cache=self.cache if self.cache.enabled else None,
            rank_fn=self._rank_fn())

    # ----------------------------------------------------- merge kernel
    def _rank_fn(self):
        """The sorted-view merge hook: two-way merge-round output
        positions through the ``merge_ranks`` Pallas kernel when the
        round is big enough to pay for a launch and both runs fit u32
        working space; declines (None -> host searchsorted) otherwise.
        """
        cfg = self.config
        if not cfg.use_merge_kernel:
            return None

        def rank(ka: np.ndarray, kb: np.ndarray):
            n = len(ka) + len(kb)
            if (n < cfg.kernel_min_merge or not len(ka) or not len(kb)
                    or int(ka[-1]) >= _U32_LIMIT
                    or int(kb[-1]) >= _U32_LIMIT):
                return None
            pa, pb = merge_ranks(ka.astype(np.uint32),
                                 kb.astype(np.uint32),
                                 interpret=cfg.interpret,
                                 device=self.device)
            self.kernels.merge_calls += 1
            self.kernels.merge_keys += n
            return pa, pb

        return rank

    # --------------------------------------------------- filter kernels
    def _bloom_maybe(self, lvl, keys: np.ndarray) -> np.ndarray:
        """SSTable filter verdicts; Pallas-launched when worth it.

        Filter words go to the kernel as the registry's device-resident
        copy (uploaded once per run uid), so the ungated per-level path
        stops re-uploading the filter on every probe."""
        cfg = self.config
        bb = lvl.bloom
        if (cfg.use_bloom_kernel and len(keys) >= cfg.kernel_min_batch
                and len(lvl) >= cfg.kernel_min_filter):
            n = len(keys)
            m = max(_QUERY_TILE, _next_pow2(n))
            k32 = np.zeros(m, dtype=np.uint32)
            k32[:n] = fold64to32(keys)
            out = np.asarray(bloom_probe(
                k32, self.registry.bloom_words(lvl), m_bits=bb.m_bits,
                seeds=tuple(int(s) for s in bb.seeds),
                interpret=cfg.interpret, device=self.device))
            self.kernels.bloom_calls += 1
            self.kernels.bloom_queries += n
            return out[:n]
        return bb.might_contain(keys)

    def _query_drtree_level(self, lvl, keys: np.ndarray, seqs: np.ndarray,
                            io) -> np.ndarray:
        """Point-stab one DR-tree level; Pallas-launched when worth it."""
        cfg = self.config
        if (cfg.use_interval_kernel
                and len(lvl) >= cfg.kernel_min_areas
                and len(keys) >= cfg.kernel_min_batch
                and int(keys.max()) < _U32_LIMIT
                and int(seqs.max()) < _U32_LIMIT):
            return self._interval_kernel_query(lvl, keys, seqs, io)
        return lvl.query_batch(keys, seqs, io=io)

    def _interval_kernel_query(self, lvl, keys: np.ndarray,
                               seqs: np.ndarray, io) -> np.ndarray:
        """One Pallas launch over a disjoint level; same I/O as a probe."""
        lo32, hi32, smin32, smax32 = self._level_u32(lvl)
        io.read_blocks(lvl.probe_cost() * len(keys), tag="drtree_probe")
        n = len(keys)
        m = max(_QUERY_TILE, _next_pow2(n))
        kq = np.zeros(m, dtype=np.uint32)
        sq = np.zeros(m, dtype=np.uint32)
        kq[:n] = keys.astype(np.uint32)
        sq[:n] = seqs.astype(np.uint32)
        out = np.asarray(interval_query(kq, sq, lo32, hi32, smin32, smax32,
                                        interpret=self.config.interpret,
                                        device=self.device))
        self.kernels.interval_calls += 1
        self.kernels.interval_queries += n
        return out[:n]

    def _level_u32(self, lvl):
        """Clamped, padded u32 view of an immutable DR-tree level —
        the registry's device-resident piece (``clamp_level_u32``, the
        single source of the u32 transform), shared with the cascade's
        packed GLORAN view: one upload and one device copy serve both
        kernel paths, and the interval ops layer passes the pre-uploaded
        ``jax.Array`` columns through untouched."""
        live = [l for l in getattr(self.tree.gloran.index, "levels", [])
                if l is not None]
        return self.registry.gl_columns(lvl, live)
