"""Read-through LRU block cache with hit/miss accounting.

The I/O simulator charges one block read per data-block access; repeated
session lookups in the serving tier keep re-reading the same hot blocks.
The cache models a block cache in front of the simulated disk: a hit
skips the charge, a miss charges it and admits the block.  Keys are
``(run_uid, block_index)`` — run uids are process-unique, so blocks of
compacted-away runs are never falsely hit and simply age out of the LRU.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np


class BlockCache:
    def __init__(self, capacity_blocks: int = 0):
        self.capacity = int(capacity_blocks)
        self._blocks: OrderedDict[tuple[int, int], None] = OrderedDict()
        self.hits = 0
        self.misses = 0
        # Per-op-class attribution: the executor tags each probe window
        # with the op class it serves ("get" vs "range_scan"), so scan
        # and point-lookup cache behavior stay distinguishable in the
        # global ledger.
        self.op_class: str | None = None
        self.class_hits: dict[str, int] = {}
        self.class_misses: dict[str, int] = {}

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def __len__(self) -> int:
        return len(self._blocks)

    def probe_many(self, run_uid: int, blocks: np.ndarray) -> np.ndarray:
        """Read-through probe: bool hit mask per block, misses admitted.

        Duplicate block indices within one call count one miss + n-1 hits,
        matching what a real cache would do for a sorted probe batch.
        """
        hit = np.zeros(len(blocks), dtype=bool)
        cls = self.op_class or "other"
        if not self.enabled:
            self.misses += len(blocks)
            self.class_misses[cls] = \
                self.class_misses.get(cls, 0) + len(blocks)
            return hit
        for j, b in enumerate(blocks.tolist()):
            key = (run_uid, int(b))
            if key in self._blocks:
                self._blocks.move_to_end(key)
                hit[j] = True
            else:
                self._blocks[key] = None
                if len(self._blocks) > self.capacity:
                    self._blocks.popitem(last=False)
        h = int(hit.sum())
        m = int((~hit).sum())
        self.hits += h
        self.misses += m
        self.class_hits[cls] = self.class_hits.get(cls, 0) + h
        self.class_misses[cls] = self.class_misses.get(cls, 0) + m
        return hit

    def by_class(self) -> dict:
        """Per-op-class hit/miss/hit-rate breakdown of the ledger."""
        out = {}
        for cls in sorted(set(self.class_hits) | set(self.class_misses)):
            h = self.class_hits.get(cls, 0)
            m = self.class_misses.get(cls, 0)
            out[cls] = {"hits": h, "misses": m,
                        "hit_rate": h / (h + m) if h + m else 0.0}
        return out

    def snapshot(self) -> dict:
        total = self.hits + self.misses
        return {
            "capacity_blocks": self.capacity,
            "resident_blocks": len(self._blocks),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "by_class": self.by_class(),
        }

    def clear(self) -> None:
        self._blocks.clear()
