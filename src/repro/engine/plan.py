"""Typed op batches and the planner that compiles them per shard.

The engine's public surface is **plan -> submit -> collect**:

  ``OpBatch``    a typed, columnar batch of mixed operations — structured
                 arrays for kind/key/val/lo/hi, validated at construction
                 (replaces the ad-hoc ``("get", k)`` tuple convention),
  ``Planner``    compiles an ``OpBatch`` against a ``ShardRouter`` into
                 one ``ShardPlan`` per shard: point ops are routed
                 vectorized, range ops are clipped to the owning slabs,
                 and consecutive same-kind ops bound for the same shard
                 are grouped into one vectorized ``PlanStep``,
  ``Plan``       the compiled batch: per-shard plans plus the merge-back
                 bookkeeping (which op ids are scans, how many ops).

Plans are pure data — compiling one mutates nothing — so planning batch
n+1 can overlap executing batch n (see ``engine.pending``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from itertools import count

import numpy as np

from ..obs import span
from .router import ShardRouter

# Op kind codes (stable: these are the OpBatch column encoding).
OP_PUT = 0
OP_DELETE = 1
OP_GET = 2
OP_RANGE_DELETE = 3
OP_RANGE_SCAN = 4

KIND_NAMES = ("put", "delete", "get", "range_delete", "range_scan")
KIND_CODES = {name: code for code, name in enumerate(KIND_NAMES)}
_POINT_KINDS = (OP_PUT, OP_DELETE, OP_GET)
# Tuple arity per kind for the ``from_ops`` migration shim.
_ARITY = {OP_PUT: 3, OP_DELETE: 2, OP_GET: 2,
          OP_RANGE_DELETE: 3, OP_RANGE_SCAN: 3}


def _u64(x, n: int | None = None) -> np.ndarray:
    if x is None:
        return np.zeros(0 if n is None else n, dtype=np.uint64)
    return np.asarray(x, dtype=np.uint64)


class OpBatch:
    """A typed, columnar batch of mixed engine operations.

    Struct-of-arrays: ``kinds`` (uint8 op codes), ``keys``/``vals``
    (uint64, point ops), ``los``/``his`` (uint64, range ops).  Unused
    columns hold zeros.  Construction validates shape, kind codes, and
    range bounds once — executors and planners then trust the arrays
    and never re-inspect per-op tuples.

    Build one with the typed constructors (``OpBatch.gets(keys)``,
    ``OpBatch.puts(keys, vals)``, ``OpBatch.range_scans(ranges)``, ...),
    the mixed-stream shim ``OpBatch.from_ops([("put", k, v), ...])``, or
    directly from columns.  Batches are immutable by convention; results
    of ``Engine.submit`` align with op order (op id = row index).
    """

    __slots__ = ("kinds", "keys", "vals", "los", "his")

    def __init__(self, kinds, keys=None, vals=None, los=None, his=None):
        kinds = np.asarray(kinds, dtype=np.uint8)
        n = len(kinds)
        self.kinds = kinds
        self.keys = _u64(keys, n)
        self.vals = _u64(vals, n)
        self.los = _u64(los, n)
        self.his = _u64(his, n)
        self._validate()

    def _validate(self) -> None:
        n = len(self.kinds)
        for name in ("keys", "vals", "los", "his"):
            col = getattr(self, name)
            if col.ndim != 1 or len(col) != n:
                raise ValueError(
                    f"OpBatch.{name}: expected 1-D length {n}, "
                    f"got shape {col.shape}")
        if n and int(self.kinds.max()) > OP_RANGE_SCAN:
            bad = int(np.flatnonzero(self.kinds > OP_RANGE_SCAN)[0])
            raise ValueError(
                f"OpBatch: unknown op kind code {self.kinds[bad]} "
                f"at op {bad}")
        rng = self.kinds >= OP_RANGE_DELETE
        if rng.any():
            empty = rng & (self.los >= self.his)
            if empty.any():
                bad = int(np.flatnonzero(empty)[0])
                raise ValueError(
                    f"OpBatch: empty range [{self.los[bad]}, "
                    f"{self.his[bad]}) at op {bad} "
                    f"({KIND_NAMES[self.kinds[bad]]})")

    # ------------------------------------------------------ constructors
    @classmethod
    def puts(cls, keys, vals) -> "OpBatch":
        keys, vals = _u64(keys), _u64(vals)
        if len(keys) != len(vals):
            raise ValueError(
                f"OpBatch.puts: {len(keys)} keys vs {len(vals)} vals")
        return cls(np.full(len(keys), OP_PUT, np.uint8), keys=keys,
                   vals=vals)

    @classmethod
    def deletes(cls, keys) -> "OpBatch":
        keys = _u64(keys)
        return cls(np.full(len(keys), OP_DELETE, np.uint8), keys=keys)

    @classmethod
    def gets(cls, keys) -> "OpBatch":
        keys = _u64(keys)
        return cls(np.full(len(keys), OP_GET, np.uint8), keys=keys)

    @classmethod
    def _ranges(cls, code: int, ranges) -> "OpBatch":
        ranges = list(ranges)
        los = _u64([r[0] for r in ranges])
        his = _u64([r[1] for r in ranges])
        return cls(np.full(len(ranges), code, np.uint8), los=los, his=his)

    @classmethod
    def range_deletes(cls, ranges) -> "OpBatch":
        return cls._ranges(OP_RANGE_DELETE, ranges)

    @classmethod
    def range_scans(cls, ranges) -> "OpBatch":
        return cls._ranges(OP_RANGE_SCAN, ranges)

    @classmethod
    def from_ops(cls, ops) -> "OpBatch":
        """Migration shim from the legacy tuple stream:
        ``("put", k, v) | ("delete", k) | ("get", k) |
        ("range_delete", lo, hi) | ("range_scan", lo, hi)``."""
        n = len(ops)
        kinds = np.zeros(n, dtype=np.uint8)
        keys = np.zeros(n, dtype=np.uint64)
        vals = np.zeros(n, dtype=np.uint64)
        los = np.zeros(n, dtype=np.uint64)
        his = np.zeros(n, dtype=np.uint64)
        for i, op in enumerate(ops):
            code = KIND_CODES.get(op[0])
            if code is None:
                raise ValueError(f"unknown op kind: {op[0]!r} at op {i}")
            if len(op) != _ARITY[code]:
                raise ValueError(
                    f"op {i}: {op[0]!r} takes {_ARITY[code] - 1} "
                    f"arguments, got {len(op) - 1}")
            kinds[i] = code
            if code in _POINT_KINDS:
                keys[i] = op[1]
                if code == OP_PUT:
                    vals[i] = op[2]
            else:
                los[i], his[i] = op[1], op[2]
        return cls(kinds, keys=keys, vals=vals, los=los, his=his)

    @classmethod
    def concat(cls, batches) -> "OpBatch":
        batches = list(batches)
        if not batches:
            return cls(np.zeros(0, np.uint8))
        return cls(np.concatenate([b.kinds for b in batches]),
                   keys=np.concatenate([b.keys for b in batches]),
                   vals=np.concatenate([b.vals for b in batches]),
                   los=np.concatenate([b.los for b in batches]),
                   his=np.concatenate([b.his for b in batches]))

    # ------------------------------------------------------------- views
    def __len__(self) -> int:
        return len(self.kinds)

    @property
    def scan_ids(self) -> np.ndarray:
        """Op ids of the range scans (merge-back slots)."""
        return np.flatnonzero(self.kinds == OP_RANGE_SCAN)

    @property
    def get_ids(self) -> np.ndarray:
        """Op ids of the point gets."""
        return np.flatnonzero(self.kinds == OP_GET)

    @property
    def kind_name(self) -> str:
        """The op class: a kind name if homogeneous, else ``"mixed"``."""
        if len(self.kinds) == 0:
            return "mixed"
        k0 = int(self.kinds[0])
        if (self.kinds == k0).all():
            return KIND_NAMES[k0]
        return "mixed"

    def counts(self) -> dict:
        c = np.bincount(self.kinds, minlength=len(KIND_NAMES))
        return {name: int(c[code]) for code, name in enumerate(KIND_NAMES)
                if c[code]}

    def to_ops(self) -> list[tuple]:
        """Back to the legacy tuple stream (tests / debugging)."""
        out = []
        for i, code in enumerate(self.kinds.tolist()):
            if code == OP_PUT:
                out.append(("put", int(self.keys[i]), int(self.vals[i])))
            elif code in (OP_DELETE, OP_GET):
                out.append((KIND_NAMES[code], int(self.keys[i])))
            else:
                out.append((KIND_NAMES[code], int(self.los[i]),
                            int(self.his[i])))
        return out

    def __repr__(self) -> str:
        return f"OpBatch(n={len(self)}, {self.counts()})"


@dataclass
class PlanStep:
    """One same-kind vectorized sub-batch bound for one shard.

    ``idx`` holds the op ids (rows of the source ``OpBatch``) this step
    serves, ascending — per-shard arrival order is request order.  Point
    steps carry ``keys`` (and ``vals`` for puts); range steps carry the
    per-shard *clipped* ``los``/``his``.
    """

    kind: int
    idx: np.ndarray
    keys: np.ndarray | None = None
    vals: np.ndarray | None = None
    los: np.ndarray | None = None
    his: np.ndarray | None = None

    def __len__(self) -> int:
        return len(self.idx)


@dataclass
class ShardPlan:
    """Everything one shard executes for a batch, in request order."""

    shard: int
    steps: list[PlanStep] = field(default_factory=list)
    seq: int = -1  # owning Plan's batch number (trace correlation)

    @property
    def n_ops(self) -> int:
        return sum(len(s) for s in self.steps)

    def __bool__(self) -> bool:
        return bool(self.steps)


@dataclass
class Plan:
    """A compiled ``OpBatch``: per-shard plans + merge-back bookkeeping."""

    batch: OpBatch
    shard_plans: list[ShardPlan]
    seq: int = -1  # planner-assigned batch number (trace correlation)

    @property
    def n_ops(self) -> int:
        return len(self.batch)

    @property
    def scan_ids(self) -> np.ndarray:
        return self.batch.scan_ids


class Planner:
    """Compiles ``OpBatch``es into per-shard ``ShardPlan``s.

    Routing is columnar: one vectorized ``shard_of`` call covers every
    point op, one vectorized ``clip_ranges`` call covers every range op
    (clipping each [lo, hi) to the slabs it overlaps under range
    partitioning, broadcasting under hash).  Per shard, the op stream is
    ordered by op id and split into maximal same-kind runs — each run
    becomes one ``PlanStep``, so a shard executes exactly the vectorized
    sub-batches the old ``Engine.execute`` loop built per-op in Python.
    """

    def __init__(self, router: ShardRouter):
        self.router = router
        self._seq = count()

    def plan(self, batch: OpBatch) -> Plan:
        seq = next(self._seq)
        with span("plan.compile", kind=batch.kind_name,
                  n_ops=len(batch), batch=seq):
            return self._plan(batch, seq)

    def _plan(self, batch: OpBatch, seq: int) -> Plan:
        ns = self.router.num_shards
        kinds = batch.kinds
        point_ids = np.flatnonzero(kinds <= OP_GET)
        range_ids = np.flatnonzero(kinds >= OP_RANGE_DELETE)

        # Per-shard op ids (points) — split() is stable, ids ascend.
        if len(point_ids):
            psplit = self.router.split(batch.keys[point_ids])
        else:
            psplit = [np.zeros(0, np.int64)] * ns

        # Per-shard clipped visits (ranges), vectorized across the batch.
        rids, rshards, clos, chis = self.router.clip_ranges(
            batch.los[range_ids], batch.his[range_ids])

        plans = []
        for s in range(ns):
            oidx = point_ids[psplit[s]]
            slo = shi = None
            vm = rshards == s
            if vm.any():
                v_ids = range_ids[rids[vm]]
                oidx = np.concatenate([oidx, v_ids])
                slo = np.concatenate(
                    [np.zeros(len(oidx) - len(v_ids), np.uint64),
                     clos[vm]])
                shi = np.concatenate(
                    [np.zeros(len(oidx) - len(v_ids), np.uint64),
                     chis[vm]])
                order = np.argsort(oidx, kind="stable")
                oidx, slo, shi = oidx[order], slo[order], shi[order]
            plans.append(self._shard_plan(s, batch, oidx, slo, shi))
        for sp in plans:
            sp.seq = seq
        return Plan(batch=batch, shard_plans=plans, seq=seq)

    def _shard_plan(self, s: int, batch: OpBatch, oidx: np.ndarray,
                    slo, shi) -> ShardPlan:
        """Split one shard's ordered op-id stream into vectorized steps.

        Writes split on every kind change (their relative order is the
        semantics).  Reads are scheduled dependency-aware: a get or a
        range scan commutes with every other read, and it commutes with
        an intervening *write* as long as the write does not touch its
        key(s) — a range delete over a cold slab cannot change what a
        hot get observes.  The planner therefore keeps one *open read
        slot* and hoists each arriving read into it unless the read
        overlaps a write accumulated since the slot opened; a
        conflicting read closes the slot (materializing at most one
        batched-get step and one batched-scan step at its position) and
        opens a fresh slot after the writes.  Mixed streams thus compile
        to a few large read sub-batches — big enough to amortize kernel
        launches — while every read still observes exactly the writes
        its results depend on.
        """
        sp = ShardPlan(shard=s)
        if len(oidx) == 0:
            return sp
        k = batch.kinds[oidx]
        wr = (k != OP_GET) & (k != OP_RANGE_SCAN)
        brk = (wr[1:] != wr[:-1]) | (wr[1:] & (k[1:] != k[:-1]))
        bounds = np.concatenate(
            [[0], np.flatnonzero(brk) + 1, [len(k)]])

        items: list = []  # PlanStep (writes) | dict (open read slots)
        slot: dict | None = None

        def open_slot() -> dict:
            # gets/scans accumulate op ids (+ scan bounds); wlo/whi and
            # wkeys are the ranges/keys written since the slot opened.
            s_ = {"gets": [], "scans": [], "wlo": [], "whi": [],
                  "wkeys": []}
            items.append(s_)
            return s_

        for a, b in zip(bounds[:-1].tolist(), bounds[1:].tolist()):
            kind = int(k[a])
            idx = oidx[a:b]
            if wr[a]:
                if kind in _POINT_KINDS:
                    items.append(PlanStep(
                        kind=kind, idx=idx, keys=batch.keys[idx],
                        vals=batch.vals[idx] if kind == OP_PUT else None))
                    if slot is not None:
                        slot["wkeys"].append(batch.keys[idx])
                else:
                    items.append(PlanStep(
                        kind=kind, idx=idx, los=slo[a:b], his=shi[a:b]))
                    if slot is not None:
                        slot["wlo"].append(slo[a:b])
                        slot["whi"].append(shi[a:b])
                continue
            if slot is None:
                slot = open_slot()
            gets = idx[k[a:b] == OP_GET]
            sm = k[a:b] == OP_RANGE_SCAN
            scans = (idx[sm], slo[a:b][sm], shi[a:b][sm]) \
                if sm.any() else None
            g_conf, s_conf = self._read_conflicts(batch, slot, gets,
                                                  scans)
            if len(gets):
                slot["gets"].append(gets[~g_conf])
            if scans is not None:
                slot["scans"].append(tuple(x[~s_conf] for x in scans))
            if g_conf.any() or (s_conf is not None and s_conf.any()):
                # Conflicting reads must observe the writes: close the
                # slot and start a fresh one after them.
                slot = open_slot()
                if g_conf.any():
                    slot["gets"].append(gets[g_conf])
                if s_conf is not None and s_conf.any():
                    slot["scans"].append(tuple(x[s_conf] for x in scans))

        for item in items:
            if isinstance(item, PlanStep):
                sp.steps.append(item)
                continue
            gids = [g for g in item["gets"] if len(g)]
            if gids:
                gid = np.concatenate(gids)
                sp.steps.append(PlanStep(kind=OP_GET, idx=gid,
                                         keys=batch.keys[gid]))
            sids = [t for t in item["scans"] if len(t[0])]
            if sids:
                sp.steps.append(PlanStep(
                    kind=OP_RANGE_SCAN,
                    idx=np.concatenate([t[0] for t in sids]),
                    los=np.concatenate([t[1] for t in sids]),
                    his=np.concatenate([t[2] for t in sids])))
        return sp

    @staticmethod
    def _read_conflicts(batch: OpBatch, slot: dict, gets: np.ndarray,
                        scans):
        """Which of a read segment's ops overlap the slot's writes.

        A get conflicts if a write range covers its key or a written key
        equals it; a scan conflicts if a write range overlaps [lo, hi)
        or a written key falls inside it.  Everything else is safe to
        hoist into the open slot (the writes cannot change its result).
        """
        wlo = np.concatenate(slot["wlo"]) if slot["wlo"] else None
        wk = np.concatenate(slot["wkeys"]) if slot["wkeys"] else None
        g_conf = np.zeros(len(gets), dtype=bool)
        if len(gets):
            keys = batch.keys[gets]
            if wlo is not None:
                whi = np.concatenate(slot["whi"])
                g_conf |= ((keys[:, None] >= wlo[None, :]) &
                           (keys[:, None] < whi[None, :])).any(axis=1)
            if wk is not None:
                g_conf |= np.isin(keys, wk)
        if scans is None:
            return g_conf, None
        _, alos, ahis = scans
        s_conf = np.zeros(len(alos), dtype=bool)
        if wlo is not None:
            whi = np.concatenate(slot["whi"])
            s_conf |= ((alos[:, None] < whi[None, :]) &
                       (ahis[:, None] > wlo[None, :])).any(axis=1)
        if wk is not None:
            s_conf |= ((wk[None, :] >= alos[:, None]) &
                       (wk[None, :] < ahis[:, None])).any(axis=1)
        return g_conf, s_conf
