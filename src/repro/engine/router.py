"""Shard routing: partition op batches across shards, merge in order.

Two partition schemes:

  hash    shard = mix64(key) % N.  Point ops spread uniformly; a range
          delete broadcasts to every shard (its keys are scattered).
  range   the key universe is cut into N equal slabs; point ops go to
          their slab, range ops touch only overlapping slabs (clipped,
          so each shard's global index never learns about foreign keys).

Every key deterministically owns exactly one shard, so per-shard sequence
numbers are enough for correctness: visibility (newest-wins, range-delete
kills strictly older) only ever compares entries of the SAME key, and a
key's whole history lives on one shard in arrival order.

``split`` returns per-shard index arrays; callers scatter per-shard
results through those indices to restore request order exactly.
"""

from __future__ import annotations

import numpy as np

_MIX_MUL = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (same mixing family as repro.core.eve)."""
    x = np.asarray(x, dtype=np.uint64) * _MIX_MUL
    x ^= x >> np.uint64(31)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(29)
    return x


class ShardRouter:
    def __init__(self, num_shards: int, partition: str = "hash",
                 universe: int = 1 << 63):
        assert num_shards >= 1
        assert partition in ("hash", "range"), partition
        self.num_shards = num_shards
        self.partition = partition
        self.universe = int(universe)
        # Slab width for range partitioning (ceil so N slabs cover U).
        self._width = -(-self.universe // num_shards)

    # ------------------------------------------------------------ points
    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Owning shard of each key; (n,) int64."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.num_shards == 1:
            return np.zeros(len(keys), dtype=np.int64)
        if self.partition == "hash":
            return (_mix64(keys) % np.uint64(self.num_shards)).astype(
                np.int64)
        return np.minimum(keys // np.uint64(self._width),
                          self.num_shards - 1).astype(np.int64)

    def shard_of_scalar(self, key: int) -> int:
        return int(self.shard_of(np.asarray([key], dtype=np.uint64))[0])

    def split(self, keys: np.ndarray) -> list[np.ndarray]:
        """Index arrays per shard: keys[idx[s]] is shard s's sub-batch.

        Indices are ascending within each shard (stable), so per-shard
        sub-batches preserve the request's relative order; scattering
        results back through idx[s] restores full request order.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        if self.num_shards == 1:
            return [np.arange(len(keys))]
        sid = self.shard_of(keys)
        order = np.argsort(sid, kind="stable")
        counts = np.bincount(sid, minlength=self.num_shards)
        bounds = np.concatenate([[0], np.cumsum(counts)])
        return [order[bounds[s]:bounds[s + 1]]
                for s in range(self.num_shards)]

    # ------------------------------------------------------------ ranges
    def shards_for_range(self, lo: int, hi: int) -> list[tuple[int, int,
                                                               int]]:
        """(shard, lo', hi') per shard a range op must visit."""
        lo, hi = int(lo), int(hi)
        assert lo < hi
        if self.partition == "hash":
            # Keys of the range are scattered: broadcast, unclipped.
            return [(s, lo, hi) for s in range(self.num_shards)]
        first = min(lo // self._width, self.num_shards - 1)
        last = min((hi - 1) // self._width, self.num_shards - 1)
        out = []
        for s in range(first, last + 1):
            slab_lo = s * self._width
            # The last slab is unbounded above: shard_of clamps every
            # key >= universe into it, so range ops must reach them too.
            slab_hi = (s + 1) * self._width \
                if s < self.num_shards - 1 else hi
            c_lo, c_hi = max(lo, slab_lo), min(hi, slab_hi)
            if c_lo < c_hi:
                out.append((s, c_lo, c_hi))
        return out

    def split_ranges(self, ranges) -> list[list[tuple[int, int, int]]]:
        """Per-shard worklists for a batch of range ops.

        Returns one list per shard of ``(rid, lo', hi')`` visits, where
        ``rid`` indexes the request batch and [lo', hi') is the clipped
        sub-range that shard must serve.  Within a shard, visits keep
        request order (rid ascending), so batched range ops interleave
        correctly with the shard's other work; callers reassemble
        per-request results by rid.  Range partitioning visits only
        overlapping slabs; hash partitioning broadcasts (see
        ``shards_for_range``).
        """
        out: list[list[tuple[int, int, int]]] = [
            [] for _ in range(self.num_shards)]
        for rid, (lo, hi) in enumerate(ranges):
            for s, c_lo, c_hi in self.shards_for_range(lo, hi):
                out[s].append((rid, c_lo, c_hi))
        return out
