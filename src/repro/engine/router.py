"""Shard routing: partition op batches across shards, merge in order.

Two partition schemes:

  hash    shard = mix64(key) % N.  Point ops spread uniformly; a range
          delete broadcasts to every shard (its keys are scattered).
  range   the key universe is cut into N equal slabs; point ops go to
          their slab, range ops touch only overlapping slabs (clipped,
          so each shard's global index never learns about foreign keys).

Every key deterministically owns exactly one shard, so per-shard sequence
numbers are enough for correctness: visibility (newest-wins, range-delete
kills strictly older) only ever compares entries of the SAME key, and a
key's whole history lives on one shard in arrival order.

``split`` returns per-shard index arrays; callers scatter per-shard
results through those indices to restore request order exactly.
"""

from __future__ import annotations

import numpy as np

_MIX_MUL = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (same mixing family as repro.core.eve)."""
    x = np.asarray(x, dtype=np.uint64) * _MIX_MUL
    x ^= x >> np.uint64(31)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(29)
    return x


class ShardRouter:
    def __init__(self, num_shards: int, partition: str = "hash",
                 universe: int = 1 << 63):
        assert num_shards >= 1
        assert partition in ("hash", "range"), partition
        self.num_shards = num_shards
        self.partition = partition
        self.universe = int(universe)
        # Slab width for range partitioning (ceil so N slabs cover U).
        self._width = -(-self.universe // num_shards)

    # ------------------------------------------------------------ points
    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Owning shard of each key; (n,) int64."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.num_shards == 1:
            return np.zeros(len(keys), dtype=np.int64)
        if self.partition == "hash":
            return (_mix64(keys) % np.uint64(self.num_shards)).astype(
                np.int64)
        return np.minimum(keys // np.uint64(self._width),
                          self.num_shards - 1).astype(np.int64)

    def shard_of_scalar(self, key: int) -> int:
        return int(self.shard_of(np.asarray([key], dtype=np.uint64))[0])

    def split(self, keys: np.ndarray) -> list[np.ndarray]:
        """Index arrays per shard: keys[idx[s]] is shard s's sub-batch.

        Indices are ascending within each shard (stable), so per-shard
        sub-batches preserve the request's relative order; scattering
        results back through idx[s] restores full request order.
        """
        keys = np.asarray(keys, dtype=np.uint64)
        if self.num_shards == 1:
            return [np.arange(len(keys))]
        sid = self.shard_of(keys)
        order = np.argsort(sid, kind="stable")
        counts = np.bincount(sid, minlength=self.num_shards)
        bounds = np.concatenate([[0], np.cumsum(counts)])
        return [order[bounds[s]:bounds[s + 1]]
                for s in range(self.num_shards)]

    # ------------------------------------------------------------ ranges
    def clip_ranges(self, los, his) -> tuple[np.ndarray, np.ndarray,
                                             np.ndarray, np.ndarray]:
        """Vectorized shard visits for a batch of [lo, hi) range ops.

        Returns ``(rids, shards, clos, chis)`` parallel arrays, one row
        per (range, shard) visit, ordered by rid then shard: range op
        ``rids[i]`` must visit ``shards[i]`` with the clipped sub-range
        [``clos[i]``, ``chis[i]``).  Under hash partitioning every range
        broadcasts unclipped (its keys are scattered); under range
        partitioning each range visits only the slabs it overlaps, and
        the last slab is unbounded above (``shard_of`` clamps every key
        >= universe into it, so range ops must reach them too).
        """
        los = np.asarray(los, dtype=np.uint64)
        his = np.asarray(his, dtype=np.uint64)
        nr = len(los)
        assert len(his) == nr
        if nr and not (los < his).all():
            bad = int(np.flatnonzero(los >= his)[0])
            raise ValueError(f"empty range [{los[bad]}, {his[bad]})")
        ns = self.num_shards
        empty = (np.zeros(0, np.int64), np.zeros(0, np.int64),
                 np.zeros(0, np.uint64), np.zeros(0, np.uint64))
        if nr == 0:
            return empty
        if self.partition == "hash" or ns == 1:
            return (np.repeat(np.arange(nr, dtype=np.int64), ns),
                    np.tile(np.arange(ns, dtype=np.int64), nr),
                    np.repeat(los, ns), np.repeat(his, ns))
        w = np.uint64(self._width)
        first = np.minimum(los // w, np.uint64(ns - 1)).astype(np.int64)
        last = np.minimum((his - np.uint64(1)) // w,
                          np.uint64(ns - 1)).astype(np.int64)
        counts = last - first + 1
        total = int(counts.sum())
        rids = np.repeat(np.arange(nr, dtype=np.int64), counts)
        offs = np.concatenate([[0], np.cumsum(counts)[:-1]])
        shards = first[rids] + (np.arange(total, dtype=np.int64)
                                - np.repeat(offs, counts))
        slab_lo = shards.astype(np.uint64) * w
        slab_hi = np.where(shards < ns - 1,
                           (shards.astype(np.uint64) + np.uint64(1)) * w,
                           his[rids])
        clos = np.maximum(los[rids], slab_lo)
        chis = np.minimum(his[rids], slab_hi)
        keep = clos < chis
        if keep.all():
            return rids, shards, clos, chis
        return rids[keep], shards[keep], clos[keep], chis[keep]

    def shards_for_range(self, lo: int, hi: int) -> list[tuple[int, int,
                                                               int]]:
        """(shard, lo', hi') per shard a range op must visit."""
        _, shards, clos, chis = self.clip_ranges([lo], [hi])
        return [(int(s), int(a), int(b))
                for s, a, b in zip(shards, clos, chis)]

    def split_ranges(self, ranges) -> list[list[tuple[int, int, int]]]:
        """Per-shard worklists for a batch of range ops.

        Returns one list per shard of ``(rid, lo', hi')`` visits, where
        ``rid`` indexes the request batch and [lo', hi') is the clipped
        sub-range that shard must serve.  Within a shard, visits keep
        request order (rid ascending), so batched range ops interleave
        correctly with the shard's other work; callers reassemble
        per-request results by rid.  Range partitioning visits only
        overlapping slabs; hash partitioning broadcasts (see
        ``shards_for_range``).
        """
        ranges = list(ranges)
        out: list[list[tuple[int, int, int]]] = [
            [] for _ in range(self.num_shards)]
        rids, shards, clos, chis = self.clip_ranges(
            [r[0] for r in ranges], [r[1] for r in ranges])
        for rid, s, lo, hi in zip(rids.tolist(), shards.tolist(),
                                  clos.tolist(), chis.tolist()):
            out[s].append((rid, lo, hi))
        return out
