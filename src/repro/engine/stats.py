"""Engine-level rollups: throughput, latency, I/O, cache, kernel usage.

Each shard executor owns an ``IOStats`` ledger and kernel counters; the
engine aggregates them here, together with per-op-type wall time, so one
``engine.stats()`` call answers "what did the fleet do and what did it
cost" — the serving-tier analogue of ``LSMTree.stats``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..obs import LatencyHistogram


@dataclass
class KernelCounters:
    """How often the fused Pallas filter/merge stages actually ran."""

    interval_calls: int = 0     # interval_query launches (DR-tree levels)
    interval_queries: int = 0   # point-stab verdicts produced by them
    bloom_calls: int = 0        # bloom_probe launches (SSTable filters)
    bloom_queries: int = 0      # filter verdicts produced by them
    merge_calls: int = 0        # merge_ranks launches (scan merge rounds)
    merge_keys: int = 0         # keys positioned by them
    cascade_calls: int = 0      # fused lookup-cascade launches
    cascade_queries: int = 0    # lookups answered by the cascade
    cascade_packs: int = 0      # registry device-state (re)packs
    upload_bytes: int = 0       # host->device bytes moved by the packs
    # upload_bytes split by destination device ("cpu:0", ... — "host"
    # when packs stay on the default device): the per-device ledger the
    # multi-device registry charges, so steady-state "uploaded once per
    # device, not once per batch" is assertable per device.
    upload_bytes_by_device: dict = field(default_factory=dict)

    def merge(self, other: "KernelCounters") -> None:
        """Accumulate another ledger into this one (fleet rollups)."""
        self.interval_calls += other.interval_calls
        self.interval_queries += other.interval_queries
        self.bloom_calls += other.bloom_calls
        self.bloom_queries += other.bloom_queries
        self.merge_calls += other.merge_calls
        self.merge_keys += other.merge_keys
        self.cascade_calls += other.cascade_calls
        self.cascade_queries += other.cascade_queries
        self.cascade_packs += other.cascade_packs
        self.upload_bytes += other.upload_bytes
        for dev, nbytes in other.upload_bytes_by_device.items():
            self.upload_bytes_by_device[dev] = \
                self.upload_bytes_by_device.get(dev, 0) + nbytes

    @classmethod
    def from_snapshot(cls, snap: dict) -> "KernelCounters":
        """Inverse of ``snapshot()`` — how a shard-worker reply's
        cumulative kernel ledger rehydrates on the parent side."""
        out = cls()
        for k, v in (snap or {}).items():
            if k == "upload_bytes_by_device":
                out.upload_bytes_by_device = {str(d): int(b)
                                              for d, b in v.items()}
            elif hasattr(out, k):
                setattr(out, k, int(v))
        return out

    def snapshot(self) -> dict:
        return {
            "interval_calls": self.interval_calls,
            "interval_queries": self.interval_queries,
            "bloom_calls": self.bloom_calls,
            "bloom_queries": self.bloom_queries,
            "merge_calls": self.merge_calls,
            "merge_keys": self.merge_keys,
            "cascade_calls": self.cascade_calls,
            "cascade_queries": self.cascade_queries,
            "cascade_packs": self.cascade_packs,
            "upload_bytes": self.upload_bytes,
            "upload_bytes_by_device": dict(sorted(
                self.upload_bytes_by_device.items())),
        }


@dataclass
class EngineStats:
    """Per-op-class rollups of everything the engine executed.

    ``record`` is called once per engine-level batch with the op class
    (``get``, ``put``, ``delete``, ``range_scan``, ``range_delete``,
    ``mixed``), the number of logical ops in the batch, its wall time,
    and the simulated block I/O it charged — so latency AND I/O are
    attributable per op class, not just in aggregate.
    """

    ops: dict = field(default_factory=dict)        # op -> count
    wall: dict = field(default_factory=dict)       # op -> seconds
    batches: dict = field(default_factory=dict)    # op -> batch count
    io_reads: dict = field(default_factory=dict)   # op -> blocks read
    io_writes: dict = field(default_factory=dict)  # op -> blocks written
    shard_wall: dict = field(default_factory=dict)   # shard -> busy s
    shard_stall: dict = field(default_factory=dict)  # shard -> idle s
    pipelined_batches: int = 0
    serial_batches: int = 0
    staging: dict = field(default_factory=dict)      # buffer occupancy
    latency: dict = field(default_factory=dict)      # op -> histogram
    shard_latency: dict = field(default_factory=dict)  # shard -> histogram

    def record(self, op: str, n: int, seconds: float,
               io_reads: int = 0, io_writes: int = 0) -> None:
        self.ops[op] = self.ops.get(op, 0) + int(n)
        self.wall[op] = self.wall.get(op, 0.0) + float(seconds)
        self.batches[op] = self.batches.get(op, 0) + 1
        self.io_reads[op] = self.io_reads.get(op, 0) + int(io_reads)
        self.io_writes[op] = self.io_writes.get(op, 0) + int(io_writes)
        hist = self.latency.get(op)
        if hist is None:
            hist = self.latency[op] = LatencyHistogram()
        hist.record(seconds)

    def record_shards(self, walls: dict, pipelined: bool) -> None:
        """Per-shard busy/stall seconds for one submitted batch.

        ``walls`` maps shard id -> that shard's plan execution time.  A
        batch's critical path is its slowest shard; every other shard
        *stalls* for the difference (idle while the merge-back waits).
        Observable pipeline health: a balanced fleet has stall ~ 0, a
        skewed one shows where the wall time actually went.
        """
        if pipelined:
            self.pipelined_batches += 1
        else:
            self.serial_batches += 1
        if not walls:
            return
        crit = max(walls.values())
        for s, w in walls.items():
            self.shard_wall[s] = self.shard_wall.get(s, 0.0) + float(w)
            self.shard_stall[s] = self.shard_stall.get(s, 0.0) + \
                float(crit - w)
            hist = self.shard_latency.get(s)
            if hist is None:
                hist = self.shard_latency[s] = LatencyHistogram()
            hist.record(w)

    def record_staging(self, per_shard: list[dict]) -> None:
        """Current staging-buffer occupancy across the GLORAN shards.

        ``per_shard`` entries come from ``GloranIndex.buffer_snapshot``;
        the rollup keeps the fleet totals and the fill fraction so
        "how close is the next index flush" is answerable from stats.
        """
        recs = sum(d["records"] for d in per_shard)
        cap = sum(d["capacity"] for d in per_shard)
        self.staging = {
            "records": recs,
            "capacity": cap,
            "occupancy": round(recs / cap, 4) if cap else 0.0,
            "per_shard": per_shard,
        }

    def reset(self) -> None:
        """Zero every rollup (counts, walls, I/O, histograms).

        Long-lived serving sessions call this at window boundaries so
        ``snapshot()`` reports per-window latency/throughput instead of
        since-boot cumulative only (see ``Engine.reset_stats``).
        """
        for d in (self.ops, self.wall, self.batches, self.io_reads,
                  self.io_writes, self.shard_wall, self.shard_stall,
                  self.staging, self.latency, self.shard_latency):
            d.clear()
        self.pipelined_batches = 0
        self.serial_batches = 0

    def ops_per_sec(self, op: str) -> float:
        return self.ops.get(op, 0) / max(self.wall.get(op, 0.0), 1e-12)

    def us_per_op(self, op: str) -> float:
        n = self.ops.get(op, 0)
        return 1e6 * self.wall.get(op, 0.0) / n if n else 0.0

    def io_per_op(self, op: str) -> float:
        """Blocks (read + written) charged per logical op of this class."""
        n = self.ops.get(op, 0)
        io = self.io_reads.get(op, 0) + self.io_writes.get(op, 0)
        return io / n if n else 0.0

    def snapshot(self) -> dict:
        """Schema: each entry maps op class -> value.

        ``ops`` logical ops executed; ``batches`` engine-level calls;
        ``wall_seconds`` total wall time; ``ops_per_sec`` / ``us_per_op``
        derived throughput/latency; ``io_reads`` / ``io_writes`` blocks
        charged while serving that class; ``io_per_op`` blocks per op;
        ``shard_wall_seconds`` / ``shard_stall_seconds`` per-shard
        busy/idle time across submitted batches; ``pipelined_batches`` /
        ``serial_batches`` how each batch executed; ``staging_buffer``
        the current range-delete staging-buffer occupancy; ``latency``
        per-op-class batch-latency histograms (count/mean/p50/p95/p99,
        microseconds) and ``shard_latency`` the same per shard over its
        plan execution walls — the tail-latency view the scalar
        ``us_per_op`` mean cannot give.
        """
        return {
            "latency": {k: h.snapshot()
                        for k, h in sorted(self.latency.items())},
            "shard_latency": {s: h.snapshot()
                              for s, h in sorted(self.shard_latency
                                                 .items())},
            "pipelined_batches": self.pipelined_batches,
            "serial_batches": self.serial_batches,
            "staging_buffer": dict(self.staging),
            "shard_wall_seconds": {s: round(v, 6)
                                   for s, v in self.shard_wall.items()},
            "shard_stall_seconds": {s: round(v, 6)
                                    for s, v in self.shard_stall.items()},
            "ops": dict(self.ops),
            "wall_seconds": {k: round(v, 6) for k, v in self.wall.items()},
            "batches": dict(self.batches),
            "ops_per_sec": {k: round(self.ops_per_sec(k), 1)
                            for k in self.ops},
            "us_per_op": {k: round(self.us_per_op(k), 3) for k in self.ops},
            "io_reads": dict(self.io_reads),
            "io_writes": dict(self.io_writes),
            "io_per_op": {k: round(self.io_per_op(k), 4) for k in self.ops},
        }


def merge_io_snapshots(snaps: list[dict]) -> dict:
    """Sum per-shard IOStats snapshots into one fleet ledger."""
    out = {"reads": 0, "writes": 0, "total": 0, "by_tag": {}}
    for s in snaps:
        out["reads"] += s["reads"]
        out["writes"] += s["writes"]
        out["total"] += s["total"]
        for tag, n in s["by_tag"].items():
            out["by_tag"][tag] = out["by_tag"].get(tag, 0) + n
    return out
