"""Engine-level rollups: throughput, latency, I/O, cache, kernel usage.

Each shard executor owns an ``IOStats`` ledger and kernel counters; the
engine aggregates them here, together with per-op-type wall time, so one
``engine.stats()`` call answers "what did the fleet do and what did it
cost" — the serving-tier analogue of ``LSMTree.stats``.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class KernelCounters:
    """How often the fused Pallas filter stage actually ran."""

    interval_calls: int = 0     # interval_query launches (DR-tree levels)
    interval_queries: int = 0   # point-stab verdicts produced by them
    bloom_calls: int = 0        # bloom_probe launches (SSTable filters)
    bloom_queries: int = 0      # filter verdicts produced by them

    def snapshot(self) -> dict:
        return {
            "interval_calls": self.interval_calls,
            "interval_queries": self.interval_queries,
            "bloom_calls": self.bloom_calls,
            "bloom_queries": self.bloom_queries,
        }


@dataclass
class EngineStats:
    ops: dict = field(default_factory=dict)        # op -> count
    wall: dict = field(default_factory=dict)       # op -> seconds
    batches: dict = field(default_factory=dict)    # op -> batch count

    def record(self, op: str, n: int, seconds: float) -> None:
        self.ops[op] = self.ops.get(op, 0) + int(n)
        self.wall[op] = self.wall.get(op, 0.0) + float(seconds)
        self.batches[op] = self.batches.get(op, 0) + 1

    def ops_per_sec(self, op: str) -> float:
        return self.ops.get(op, 0) / max(self.wall.get(op, 0.0), 1e-12)

    def us_per_op(self, op: str) -> float:
        n = self.ops.get(op, 0)
        return 1e6 * self.wall.get(op, 0.0) / n if n else 0.0

    def snapshot(self) -> dict:
        return {
            "ops": dict(self.ops),
            "wall_seconds": {k: round(v, 6) for k, v in self.wall.items()},
            "batches": dict(self.batches),
            "ops_per_sec": {k: round(self.ops_per_sec(k), 1)
                            for k in self.ops},
            "us_per_op": {k: round(self.us_per_op(k), 3) for k in self.ops},
        }


def merge_io_snapshots(snaps: list[dict]) -> dict:
    """Sum per-shard IOStats snapshots into one fleet ledger."""
    out = {"reads": 0, "writes": 0, "total": 0, "by_tag": {}}
    for s in snaps:
        out["reads"] += s["reads"]
        out["writes"] += s["writes"]
        out["total"] += s["total"]
        for tag, n in s["by_tag"].items():
            out["by_tag"][tag] = out["by_tag"].get(tag, 0) + n
    return out
