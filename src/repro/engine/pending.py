"""Future-like handles for submitted op batches (the *collect* stage).

``Engine.submit(batch)`` compiles the batch into per-shard plans and
returns a ``PendingBatch`` immediately.  Pipelined, every shard plan runs
on that shard's single-worker pool — shards execute concurrently, but
each shard sees its batches in submit order (per-shard FIFO), which is
all correctness needs: a key's whole history lives on one shard.  Serial
(``pipeline=False``), the shard plans run inline at submit time in shard
order — exactly the old ``Engine.execute`` control flow — and collection
is a no-op.  Either way the results are identical; only the overlap
differs.

Collection merges per-shard payloads back in deterministic request
order: get verdicts scatter through their op ids, and each scan's
per-shard parts are combined in ascending shard order (slab concatenation
under range partitioning, sorted-view merge under hash), so pipelined
and serial execution return byte-identical results.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from ..obs import span
from .plan import OP_GET, Plan


class PendingBatch:
    """An in-flight (or completed) submitted ``OpBatch``.

    ``wait()`` blocks until every shard plan finished and the merge-back
    ran (idempotent, thread-safe).  ``results()`` returns one slot per
    op in request order — gets yield value-or-None, range scans yield a
    sorted ``(keys, vals)`` pair, writes yield None.  ``get_results()``
    / ``scan_results()`` are the columnar accessors the typed engine
    wrappers use.  All accessors imply ``wait()``.

    Overlap contract: while a pipelined batch is in flight, submitting
    more batches is safe (per-shard FIFO), but out-of-band access to the
    engine's shards (``flush``, direct tree reads) must happen after
    ``wait()`` / ``Engine.drain()``.
    """

    def __init__(self, engine, plan: Plan, pipeline: bool):
        self.engine = engine
        self.plan = plan
        self.pipeline = pipeline
        self._t0 = time.perf_counter()
        self._io0 = engine._io_marks()
        self._futures: dict | None = None
        self._payloads: dict | None = None
        self._collected = False
        self._found: np.ndarray | None = None
        self._vals: np.ndarray | None = None
        self._scan_out: dict | None = None
        self._walls: dict[int, float] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ launch
    def _start(self) -> None:
        active = [sp for sp in self.plan.shard_plans if sp]
        if self.pipeline:
            pools = self.engine._shard_pools()
            self._futures = {
                sp.shard: pools[sp.shard].submit(
                    self.engine.shards[sp.shard].run_plan, sp)
                for sp in active}
        else:
            self._payloads = {
                sp.shard: self.engine.shards[sp.shard].run_plan(sp)
                for sp in active}

    # ----------------------------------------------------------- collect
    def done(self) -> bool:
        """True once every shard plan has finished executing."""
        if self._futures is not None and not self._collected:
            return all(f.done() for f in self._futures.values())
        return True

    def wait(self) -> "PendingBatch":
        """Block until executed + merged; safe to call repeatedly."""
        with self._lock:
            if not self._collected:
                # Device-aware collect: the span records how many
                # distinct devices served the batch, so a trace shows
                # whether the merge-back actually waited on parallel
                # devices or on one serialized default device.
                devs = self.engine.device_map()
                with span("engine.collect",
                          kind=self.plan.batch.kind_name,
                          batch=self.plan.seq,
                          pipelined=self.pipeline,
                          devices=len(set(devs.values()))):
                    self._collect()
                self._collected = True
        return self

    def _collect(self) -> None:
        if self._futures is not None:
            # The blocking part: waiting out the slowest shard plan.
            with span("engine.wait", batch=self.plan.seq):
                payloads = {s: f.result()
                            for s, f in self._futures.items()}
        elif self._payloads is not None:
            payloads = self._payloads
        elif not any(self.plan.shard_plans):
            payloads = {}  # empty batch: nothing was launched
        else:
            raise RuntimeError("PendingBatch collected before _start()")
        n = self.plan.n_ops
        found = np.zeros(n, dtype=bool)
        vals = np.zeros(n, dtype=np.uint64)
        scan_parts: dict[int, list] = {
            i: [] for i in self.plan.scan_ids.tolist()}
        # Ascending shard order keeps scan merge-back deterministic (and,
        # under range partitioning, already globally sorted).
        for s in sorted(payloads):
            step_payloads, wall = payloads[s]
            self._walls[s] = wall
            for payload in step_payloads:
                if payload[0] == OP_GET:
                    _, idx, f, v = payload
                    found[idx] = f
                    vals[idx] = v
                else:
                    _, idx, res = payload
                    for i, kv in zip(idx.tolist(), res):
                        scan_parts[i].append(kv)
        self._found, self._vals = found, vals
        self._scan_out = {i: self.engine._merge_scan_parts(ps)
                          for i, ps in scan_parts.items()}
        self.engine._finish_batch(self)

    # ----------------------------------------------------------- results
    def results(self) -> list:
        """One slot per op, request order (the ``execute`` contract)."""
        self.wait()
        out: list = [None] * self.plan.n_ops
        for i in self.plan.batch.get_ids.tolist():
            out[i] = int(self._vals[i]) if self._found[i] else None
        for i, kv in self._scan_out.items():
            out[i] = kv
        return out

    def get_results(self) -> tuple[np.ndarray, np.ndarray]:
        """(found mask, values) over the batch's get ops, in op order."""
        self.wait()
        gids = self.plan.batch.get_ids
        return self._found[gids], self._vals[gids]

    def scan_results(self) -> list:
        """Merged (keys, vals) per range scan op, in op order."""
        self.wait()
        return [self._scan_out[i] for i in self.plan.scan_ids.tolist()]

    @property
    def shard_walls(self) -> dict[int, float]:
        """Per-shard busy seconds (populated after ``wait``)."""
        return dict(self._walls)

    @property
    def shard_devices(self) -> dict[int, str]:
        """Home device per shard that executed this batch ("host" when
        the engine runs the single-device fallback)."""
        return {s: self.engine.device_map()[s] for s in self._walls}
