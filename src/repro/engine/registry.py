"""Device-resident read-path filter registry for the fused cascade.

The per-level Pallas filter path re-uploads every SSTable's Bloom words
and every DR-tree level's interval columns from host numpy on every
``get_batch``.  This registry makes the whole filter stack **persistent
device state**: each SSTable's packed piece (u32 keys + entry seqs +
pow2-padded Bloom words) is uploaded once when the run is first probed
— runs are immutable, so the piece is cached on ``SSTable.uid`` until a
compaction replaces the run — and the GLORAN disjoint interval view is
uploaded once per index epoch (``LSMDRTree.epoch`` moves on index
flush/compaction/GC).  Assembling a ``CascadeState`` for the cascade
kernel is then a device-side concat of cached pieces; a steady-state
lookup uploads nothing but its own query tiles.

Pow2 padding everywhere (keys, words, interval columns, totals) bounds
the set of distinct compiled kernel shapes to O(log) per dimension
across compactions, the same discipline as the interval kernel's padded
level views.

Eligibility: the cascade compares keys exactly in u32 working space
(TPU has no 64-bit integer ops), so a tree whose level keys or entry
seqs reach 2^32 - 1 is declined wholesale and the per-level host/kernel
path serves it — identical results, just per-level launches.  GLORAN
interval columns are *clamped* into u32 like the per-level view (exact
for u32-range queries); packs past the kernels' VMEM budgets are also
declined.  Every decline is cached on the same key as a hit, so
ineligible trees pay one scan, not one per lookup.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.cascade.ops import (CascadeState, MAX_PACK_AREAS,
                                   MAX_PACK_BYTES, MAX_PACK_KEYS,
                                   MAX_PACK_WORDS, pack_bytes)
from ..obs import span
from .stats import KernelCounters

_U32_LIMIT = 0xFFFFFFFF
_MAX_LEVEL_BITS = 30  # survivor masks are int32 bitmasks


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


def clamp_level_u32(areas):
    """Clamped, pow2-padded u32 columns of one disjoint DR-tree level.

    THE single source of the u32 working-space transform both kernel
    paths rely on (the cascade's packed GLORAN view here, the per-level
    interval path via ``ShardExecutor._level_u32``) — the cascade-vs-
    per-level parity contract requires the two to stay bit-identical.
    Exact for queries with key, seq < 2^32 - 1: areas that cannot cover
    such queries (lo or smin past u32) are dropped, hi/smax are clamped
    to the u32 ceiling (coverage for in-range queries is unchanged), and
    the columns are padded to a power of two (min 64) with
    never-covering sentinels (lo = hi = ceiling, smax = 0) so compiled
    kernel shapes stay O(log n) distinct across compactions.

    Returns ``(lo, hi, smin, smax, n)`` numpy uint32 columns + the true
    (unpadded) area count.
    """
    ceil = np.uint64(_U32_LIMIT)
    keep = (areas.lo < ceil) & (areas.smin < ceil)
    lo = areas.lo[keep]
    n = len(lo)
    pad = max(64, _next_pow2(n))
    cols = (np.full(pad, _U32_LIMIT, np.uint32),
            np.full(pad, _U32_LIMIT, np.uint32),
            np.zeros(pad, np.uint32),
            np.zeros(pad, np.uint32))
    cols[0][:n] = lo.astype(np.uint32)
    cols[1][:n] = np.minimum(areas.hi[keep], ceil).astype(np.uint32)
    cols[2][:n] = areas.smin[keep].astype(np.uint32)
    cols[3][:n] = np.minimum(areas.smax[keep], ceil).astype(np.uint32)
    return cols[0], cols[1], cols[2], cols[3], n


@dataclass
class _RunPiece:
    """One SSTable's device-resident filter piece (immutable, per-uid)."""

    sstable: object        # pinned: uid is only unique while it lives
    keys: jax.Array        # (pow2,) u32, 0xFFFFFFFF sentinels
    seqs: jax.Array        # (pow2,) u32, zero padding
    words: jax.Array       # (pow2,) u32 Bloom words, zero padding
    n: int                 # true entry count
    m_bits: int
    seeds: np.ndarray      # (H,) u32


@dataclass
class _GlPiece:
    """One DR-tree level's clamped u32 interval columns (per-object)."""

    level: object          # pinned DRTree
    lo: jax.Array          # (pow2,) u32, never-covering sentinels
    hi: jax.Array
    smin: jax.Array
    smax: jax.Array
    n: int                 # clamped area count


@dataclass
class CascadeView:
    """Everything one fused launch needs for one tree state."""

    state: CascadeState
    slots: np.ndarray          # tree level index -> packed column (-1)
    has_gloran: bool           # gl_cov columns align with index levels


class DeviceFilterRegistry:
    """Per-shard cache of device-resident packed filter state.

    Invalidation is structural, never temporal: the LSM half keys on the
    exact (level index, run uid, run length) tuple — process-unique uids
    make stale hits impossible after compaction — and the GLORAN half
    keys on the index epoch.  A changed key rebuilds only the changed
    pieces (uploads are counted in the kernel counters' byte ledger,
    split per destination device) and re-concats the rest on device.

    Multi-device: a registry built with ``device=`` commits every upload
    to that shard's home XLA device and keys its caches on
    ``(uid-or-epoch-identity, device)``; an epoch bump or compaction
    therefore invalidates the piece on *every* device that cached it —
    each shard's registry sees the same structural key move and rebuilds
    its own copy.  ``device=None`` is the byte-identical legacy
    single-device path (plain uncommitted uploads).
    """

    def __init__(self, counters: KernelCounters | None = None,
                 device=None):
        self.counters = counters if counters is not None else \
            KernelCounters()
        # The shard's home XLA device.  None = legacy single-device path:
        # uploads are plain (uncommitted) jnp.asarray on the default
        # device.  Set, every upload is jax.device_put-committed to it,
        # so downstream jit dispatches run there (committed operands pin
        # placement) — per-device jit, no cross-shard serialization on
        # device 0.
        self.device = device
        self._dev_key = "host" if device is None else \
            f"{device.platform}:{device.id}"
        # Caches key on (uid-or-identity, device) per the invalidation
        # contract: a piece is only reusable on the device it was
        # committed to.  A registry serves one shard = one device, so
        # the second component is constant here, but the explicit key
        # keeps a piece from ever leaking across devices if a registry
        # is shared or re-homed.
        self._runs: dict[tuple, _RunPiece] = {}   # (uid, dev) -> piece
        self._gl: dict[tuple, _GlPiece] = {}      # (id(level), dev) -> piece
        self._view: CascadeView | None = None
        self._view_key: tuple | None = None          # includes declines
        self._bloom_words: OrderedDict[int, jax.Array] = OrderedDict()

    # ---------------------------------------------------------- placement
    def _put(self, arr) -> jax.Array:
        """Upload one host array: committed to the home device when one
        is set, plain default-device upload otherwise (legacy path)."""
        if self.device is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self.device)

    def _charge_upload(self, nbytes: int) -> None:
        """Count host->device bytes in the total AND per-device ledger."""
        self.counters.upload_bytes += nbytes
        by_dev = self.counters.upload_bytes_by_device
        by_dev[self._dev_key] = by_dev.get(self._dev_key, 0) + nbytes

    # ----------------------------------------------------------- packing
    def view(self, tree) -> CascadeView | None:
        """The cascade view of ``tree``'s current levels (+ GLORAN index
        when present), rebuilt only when the structure moved; None when
        the tree is cascade-ineligible."""
        lvls = [(i, lvl) for i, lvl in enumerate(tree.levels)
                if lvl is not None and len(lvl)]
        gloran = tree.gloran if tree.strategy == "gloran" else None
        gl_levels = gloran.level_views() if gloran is not None else None
        key = (len(tree.levels),
               tuple((i, lvl.uid, len(lvl)) for i, lvl in lvls),
               None if gloran is None else gloran.index_epoch,
               None if gl_levels is None else len(gl_levels))
        if key == self._view_key:
            return self._view
        with span("registry.pack", levels=len(lvls),
                  gl_levels=len(gl_levels or [])):
            view = self._build(tree, lvls, gl_levels)
        self._view, self._view_key = view, key
        return view

    def _build(self, tree, lvls, gl_levels) -> CascadeView | None:
        # Evict first, gate after: even a tree that has become cascade-
        # ineligible must release the pieces (and the runs/levels they
        # pin) of structures compaction has since replaced.
        self._evict(tree, gl_levels)
        if not lvls or len(lvls) > _MAX_LEVEL_BITS:
            return None
        if gl_levels is not None and len(gl_levels) > _MAX_LEVEL_BITS:
            return None
        for _, lvl in lvls:
            if lvl.max_key >= _U32_LIMIT or lvl.max_seq >= _U32_LIMIT:
                return None
        # Budget + uniformity gates run on host-side lengths BEFORE any
        # piece is built, so a permanently over-budget tree never pays a
        # host->device upload for a view that will always be declined.
        H = len(lvls[0][1].bloom.seeds)
        if any(len(lvl.bloom.seeds) != H for _, lvl in lvls):
            return None
        key_slots = sum(_next_pow2(len(lvl)) for _, lvl in lvls)
        word_slots = sum(_next_pow2(len(lvl.bloom.words))
                         for _, lvl in lvls)
        # u32 clamping only shrinks a level's columns, so the unclamped
        # bound is conservative (a decline just means per-level serving).
        area_slots = sum(max(64, _next_pow2(len(g.areas)))
                         for g in (gl_levels or []))
        if (key_slots > MAX_PACK_KEYS or word_slots > MAX_PACK_WORDS
                or area_slots > MAX_PACK_AREAS
                or pack_bytes(key_slots, word_slots,
                              area_slots) > MAX_PACK_BYTES):
            return None
        pieces = [self._run_piece(lvl) for _, lvl in lvls]
        key_pad = [p.keys.shape[0] for p in pieces]
        word_pad = [p.words.shape[0] for p in pieces]
        gl_pieces = [self._gl_piece(g) for g in (gl_levels or [])]
        gl_pad = [p.lo.shape[0] for p in gl_pieces]

        slots = np.full(len(tree.levels), -1, np.int32)
        for col, (i, _) in enumerate(lvls):
            slots[i] = col
        # Concats of committed pieces stay on the home device; the small
        # offset/count vectors are _put there too so a cascade dispatch
        # never mixes committed and default-device operands (placement
        # stays pinned, no per-call host hops for the metadata arrays).
        state = CascadeState(
            lkeys=jnp.concatenate([p.keys for p in pieces]),
            lseqs=jnp.concatenate([p.seqs for p in pieces]),
            key_off=self._put(
                np.cumsum([0] + key_pad[:-1]).astype(np.int32)),
            key_cnt=self._put(np.array([p.n for p in pieces], np.int32)),
            words=jnp.concatenate([p.words for p in pieces]),
            word_off=self._put(
                np.cumsum([0] + word_pad[:-1]).astype(np.int32)),
            mbits=self._put(
                np.array([p.m_bits for p in pieces], np.uint32)),
            seeds=self._put(np.stack([p.seeds for p in pieces])),
            glo_lo=self._gl_cat(gl_pieces, "lo"),
            glo_hi=self._gl_cat(gl_pieces, "hi"),
            glo_smin=self._gl_cat(gl_pieces, "smin"),
            glo_smax=self._gl_cat(gl_pieces, "smax"),
            gl_off=self._put(
                np.cumsum([0] + gl_pad[:-1]).astype(np.int32)
                if gl_pieces else np.zeros(0, np.int32)),
            gl_cnt=self._put(
                np.array([p.n for p in gl_pieces], np.int32)),
            L=len(pieces), H=H, G=len(gl_pieces),
            steps_keys=_steps(max(key_pad)),
            steps_gl=_steps(max(gl_pad) if gl_pad else 1),
            key_pad=tuple(key_pad), word_pad=tuple(word_pad),
            gl_pad=tuple(gl_pad))
        self.counters.cascade_packs += 1
        return CascadeView(state=state, slots=slots,
                           has_gloran=gl_levels is not None)

    def _gl_cat(self, pieces: list[_GlPiece], field: str) -> jax.Array:
        if not pieces:
            # G=0: placeholder operand (committed home-side like the rest)
            return self._put(np.zeros(1, np.uint32))
        return jnp.concatenate([getattr(p, field) for p in pieces])

    def _run_piece(self, lvl) -> _RunPiece:
        piece = self._runs.get((lvl.uid, self._dev_key))
        if piece is not None and piece.sstable is lvl:
            return piece
        with span("registry.upload_run", uid=lvl.uid, entries=len(lvl),
                  device=self._dev_key):
            n = len(lvl)
            pad = _next_pow2(n)
            keys = np.full(pad, _U32_LIMIT, np.uint32)
            keys[:n] = lvl.keys.astype(np.uint32)
            seqs = np.zeros(pad, np.uint32)
            seqs[:n] = lvl.seqs.astype(np.uint32)
            bb = lvl.bloom
            wpad = _next_pow2(len(bb.words))
            words = np.zeros(wpad, np.uint32)
            words[:len(bb.words)] = bb.words
            piece = _RunPiece(sstable=lvl, keys=self._put(keys),
                              seqs=self._put(seqs),
                              words=self._put(words),
                              n=n, m_bits=bb.m_bits, seeds=bb.seeds)
            self._charge_upload(keys.nbytes + seqs.nbytes + words.nbytes)
            self._runs[(lvl.uid, self._dev_key)] = piece
        return piece

    def _gl_piece(self, lvl) -> _GlPiece:
        piece = self._gl.get((id(lvl), self._dev_key))
        if piece is not None and piece.level is lvl:
            return piece
        with span("registry.upload_gl", areas=len(lvl.areas),
                  device=self._dev_key):
            lo, hi, smin, smax, n = clamp_level_u32(lvl.areas)
            piece = _GlPiece(level=lvl, lo=self._put(lo),
                             hi=self._put(hi), smin=self._put(smin),
                             smax=self._put(smax), n=n)
            self._charge_upload(4 * lo.nbytes)
            self._gl[(id(lvl), self._dev_key)] = piece
        return piece

    def _evict(self, tree, gl_levels) -> None:
        """Drop pieces of compacted-away runs/levels so stale device
        copies (and the objects they pin) don't linger."""
        live = {lvl.uid for lvl in tree.levels
                if lvl is not None and len(lvl)}
        self._runs = {k: p for k, p in self._runs.items()
                      if k[0] in live}
        for uid in [u for u in self._bloom_words if u not in live]:
            del self._bloom_words[uid]
        if gl_levels is not None:
            alive = {id(g) for g in gl_levels}
            self._gl = {k: p for k, p in self._gl.items()
                        if k[0] in alive}

    # -------------------------------------------- per-level device state
    def gl_columns(self, lvl, live) -> tuple:
        """Device-resident clamped u32 columns of one DR-tree level, for
        the per-level (non-cascade) interval path — served from the same
        cached ``_GlPiece`` the cascade packs, so both kernel paths
        share ONE upload and ONE device copy per level.  ``live`` is the
        index's current non-None level list; pieces of compacted-away
        levels are pruned against it (cascade-off engines never call
        ``view()``, so eviction must happen here too)."""
        alive = {id(g) for g in live}
        if any(k[0] not in alive for k in self._gl):
            self._gl = {k: p for k, p in self._gl.items()
                        if k[0] in alive}
        p = self._gl_piece(lvl)
        return p.lo, p.hi, p.smin, p.smax

    def bloom_words(self, lvl) -> jax.Array:
        """Device-resident Bloom words of one run, for the per-level
        (non-cascade) kernel path: uploaded once per uid, served from
        the cascade piece when one exists, else from a small LRU.
        Run uids are process-unique and never recycled, so a uid hit
        can never be stale; only the words are stored (no run pin)."""
        piece = self._runs.get((lvl.uid, self._dev_key))
        if piece is not None and piece.sstable is lvl:
            return piece.words  # pow2-padded: positions never reach pad
        words = self._bloom_words.get(lvl.uid)
        if words is not None:
            self._bloom_words.move_to_end(lvl.uid)
            return words
        words = self._put(lvl.bloom.words)
        self._charge_upload(lvl.bloom.words.nbytes)
        self._bloom_words[lvl.uid] = words
        if len(self._bloom_words) > 128:
            self._bloom_words.popitem(last=False)
        return words


def _steps(padded_max: int) -> int:
    """Fixed binary-search depth covering segments up to
    ``padded_max`` (+1 converge safety, like the interval kernel)."""
    return max(1, int(math.ceil(math.log2(padded_max + 1))) + 1)
