"""Sharded batched query engine fronting N LSM-tree shards.

The serving tier's execution layer, organized as **plan -> submit ->
collect**: a ``Planner`` compiles a typed ``OpBatch`` into per-shard
``ShardPlan``s (vectorized routing, range clipping, same-kind run
grouping), ``Engine.submit`` launches those plans — concurrently across
shards when pipelining is on, serially in shard order when off — and the
returned ``PendingBatch`` merges results back in request order.  The
classic conveniences (``get_batch``, ``range_scan_batch``, ``execute``,
...) are thin wrappers that build an ``OpBatch`` and block on ``submit``.
``num_shards=1`` degenerates to a single tree with the batched path —
the drop-in replacement for calling the tree directly.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..core.gloran import GloranConfig
from ..launch.mesh import shard_devices
from ..lsm import LSMConfig, LSMTree
from ..lsm.merge import merge_runs
from ..lsm.scheduler import CompactionScheduler
from ..obs import MetricsRegistry, span
from .executor import EngineConfig, ShardExecutor
from .pending import PendingBatch
from .plan import OpBatch, Planner
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots

_EMPTY_KV = (np.zeros(0, np.uint64), np.zeros(0, np.uint64))


def _resolve_devices(config: EngineConfig, num_shards: int) -> list | None:
    """The per-shard home-device assignment, or None for the legacy
    single-device path.

    ``EngineConfig.devices`` wins; None defers to ``REPRO_ENGINE_DEVICES``
    (same contract); unset = auto.  0 forces the ungated fallback.  Auto
    keeps single-device hosts on the exact legacy path (no pinning at
    all) and otherwise homes shards round-robin over up to ``num_shards``
    devices; an explicit N pins over the first min(N, available) — N=1
    included (pin everything to device 0), which is how the parity suite
    exercises the device-count-1 matrix cell.
    """
    want = config.devices
    if want is None:
        env = os.environ.get("REPRO_ENGINE_DEVICES", "").strip()
        want = int(env) if env else None
    if want == 0:
        return None
    import jax
    avail = len(jax.devices())
    if want is None:
        if avail <= 1:
            return None
        want = min(num_shards, avail)
    return shard_devices(num_shards, limit=want)


def _resolve_procs(config: EngineConfig, num_shards: int) -> int:
    """Worker-process count, or 0 for the in-process path.

    ``EngineConfig.procs`` wins; None defers to ``REPRO_ENGINE_PROCS``;
    unset/0 = off (byte-identical in-process execution).  N spawns
    min(N, num_shards) workers, shards assigned round-robin.
    """
    want = config.procs
    if want is None:
        env = os.environ.get("REPRO_ENGINE_PROCS", "").strip()
        want = int(env) if env else 0
    want = int(want or 0)
    return min(want, num_shards) if want > 0 else 0


def _merge_cache_snaps(snaps: list) -> dict:
    """Per-shard BlockCache snapshots -> one fleet rollup."""
    hits = sum(s["hits"] for s in snaps)
    misses = sum(s["misses"] for s in snaps)
    by_class: dict = {}
    for s in snaps:
        for cls, d in s["by_class"].items():
            agg = by_class.setdefault(cls, {"hits": 0, "misses": 0})
            agg["hits"] += d["hits"]
            agg["misses"] += d["misses"]
    for d in by_class.values():
        tot = d["hits"] + d["misses"]
        d["hit_rate"] = d["hits"] / tot if tot else 0.0
    return {"hits": hits, "misses": misses,
            "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
            "by_class": by_class,
            "per_shard": snaps}


class Engine:
    """Sharded, batched execution of point AND range ops.

    Public surface (all batch results come back in request order):

      submit(OpBatch) -> PendingBatch          plan + launch, collect later
      put_batch / delete_batch / get_batch     vectorized point ops
      put / delete / get                       scalar conveniences
      range_scan_batch / range_scan            sorted live entries per range
      range_delete_batch / range_delete        strategy-dispatched deletes
      execute(ops)                             one mixed tuple op stream
      drain()                                  join all in-flight batches
      stats() / cache_snapshot()               per-op-class rollups

    Pipelining: with ``EngineConfig.pipeline`` on (the default; env
    ``REPRO_ENGINE_PIPELINE=0`` forces it off) and more than one shard,
    each shard executes its plan on a dedicated single-worker pool —
    shards run concurrently, every shard sees its batches in submit
    order, and ``submit`` returns before execution finishes so the
    caller can plan batch n+1 while batch n executes.  ``pipeline=False``
    runs the identical plans inline in shard order; results are
    byte-identical either way.

    Range ops route like point ops: range-partitioned shards serve only
    the overlapping slabs (clipped), hash-partitioned shards fan out and
    the per-shard results — disjoint because every key owns exactly one
    shard — are merged back into one sorted view per request.
    """

    def __init__(self, num_shards: int = 1, strategy: str = "gloran",
                 lsm_config: LSMConfig | None = None,
                 gloran_config: GloranConfig | None = None,
                 config: EngineConfig | None = None,
                 _recover_from: str | None = None):
        self.config = config or EngineConfig()
        self.num_shards = int(num_shards)
        self.strategy = strategy
        base = lsm_config or LSMConfig()
        self.lsm_config = base
        self.gloran_config = gloran_config
        # The gloran config the shards actually run (GloranIndex
        # defaults None to GloranConfig()); the manifest's config doc
        # serializes THIS so recovery rebuilds identically.
        self._gloran_eff = ((gloran_config or GloranConfig())
                            if strategy == "gloran" else None)
        self.router = ShardRouter(self.num_shards,
                                  partition=self.config.partition,
                                  universe=base.key_universe)
        self.planner = Planner(self.router)
        # Per-shard home XLA devices (None = single-device legacy path):
        # each shard's registry packs and kernel launches live on its
        # device, so pipelined shard workers stop serializing on the
        # default device.
        self.devices = _resolve_devices(self.config, self.num_shards)
        # Background delete-aware compaction (lsm/scheduler.py):
        # ``EngineConfig.scheduler`` wins; None defers to
        # REPRO_ENGINE_BG_COMPACT; unset/0 = off (the inline flush
        # path, byte-identical to the pre-scheduler engine).
        sched = self.config.scheduler
        if sched is None:
            env = os.environ.get("REPRO_ENGINE_BG_COMPACT", "").strip()
            sched = bool(env) and env != "0"
        self.background = bool(sched)
        # A directory that already holds acknowledged frames is refused
        # — recovery must fold them in first, or acked writes would be
        # silently orphaned.  (``_recover_from`` is that fold-in:
        # ``repro.durable.recover`` passes it in procs mode so each
        # worker replays its own stream before serving.)
        if self.config.wal_dir and not _recover_from:
            from ..durable.wal import wal_has_frames
            if wal_has_frames(self.config.wal_dir):
                raise RuntimeError(
                    f"WAL at {self.config.wal_dir} holds acknowledged "
                    "frames; open it with repro.durable.recover() "
                    "instead of a fresh Engine")
        # Process-parallel shard execution (engine/procpool.py):
        # ``EngineConfig.procs`` / REPRO_ENGINE_PROCS; 0 = in-process.
        self.procs = _resolve_procs(self.config, self.num_shards)
        self._proc_pool = None
        if _recover_from and not self.procs:
            raise RuntimeError("_recover_from is the procs-mode "
                               "recovery path; use durable.recover()")
        if self.procs:
            from .procpool import ProcPool
            if self.devices is not None:
                import jax
                device_ids = [d.id for d in self.devices]
                host_devices = len(jax.devices())
            else:
                device_ids = [None] * self.num_shards
                host_devices = 1
            self._proc_pool = ProcPool(
                num_shards=self.num_shards, procs=self.procs,
                strategy=strategy, lsm_config=base,
                gloran_config=gloran_config, config=self.config,
                background=self.background, device_ids=device_ids,
                host_devices=host_devices,
                wal_dir=self.config.wal_dir or _recover_from,
                replay=bool(_recover_from))
            self.shards = self._proc_pool.shards
        else:
            self.shards = []
            for s in range(self.num_shards):
                tree = LSMTree(base, strategy=strategy,
                               gloran_config=gloran_config)
                dev = (self.devices[s] if self.devices is not None
                       else None)
                self.shards.append(ShardExecutor(tree, self.config,
                                                 device=dev))
            if self.background:
                for sh in self.shards:
                    sh.attach_scheduler(CompactionScheduler(
                        sh.tree, max_frozen=self.config.max_frozen,
                        tombstone_trigger=self.config.tombstone_trigger))
        self.stats_ = EngineStats()
        self.metrics = MetricsRegistry()
        pl = self.config.pipeline
        if pl is None:
            pl = os.environ.get("REPRO_ENGINE_PIPELINE", "1") != "0"
        self.pipeline_default = bool(pl)
        self._pools: list[ThreadPoolExecutor] | None = None
        self._inflight: list[PendingBatch] = []
        self._inflight_lock = threading.Lock()
        # Durability (repro.durable): a configured wal_dir attaches a
        # per-shard WAL stream + the level manifest.  In procs mode the
        # WAL writers live INSIDE the workers (append-before-ack holds
        # within each worker's run_plan); the parent owns the manifest,
        # applying structure edits shipped back with each reply.
        self.wal_dir: str | None = None
        self.manifest = None
        self.recovery = {"wall_s": 0.0, "frames_replayed": 0,
                         "snapshot_loaded": 0}
        if self.procs:
            d = self.config.wal_dir or _recover_from
            if d:
                self._attach_proc_durability(
                    d, recovered=bool(_recover_from))
        elif self.config.wal_dir:
            self._attach_durability(self.config.wal_dir)

    def _attach_proc_durability(self, wal_dir: str, *,
                                recovered: bool) -> None:
        """Procs-mode durability wiring: manifest in the parent, WAL
        writers in the workers (already attached by ProcPool)."""
        from ..durable.manifest import LevelManifest, engine_config_doc
        self.wal_dir = wal_dir
        if recovered:
            manifest = LevelManifest.load(os.path.join(wal_dir,
                                                       "manifest"))
        else:
            manifest = LevelManifest(
                os.path.join(wal_dir, "manifest"),
                config=engine_config_doc(self), fsync=False)
            manifest.commit(fsync=self.config.fsync != "never")
        self.manifest = manifest
        for sh in self.shards:
            sh.manifest = manifest
        if recovered:
            for s, desc in sorted(
                    self._proc_pool.recovered_descs.items()):
                manifest.record_structure_desc(s, desc, reason="recover")
            self.recovery["frames_replayed"] = \
                self._proc_pool.frames_replayed

    def _attach_durability(self, wal_dir: str, *, manifest=None,
                           writers: list | None = None) -> None:
        """Wire WAL writers + manifest into every shard.  Called from
        ``__init__`` for a fresh store and from ``repro.durable.recover``
        after replay (which passes the loaded manifest and writers
        positioned at the durable tail)."""
        from ..durable.manifest import LevelManifest, engine_config_doc
        from ..durable.wal import WalWriter
        self.wal_dir = wal_dir
        if manifest is None:
            # Routine structure commits skip fsync (not load-bearing —
            # recovery replays the WAL); the initial commit carries the
            # config doc recovery rebuilds the engine from, so THAT one
            # is made durable explicitly.
            manifest = LevelManifest(
                os.path.join(wal_dir, "manifest"),
                config=engine_config_doc(self), fsync=False)
            manifest.commit(fsync=self.config.fsync != "never")
        self.manifest = manifest
        for s, sh in enumerate(self.shards):
            w = (writers[s] if writers is not None else
                 WalWriter(wal_dir, s,
                           segment_bytes=self.config.wal_segment_bytes,
                           fsync=self.config.fsync))
            sh.attach_durability(w, manifest, s)

    # -------------------------------------------------- submit / collect
    def submit(self, batch: OpBatch, *,
               pipeline: bool | None = None) -> PendingBatch:
        """Plan and launch a typed op batch; collect via the handle.

        ``pipeline=None`` uses the engine default.  Pipelined submits
        return immediately (execution proceeds on the shard pools);
        serial submits execute inline before returning, after draining
        any in-flight pipelined work so the per-shard op order stays the
        submit order.
        """
        if pipeline is None:
            pipeline = self.pipeline_default
        pipeline = bool(pipeline) and self.num_shards > 1
        with span("engine.submit", kind=batch.kind_name, n_ops=len(batch),
                  pipelined=pipeline):
            plan = self.planner.plan(batch)
            if not pipeline:
                # Serialize with in-flight pipelined work, execute
                # inline, and collect immediately so a dropped handle
                # still lands in stats (wait() is idempotent for later
                # accessors).
                self.drain()
                pending = PendingBatch(self, plan, pipeline=False)
                pending._start()
                return pending.wait()
            pending = PendingBatch(self, plan, pipeline=True)
            # Launch before publishing: a concurrent drain()/stats()
            # must never collect a handle whose shard plans haven't
            # started.
            pending._start()
            with self._inflight_lock:
                self._inflight.append(pending)
            return pending

    def drain(self) -> None:
        """Block until every in-flight submitted batch has collected,
        then run any due background scheduler jobs — a drained engine
        is fully caught up (flushes published, cascades applied),
        exactly the state the inline path would be in."""
        while True:
            with self._inflight_lock:
                if not self._inflight:
                    break
                pending = self._inflight[0]
            pending.wait()
        if self.background:
            for sh in self.shards:
                sh.run_scheduler("drain")

    def _shard_pools(self) -> list[ThreadPoolExecutor]:
        """One single-worker pool per shard: cross-shard parallelism with
        per-shard FIFO (a later batch never overtakes an earlier one on
        the same shard — all ordering correctness needs)."""
        if self._pools is None:
            self._pools = [
                ThreadPoolExecutor(max_workers=1,
                                   thread_name_prefix=f"shard-{s}")
                for s in range(self.num_shards)]
        return self._pools

    def _finish_batch(self, pending: PendingBatch) -> None:
        """Merge-back bookkeeping: roll one collected batch into stats.

        With overlapping in-flight batches the engine-wide I/O delta is
        attributed to whichever batch collects it first — per-op-class
        I/O stays exact for the blocking wrappers and approximate under
        concurrent ``submit`` streams.
        """
        batch = pending.plan.batch
        reads, writes = self._io_marks()
        self.stats_.record(
            batch.kind_name, len(batch),
            time.perf_counter() - pending._t0,
            io_reads=reads - pending._io0[0],
            io_writes=writes - pending._io0[1])
        self.stats_.record_shards(pending._walls, pending.pipeline)
        with self._inflight_lock:
            if pending in self._inflight:
                self._inflight.remove(pending)

    def _io_marks(self) -> tuple[int, int]:
        return self.io_reads, self.io_writes

    # ------------------------------------------------------------ writes
    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert a batch of (key, val) pairs (split across shards)."""
        self.submit(OpBatch.puts(keys, vals)).wait()

    def put(self, key: int, val: int) -> None:
        """Scalar insert (a one-element ``put_batch``)."""
        self.put_batch(np.asarray([key], np.uint64),
                       np.asarray([val], np.uint64))

    def delete_batch(self, keys: np.ndarray) -> None:
        """Point-delete a batch of keys (split across shards)."""
        self.submit(OpBatch.deletes(keys)).wait()

    def delete(self, key: int) -> None:
        """Scalar point delete (a one-element ``delete_batch``)."""
        self.delete_batch(np.asarray([key], np.uint64))

    def range_delete(self, lo: int, hi: int) -> None:
        """Delete all keys in [lo, hi) on every owning shard."""
        self.range_delete_batch([(lo, hi)])

    def range_delete_batch(self, ranges) -> None:
        """Apply a batch of [lo, hi) range deletes.

        Each range is routed like any range op — clipped to overlapping
        slabs under range partitioning, broadcast under hash — and every
        shard applies its visits in request order, so a later op in the
        batch shadows an earlier one exactly as sequential calls would.
        """
        self.submit(OpBatch.range_deletes(ranges)).wait()

    def flush(self) -> None:
        """Flush every shard's memtable to its level 0 (drains first).
        Durable shards log a FLUSH marker + manifest edit each."""
        self.drain()
        for sh in self.shards:
            sh.flush()

    def close(self) -> None:
        """Deterministic shutdown (idempotent): drain in-flight batches,
        join the per-shard worker pools, and flush + fsync + close every
        WAL stream — tests and benches never leak worker threads or
        half-written segments."""
        self.drain()
        if self._pools is not None:
            for p in self._pools:
                p.shutdown(wait=True)
            self._pools = None
        if self._proc_pool is not None:
            self._proc_pool.close()
        else:
            for sh in self.shards:
                sh.close()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- reads
    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized point lookups; (found mask, values) in request
        order, merged back from the per-shard batched read paths."""
        return self.submit(OpBatch.gets(keys)).get_results()

    def get(self, key: int):
        """Scalar point lookup; the value or None."""
        found, vals = self.get_batch(np.asarray([key], np.uint64))
        return int(vals[0]) if found[0] else None

    def range_scan(self, lo: int, hi: int):
        """All live entries in [lo, hi) across shards, sorted by key."""
        return self.range_scan_batch([(lo, hi)])[0]

    def range_scan_batch(self, ranges) -> list:
        """Execute a batch of range scans; one sorted (keys, vals) pair
        per requested [lo, hi), in request order.

        Each shard serves its clipped visits in ONE pass over its tree
        (``LSMTree.range_scan_batch``: shared memtable snapshot,
        vectorized slice bounds, sorted-view merges, batched validity
        filtering through the Pallas hooks).  Per-request results from
        range-partitioned shards concatenate in slab order (already
        globally sorted); hash-partitioned shards return disjoint sorted
        sets that are merged as sorted views.
        """
        return self.submit(OpBatch.range_scans(ranges)).scan_results()

    def _merge_scan_parts(self, parts: list) -> tuple[np.ndarray,
                                                      np.ndarray]:
        """One request's per-shard (keys, vals) parts -> one sorted pair.

        Shards are visited in ascending order, so under range
        partitioning the parts are consecutive key slabs and concatenate
        sorted; under hash partitioning each key lives on exactly one
        shard, so the parts are disjoint sorted sets and a sorted-view
        merge (no re-sort) is exact.
        """
        if not parts:
            return _EMPTY_KV
        if len(parts) == 1:
            return parts[0]
        if self.router.partition == "range":
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))
        return merge_runs(parts, empty=_EMPTY_KV)

    # --------------------------------------------------------- mixed ops
    def execute(self, ops: list[tuple]) -> list:
        """Execute a mixed tuple op stream; results align with request
        order (the legacy surface — ``OpBatch.from_ops`` + ``submit``).

        ``ops`` entries: ``("put", key, val)``, ``("delete", key)``,
        ``("get", key)``, ``("range_delete", lo, hi)``,
        ``("range_scan", lo, hi)``.  Returns one slot per op: gets yield
        value-or-None, range scans yield a sorted (keys, vals) pair,
        writes yield None.  Consecutive same-kind ops destined for the
        same shard execute as one vectorized sub-batch; per-shard arrival
        order (all that matters — a key's history lives on one shard) is
        preserved.  Range ops visit every owning shard; a scan's
        per-shard parts are merged back into one sorted view.
        """
        return self.submit(OpBatch.from_ops(ops)).results()

    # -------------------------------------------------------------- misc
    @property
    def io_reads(self) -> int:
        return sum(sh.io_reads for sh in self.shards)

    @property
    def io_writes(self) -> int:
        return sum(sh.io_writes for sh in self.shards)

    @property
    def num_entries(self) -> int:
        return sum(sh.num_entries for sh in self.shards)

    @property
    def kernel_counters(self) -> KernelCounters:
        out = KernelCounters()
        for sh in self.shards:
            out.merge(sh.kernels)
        return out

    def device_map(self) -> dict:
        """shard id -> home device string ("host" when unpinned)."""
        if self.devices is None:
            return {s: "host" for s in range(self.num_shards)}
        return {s: f"{d.platform}:{d.id}"
                for s, d in enumerate(self.devices)}

    def cache_snapshot(self) -> dict:
        return _merge_cache_snaps([sh.cache_snapshot()
                                   for sh in self.shards])

    def reset_stats(self) -> None:
        """Start a fresh stats window: drain in-flight work, then zero
        the engine rollups (op counts, walls, I/O attribution, latency
        histograms) and the unified metrics snapshot.  The shard-local
        cumulative ledgers (IOStats, kernel counters, cache hit totals)
        keep running — windowed deltas of those belong to the caller."""
        self.drain()
        self.stats_.reset()
        self.metrics.reset()

    def stats(self) -> dict:
        self.drain()
        # ONE per-shard ledger document each — in-process executors
        # read their tree directly, proc shards round-trip a STATS
        # message to their worker.  Everything below aggregates these
        # documents only, so both modes share one code path, and the
        # values are cumulative snapshots: calling stats() twice
        # without intervening work returns identical numbers.
        fulls = [sh.stats_full() for sh in self.shards]
        staging = [{"shard": s, **f["staging"]}
                   for s, f in enumerate(fulls)
                   if f["staging"] is not None]
        if staging:
            self.stats_.record_staging(staging)
        kern = KernelCounters()
        for f in fulls:
            kern.merge(KernelCounters.from_snapshot(f["kernels"]))
        out = {
            "num_shards": self.num_shards,
            "partition": self.router.partition,
            "pipeline": self.pipeline_default,
            "procs": self.procs,
            "devices": {
                "enabled": self.devices is not None,
                "distinct": len(set(self.device_map().values())),
                "per_shard": self.device_map(),
            },
            "entries": sum(f["entries"] for f in fulls),
            "engine": self.stats_.snapshot(),
            "io": merge_io_snapshots([f["io"] for f in fulls]),
            "cache": _merge_cache_snaps([f["cache"] for f in fulls]),
            "kernels": kern.snapshot(),
        }
        # One namespaced flat schema absorbing every subsystem ledger
        # (kernels, I/O, cache incl. per-op-class, staging occupancy,
        # engine batch counters) — the dashboard/alerting surface.
        m = self.metrics
        m.absorb("kernels", out["kernels"])
        m.absorb("io", {k: v for k, v in out["io"].items()
                        if k != "by_tag"})
        m.absorb("io.by_tag", out["io"]["by_tag"])
        m.absorb("cache", {k: out["cache"][k]
                           for k in ("hits", "misses", "hit_rate")})
        m.absorb("cache.by_class", out["cache"]["by_class"])
        m.absorb("engine", {
            "pipelined_batches": self.stats_.pipelined_batches,
            "serial_batches": self.stats_.serial_batches,
            "entries": out["entries"],
            "num_shards": self.num_shards,
            "devices": out["devices"]["distinct"]})
        if self.stats_.staging:
            m.absorb("staging", {k: v for k, v in
                                 self.stats_.staging.items()
                                 if k != "per_shard"})
        # Background-scheduler health: job/stall counters + compaction
        # debt across the fleet (``sched.*`` metrics).
        scheds = [f["sched"] for f in fulls if f["sched"] is not None]
        if self.background and scheds:
            agg2: dict = {}
            for c in scheds:
                for k, v in c.items():
                    agg2[k] = agg2.get(k, 0) + v
            agg2["stall_seconds"] = round(agg2["stall_seconds"], 6)
            out["sched"] = agg2
            m.absorb("sched", agg2)
        # Per-level compaction observability: bytes moved compacting
        # into each level (+ range-tombstone rewrites) and the
        # estimated range-tombstone density — the scheduler's priority
        # inputs, inspectable whether or not background mode is on.
        lsm_m: dict = {}
        for f in fulls:
            for i, b in f["lsm"]["compaction_bytes"].items():
                k = f"compaction.bytes.L{i}"
                lsm_m[k] = lsm_m.get(k, 0) + b
            for i, b in f["lsm"]["rt_compaction_bytes"].items():
                k = f"rt_compaction.bytes.L{i}"
                lsm_m[k] = lsm_m.get(k, 0) + b
        for i in range(max((f["lsm"]["num_levels"] for f in fulls),
                           default=0)):
            dens = [f["lsm"]["rt_density"][i] for f in fulls
                    if i < f["lsm"]["num_levels"]]
            if dens:
                lsm_m[f"rt_density.L{i}"] = round(max(dens), 4)
        if lsm_m:
            out["lsm"] = lsm_m
            m.absorb("lsm", lsm_m)
        wals = [f["wal"] for f in fulls if f["wal"] is not None]
        if wals:
            agg: dict = {}
            for c in wals:
                for k, v in c.items():
                    agg[k] = agg.get(k, 0) + v
            out["wal"] = agg
            m.absorb("wal", agg)
        # Shared-memory transport ledger (procs mode): bytes shipped
        # each way + the enqueue->dequeue latency histogram.
        if self._proc_pool is not None:
            t = self._proc_pool.transport_snapshot()
            out["proc"] = t
            m.absorb("proc", {k: v for k, v in t.items()
                              if k != "dequeue_latency_us"})
            m.absorb("proc.dequeue_latency_us",
                     t["dequeue_latency_us"])
        m.absorb("recovery", self.recovery)
        out["metrics"] = m.snapshot()
        return out
