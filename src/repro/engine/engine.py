"""Sharded batched query engine fronting N LSM-tree shards.

The serving tier's execution layer: a ``ShardRouter`` partitions batches
of operations across hash- or range-partitioned ``LSMTree`` shards, each
shard runs its ``ShardExecutor`` batched read path (Bloom + interval
Pallas kernels, block cache), and results are merged back in request
order.  ``num_shards=1`` degenerates to a single tree with the batched
path — the drop-in replacement for calling the tree directly.
"""

from __future__ import annotations

import time

import numpy as np

from ..core.gloran import GloranConfig
from ..lsm import LSMConfig, LSMTree
from ..lsm.merge import merge_runs
from .executor import EngineConfig, ShardExecutor
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots

_EMPTY_KV = (np.zeros(0, np.uint64), np.zeros(0, np.uint64))


class Engine:
    """Sharded, batched execution of point AND range ops.

    Public surface (all batch results come back in request order):

      put_batch / delete_batch / get_batch    vectorized point ops
      put / delete / get                      scalar conveniences
      range_scan_batch / range_scan           sorted live entries per range
      range_delete_batch / range_delete       strategy-dispatched deletes
      execute(ops)                            one mixed op stream
      stats() / cache_snapshot()              per-op-class rollups

    Range ops route like point ops: range-partitioned shards serve only
    the overlapping slabs (clipped), hash-partitioned shards fan out and
    the per-shard results — disjoint because every key owns exactly one
    shard — are merged back into one sorted view per request.
    """

    def __init__(self, num_shards: int = 1, strategy: str = "gloran",
                 lsm_config: LSMConfig | None = None,
                 gloran_config: GloranConfig | None = None,
                 config: EngineConfig | None = None):
        self.config = config or EngineConfig()
        self.num_shards = int(num_shards)
        base = lsm_config or LSMConfig()
        self.router = ShardRouter(self.num_shards,
                                  partition=self.config.partition,
                                  universe=base.key_universe)
        self.shards = []
        for _ in range(self.num_shards):
            tree = LSMTree(base, strategy=strategy,
                           gloran_config=gloran_config)
            self.shards.append(ShardExecutor(tree, self.config))
        self.stats_ = EngineStats()

    def _io_marks(self) -> tuple[int, int]:
        return self.io_reads, self.io_writes

    def _record(self, op: str, n: int, t0: float,
                marks: tuple[int, int]) -> None:
        """Roll wall time + the I/O charged since ``marks`` into stats."""
        self.stats_.record(op, n, time.perf_counter() - t0,
                           io_reads=self.io_reads - marks[0],
                           io_writes=self.io_writes - marks[1])

    # ------------------------------------------------------------ writes
    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert a batch of (key, val) pairs (split across shards)."""
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.uint64)
        t0, io0 = time.perf_counter(), self._io_marks()
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx):
                self.shards[s].put_batch(keys[idx], vals[idx])
        self._record("put", len(keys), t0, io0)

    def put(self, key: int, val: int) -> None:
        """Scalar insert (a one-element ``put_batch``)."""
        self.put_batch(np.asarray([key], np.uint64),
                       np.asarray([val], np.uint64))

    def delete_batch(self, keys: np.ndarray) -> None:
        """Point-delete a batch of keys (split across shards)."""
        keys = np.asarray(keys, dtype=np.uint64)
        t0, io0 = time.perf_counter(), self._io_marks()
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx):
                self.shards[s].delete_batch(keys[idx])
        self._record("delete", len(keys), t0, io0)

    def delete(self, key: int) -> None:
        """Scalar point delete (a one-element ``delete_batch``)."""
        self.delete_batch(np.asarray([key], np.uint64))

    def range_delete(self, lo: int, hi: int) -> None:
        """Delete all keys in [lo, hi) on every owning shard."""
        self.range_delete_batch([(lo, hi)])

    def range_delete_batch(self, ranges) -> None:
        """Apply a batch of [lo, hi) range deletes.

        Each range is routed like any range op — clipped to overlapping
        slabs under range partitioning, broadcast under hash — and every
        shard applies its visits in request order, so a later op in the
        batch shadows an earlier one exactly as sequential calls would.
        """
        t0, io0 = time.perf_counter(), self._io_marks()
        for s, visits in enumerate(self.router.split_ranges(ranges)):
            if visits:
                self.shards[s].range_delete_batch(
                    [(lo, hi) for _, lo, hi in visits])
        self._record("range_delete", len(ranges), t0, io0)

    def flush(self) -> None:
        """Flush every shard's memtable to its level 0."""
        for sh in self.shards:
            sh.flush()

    # ------------------------------------------------------------- reads
    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized point lookups; (found mask, values) in request
        order, merged back from the per-shard batched read paths."""
        keys = np.asarray(keys, dtype=np.uint64)
        t0, io0 = time.perf_counter(), self._io_marks()
        found = np.zeros(len(keys), dtype=bool)
        vals = np.zeros(len(keys), dtype=np.uint64)
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx) == 0:
                continue
            f, v = self.shards[s].get_batch(keys[idx])
            found[idx] = f
            vals[idx] = v
        self._record("get", len(keys), t0, io0)
        return found, vals

    def get(self, key: int):
        """Scalar point lookup; the value or None."""
        found, vals = self.get_batch(np.asarray([key], np.uint64))
        return int(vals[0]) if found[0] else None

    def range_scan(self, lo: int, hi: int):
        """All live entries in [lo, hi) across shards, sorted by key."""
        return self.range_scan_batch([(lo, hi)])[0]

    def range_scan_batch(self, ranges) -> list:
        """Execute a batch of range scans; one sorted (keys, vals) pair
        per requested [lo, hi), in request order.

        Each shard serves its clipped visits in ONE pass over its tree
        (``LSMTree.range_scan_batch``: shared memtable snapshot,
        vectorized slice bounds, sorted-view merges, batched validity
        filtering through the Pallas hooks).  Per-request results from
        range-partitioned shards concatenate in slab order (already
        globally sorted); hash-partitioned shards return disjoint sorted
        sets that are merged as sorted views.
        """
        t0, io0 = time.perf_counter(), self._io_marks()
        parts: list[list] = [[] for _ in ranges]
        for s, visits in enumerate(self.router.split_ranges(ranges)):
            if not visits:
                continue
            res = self.shards[s].range_scan_batch(
                [(lo, hi) for _, lo, hi in visits])
            for (rid, _, _), kv in zip(visits, res):
                parts[rid].append(kv)
        out = [self._merge_scan_parts(ps) for ps in parts]
        self._record("range_scan", len(ranges), t0, io0)
        return out

    def _merge_scan_parts(self, parts: list) -> tuple[np.ndarray,
                                                      np.ndarray]:
        """One request's per-shard (keys, vals) parts -> one sorted pair.

        Shards are visited in ascending order, so under range
        partitioning the parts are consecutive key slabs and concatenate
        sorted; under hash partitioning each key lives on exactly one
        shard, so the parts are disjoint sorted sets and a sorted-view
        merge (no re-sort) is exact.
        """
        if not parts:
            return _EMPTY_KV
        if len(parts) == 1:
            return parts[0]
        if self.router.partition == "range":
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))
        return merge_runs(parts, empty=_EMPTY_KV)

    # --------------------------------------------------------- mixed ops
    def execute(self, ops: list[tuple]) -> list:
        """Execute a mixed op batch; results align with request order.

        ``ops`` entries: ``("put", key, val)``, ``("delete", key)``,
        ``("get", key)``, ``("range_delete", lo, hi)``,
        ``("range_scan", lo, hi)``.  Returns one slot per op: gets yield
        value-or-None, range scans yield a sorted (keys, vals) pair,
        writes yield None.  Consecutive same-kind ops destined for the
        same shard execute as one vectorized sub-batch; per-shard arrival
        order (all that matters — a key's history lives on one shard) is
        preserved.  Range ops visit every owning shard; a scan's
        per-shard parts are merged back into one sorted view.
        """
        results: list = [None] * len(ops)
        scan_parts: dict[int, list] = {}
        per_shard: list[list[tuple]] = [[] for _ in range(self.num_shards)]
        for i, op in enumerate(ops):
            kind = op[0]
            if kind in ("put", "delete", "get"):
                per_shard[self.router.shard_of_scalar(op[1])].append(
                    (i, op))
            elif kind in ("range_delete", "range_scan"):
                if kind == "range_scan":
                    scan_parts[i] = []
                for s, lo, hi in self.router.shards_for_range(op[1], op[2]):
                    per_shard[s].append((i, (kind, lo, hi)))
            else:
                raise ValueError(f"unknown op kind: {kind!r}")
        t0, io0 = time.perf_counter(), self._io_marks()
        for s, stream in enumerate(per_shard):
            sh = self.shards[s]
            j = 0
            while j < len(stream):
                kind = stream[j][1][0]
                k = j
                while k < len(stream) and stream[k][1][0] == kind:
                    k += 1
                group = stream[j:k]
                if kind == "put":
                    sh.put_batch(
                        np.asarray([g[1][1] for g in group], np.uint64),
                        np.asarray([g[1][2] for g in group], np.uint64))
                elif kind == "delete":
                    sh.delete_batch(
                        np.asarray([g[1][1] for g in group], np.uint64))
                elif kind == "get":
                    f, v = sh.get_batch(
                        np.asarray([g[1][1] for g in group], np.uint64))
                    for (i, _), fi, vi in zip(group, f.tolist(), v.tolist()):
                        results[i] = vi if fi else None
                elif kind == "range_scan":
                    res = sh.range_scan_batch(
                        [(lo, hi) for _, (_, lo, hi) in group])
                    for (i, _), kv in zip(group, res):
                        scan_parts[i].append(kv)
                else:  # range_delete (already clipped per shard)
                    sh.range_delete_batch(
                        [(lo, hi) for _, (_, lo, hi) in group])
                j = k
        for i, ps in scan_parts.items():
            results[i] = self._merge_scan_parts(ps)
        self._record("mixed", len(ops), t0, io0)
        return results

    # -------------------------------------------------------------- misc
    @property
    def io_reads(self) -> int:
        return sum(sh.tree.io.reads for sh in self.shards)

    @property
    def io_writes(self) -> int:
        return sum(sh.tree.io.writes for sh in self.shards)

    @property
    def num_entries(self) -> int:
        return sum(sh.tree.num_entries for sh in self.shards)

    @property
    def kernel_counters(self) -> KernelCounters:
        return KernelCounters(
            sum(sh.kernels.interval_calls for sh in self.shards),
            sum(sh.kernels.interval_queries for sh in self.shards),
            sum(sh.kernels.bloom_calls for sh in self.shards),
            sum(sh.kernels.bloom_queries for sh in self.shards))

    def cache_snapshot(self) -> dict:
        snaps = [sh.cache.snapshot() for sh in self.shards]
        hits = sum(s["hits"] for s in snaps)
        misses = sum(s["misses"] for s in snaps)
        return {"hits": hits, "misses": misses,
                "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
                "per_shard": snaps}

    def stats(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "partition": self.router.partition,
            "entries": self.num_entries,
            "engine": self.stats_.snapshot(),
            "io": merge_io_snapshots(
                [sh.tree.io.snapshot() for sh in self.shards]),
            "cache": self.cache_snapshot(),
            "kernels": self.kernel_counters.snapshot(),
        }
