"""Sharded batched query engine fronting N LSM-tree shards.

The serving tier's execution layer: a ``ShardRouter`` partitions batches
of operations across hash- or range-partitioned ``LSMTree`` shards, each
shard runs its ``ShardExecutor`` batched read path (Bloom + interval
Pallas kernels, block cache), and results are merged back in request
order.  ``num_shards=1`` degenerates to a single tree with the batched
path — the drop-in replacement for calling the tree directly.
"""

from __future__ import annotations

import time

import numpy as np

from ..core.gloran import GloranConfig
from ..lsm import LSMConfig, LSMTree
from .executor import EngineConfig, ShardExecutor
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots


class Engine:
    def __init__(self, num_shards: int = 1, strategy: str = "gloran",
                 lsm_config: LSMConfig | None = None,
                 gloran_config: GloranConfig | None = None,
                 config: EngineConfig | None = None):
        self.config = config or EngineConfig()
        self.num_shards = int(num_shards)
        base = lsm_config or LSMConfig()
        self.router = ShardRouter(self.num_shards,
                                  partition=self.config.partition,
                                  universe=base.key_universe)
        self.shards = []
        for _ in range(self.num_shards):
            tree = LSMTree(base, strategy=strategy,
                           gloran_config=gloran_config)
            self.shards.append(ShardExecutor(tree, self.config))
        self.stats_ = EngineStats()

    # ------------------------------------------------------------ writes
    def put_batch(self, keys: np.ndarray, vals: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.uint64)
        t0 = time.perf_counter()
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx):
                self.shards[s].put_batch(keys[idx], vals[idx])
        self.stats_.record("put", len(keys), time.perf_counter() - t0)

    def put(self, key: int, val: int) -> None:
        self.put_batch(np.asarray([key], np.uint64),
                       np.asarray([val], np.uint64))

    def delete_batch(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64)
        t0 = time.perf_counter()
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx):
                self.shards[s].delete_batch(keys[idx])
        self.stats_.record("delete", len(keys), time.perf_counter() - t0)

    def delete(self, key: int) -> None:
        self.delete_batch(np.asarray([key], np.uint64))

    def range_delete(self, lo: int, hi: int) -> None:
        t0 = time.perf_counter()
        for s, c_lo, c_hi in self.router.shards_for_range(lo, hi):
            self.shards[s].range_delete(c_lo, c_hi)
        self.stats_.record("range_delete", 1, time.perf_counter() - t0)

    def flush(self) -> None:
        for sh in self.shards:
            sh.flush()

    # ------------------------------------------------------------- reads
    def get_batch(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized point lookups; results in request order."""
        keys = np.asarray(keys, dtype=np.uint64)
        t0 = time.perf_counter()
        found = np.zeros(len(keys), dtype=bool)
        vals = np.zeros(len(keys), dtype=np.uint64)
        for s, idx in enumerate(self.router.split(keys)):
            if len(idx) == 0:
                continue
            f, v = self.shards[s].get_batch(keys[idx])
            found[idx] = f
            vals[idx] = v
        self.stats_.record("get", len(keys), time.perf_counter() - t0)
        return found, vals

    def get(self, key: int):
        found, vals = self.get_batch(np.asarray([key], np.uint64))
        return int(vals[0]) if found[0] else None

    def range_scan(self, lo: int, hi: int):
        """All live entries in [lo, hi) across shards, sorted by key."""
        t0 = time.perf_counter()
        parts = [self.shards[s].range_scan(c_lo, c_hi)
                 for s, c_lo, c_hi in self.router.shards_for_range(lo, hi)]
        keys = np.concatenate([p[0] for p in parts]) if parts else \
            np.zeros(0, np.uint64)
        vals = np.concatenate([p[1] for p in parts]) if parts else \
            np.zeros(0, np.uint64)
        order = np.argsort(keys, kind="stable")
        self.stats_.record("range_scan", 1, time.perf_counter() - t0)
        return keys[order], vals[order]

    # --------------------------------------------------------- mixed ops
    def execute(self, ops: list[tuple]) -> list:
        """Execute a mixed op batch; results align with request order.

        ``ops`` entries: ``("put", key, val)``, ``("delete", key)``,
        ``("get", key)``, ``("range_delete", lo, hi)``.  Returns one slot
        per op: gets yield value-or-None, writes yield None.  Consecutive
        same-kind ops destined for the same shard execute as one
        vectorized sub-batch; per-shard arrival order (all that matters —
        a key's history lives on one shard) is preserved.
        """
        results: list = [None] * len(ops)
        per_shard: list[list[tuple]] = [[] for _ in range(self.num_shards)]
        for i, op in enumerate(ops):
            kind = op[0]
            if kind in ("put", "delete", "get"):
                per_shard[self.router.shard_of_scalar(op[1])].append(
                    (i, op))
            elif kind == "range_delete":
                for s, lo, hi in self.router.shards_for_range(op[1], op[2]):
                    per_shard[s].append((i, ("range_delete", lo, hi)))
            else:
                raise ValueError(f"unknown op kind: {kind!r}")
        t0 = time.perf_counter()
        for s, stream in enumerate(per_shard):
            sh = self.shards[s]
            j = 0
            while j < len(stream):
                kind = stream[j][1][0]
                k = j
                while k < len(stream) and stream[k][1][0] == kind:
                    k += 1
                group = stream[j:k]
                if kind == "put":
                    sh.put_batch(
                        np.asarray([g[1][1] for g in group], np.uint64),
                        np.asarray([g[1][2] for g in group], np.uint64))
                elif kind == "delete":
                    sh.delete_batch(
                        np.asarray([g[1][1] for g in group], np.uint64))
                elif kind == "get":
                    f, v = sh.get_batch(
                        np.asarray([g[1][1] for g in group], np.uint64))
                    for (i, _), fi, vi in zip(group, f.tolist(), v.tolist()):
                        results[i] = vi if fi else None
                else:  # range_delete (already clipped per shard)
                    for _, (_, lo, hi) in group:
                        sh.range_delete(lo, hi)
                j = k
        self.stats_.record("mixed", len(ops), time.perf_counter() - t0)
        return results

    # -------------------------------------------------------------- misc
    @property
    def io_reads(self) -> int:
        return sum(sh.tree.io.reads for sh in self.shards)

    @property
    def io_writes(self) -> int:
        return sum(sh.tree.io.writes for sh in self.shards)

    @property
    def num_entries(self) -> int:
        return sum(sh.tree.num_entries for sh in self.shards)

    @property
    def kernel_counters(self) -> KernelCounters:
        return KernelCounters(
            sum(sh.kernels.interval_calls for sh in self.shards),
            sum(sh.kernels.interval_queries for sh in self.shards),
            sum(sh.kernels.bloom_calls for sh in self.shards),
            sum(sh.kernels.bloom_queries for sh in self.shards))

    def cache_snapshot(self) -> dict:
        snaps = [sh.cache.snapshot() for sh in self.shards]
        hits = sum(s["hits"] for s in snaps)
        misses = sum(s["misses"] for s in snaps)
        return {"hits": hits, "misses": misses,
                "hit_rate": hits / (hits + misses) if hits + misses else 0.0,
                "per_shard": snaps}

    def stats(self) -> dict:
        return {
            "num_shards": self.num_shards,
            "partition": self.router.partition,
            "entries": self.num_entries,
            "engine": self.stats_.snapshot(),
            "io": merge_io_snapshots(
                [sh.tree.io.snapshot() for sh in self.shards]),
            "cache": self.cache_snapshot(),
            "kernels": self.kernel_counters.snapshot(),
        }
