"""repro.engine: sharded, batched query execution over LSM-tree shards.

The layer between the serving runtime and the storage substrate,
organized as **plan -> submit -> collect**: typed columnar ``OpBatch``es
— point lookups, writes, range scans, and range deletes — are compiled
by a ``Planner`` into per-shard ``ShardPlan``s (vectorized routing,
range clipping, same-kind run grouping), launched by ``Engine.submit``
(concurrently across shards when pipelining is on), and merged back in
request order by the returned ``PendingBatch``.  Read batches execute
through the fused Pallas filter stage (Bloom + DR-tree interval kernels,
for point gets and scan validity alike), charge I/O through a
read-through block cache, and roll per-shard ledgers up into
per-op-class engine stats with per-shard wall/stall times.

Public surface: ``Engine`` (the façade), ``OpBatch`` / ``Planner`` /
``Plan`` / ``ShardPlan`` (typed batches + compilation), ``PendingBatch``
(collection), ``EngineConfig`` (execution knobs), ``ShardRouter``
(partitioning), ``ShardExecutor`` (per-shard batched paths),
``BlockCache``, and the stats types.
"""

from .cache import BlockCache
from .engine import Engine
from .executor import EngineConfig, ShardExecutor
from .pending import PendingBatch
from .procpool import ProcPool, ProcShard, WorkerSpec
from .plan import (KIND_CODES, KIND_NAMES, OP_DELETE, OP_GET, OP_PUT,
                   OP_RANGE_DELETE, OP_RANGE_SCAN, OpBatch, Plan, Planner,
                   PlanStep, ShardPlan)
from .registry import CascadeView, DeviceFilterRegistry
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots

__all__ = ["BlockCache", "Engine", "EngineConfig", "ShardExecutor",
           "ShardRouter", "EngineStats", "KernelCounters",
           "merge_io_snapshots", "OpBatch", "Plan", "Planner", "PlanStep",
           "ShardPlan", "PendingBatch", "ProcPool", "ProcShard",
           "WorkerSpec", "CascadeView",
           "DeviceFilterRegistry", "KIND_CODES", "KIND_NAMES",
           "OP_PUT", "OP_DELETE", "OP_GET", "OP_RANGE_DELETE",
           "OP_RANGE_SCAN"]
