"""repro.engine: sharded, batched query execution over LSM-tree shards.

The layer between the serving runtime and the storage substrate: routes
vectorized op batches — point lookups, writes, range scans, and range
deletes — across N ``LSMTree`` shards, executes read batches through the
fused Pallas filter stage (Bloom + DR-tree interval kernels, for point
gets and scan validity alike), charges I/O through a read-through block
cache, and rolls per-shard ledgers up into per-op-class engine stats.

Public surface: ``Engine`` (the façade), ``EngineConfig`` (execution
knobs), ``ShardRouter`` (partitioning), ``ShardExecutor`` (per-shard
batched paths), ``BlockCache``, and the stats types.
"""

from .cache import BlockCache
from .engine import Engine
from .executor import EngineConfig, ShardExecutor
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots

__all__ = ["BlockCache", "Engine", "EngineConfig", "ShardExecutor",
           "ShardRouter", "EngineStats", "KernelCounters",
           "merge_io_snapshots"]
