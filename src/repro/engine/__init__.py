"""repro.engine: sharded, batched query execution over LSM-tree shards.

The layer between the serving runtime and the storage substrate: routes
vectorized op batches across N ``LSMTree`` shards, executes point-lookup
batches through the fused Pallas filter stage (Bloom + DR-tree interval
kernels), charges I/O through a read-through block cache, and rolls
per-shard ledgers up into engine-level stats.
"""

from .cache import BlockCache
from .engine import Engine
from .executor import EngineConfig, ShardExecutor
from .router import ShardRouter
from .stats import EngineStats, KernelCounters, merge_io_snapshots

__all__ = ["BlockCache", "Engine", "EngineConfig", "ShardExecutor",
           "ShardRouter", "EngineStats", "KernelCounters",
           "merge_io_snapshots"]
