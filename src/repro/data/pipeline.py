"""Deterministic, shardable, checkpointable synthetic data pipeline.

Produces token batches from a counter-based RNG (threefry on (seed, step,
host_shard)): any batch is reproducible from (seed, step) alone, so the
pipeline state checkpoint is just two integers — restart-safe and
elastic (a different host count re-slices the same global batch).

This stands in for a tokenized corpus reader; the interface (``next()``,
``state()``, ``restore()``, per-host sharding) is the production one.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PipelineConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    emit_embeddings: bool = False  # stub-frontend archs
    d_model: int = 0


def _batch_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step,)))


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.step = 0

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def next(self) -> dict:
        """Next per-host batch: {tokens|embeds, labels}."""
        cfg = self.cfg
        rng = _batch_rng(cfg.seed, self.step)
        # Draw the GLOBAL batch deterministically, slice this host's rows:
        # elastic restarts with different n_hosts see identical data.
        if cfg.emit_embeddings:
            glob = rng.standard_normal(
                (cfg.global_batch, cfg.seq_len, cfg.d_model),
                dtype=np.float32)
            labels = rng.integers(0, cfg.vocab,
                                  size=(cfg.global_batch, cfg.seq_len),
                                  dtype=np.int32)
        else:
            glob = rng.integers(0, cfg.vocab,
                                size=(cfg.global_batch, cfg.seq_len),
                                dtype=np.int32)
            # Labels are a fixed bijection of the tokens: a learnable
            # stand-in for next-token targets.  (Independent random labels
            # would make the irreducible loss ln(vocab) — nothing to
            # learn, so training smoke tests could only pass by noise.)
            labels = (glob + 1) % cfg.vocab
        lo = cfg.host_id * self.host_batch
        hi = lo + self.host_batch
        self.step += 1
        key = "embeds" if cfg.emit_embeddings else "tokens"
        return {key: glob[lo:hi], "labels": labels[lo:hi]}

    # ------------------------------------------------------- checkpointing
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(state["step"])
