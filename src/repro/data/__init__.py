from .pipeline import PipelineConfig, TokenPipeline
from .versioned_store import VersionedSampleStore

__all__ = ["PipelineConfig", "TokenPipeline", "VersionedSampleStore"]
