"""Versioned dataset store on the GLORAN LSM-tree — the paper's own
motivating example ("discarding outdated dataset versions in machine
learning pipelines", §1).

Keys encode (version << 40 | sample_id); publishing a new version writes
its samples; ``purge_version`` is ONE range delete — O(log) instead of
millions of point tombstones — and readers' point lookups stay fast
because GLORAN keeps range records out of the lookup path (Table 2).
"""

from __future__ import annotations

import numpy as np

from ..core.gloran import GloranConfig
from ..lsm import LSMConfig, LSMTree

VERSION_SHIFT = 40


class VersionedSampleStore:
    def __init__(self, strategy: str = "gloran",
                 lsm_config: LSMConfig | None = None,
                 gloran_config: GloranConfig | None = None):
        self.tree = LSMTree(lsm_config or LSMConfig(buffer_capacity=4096),
                            strategy=strategy, gloran_config=gloran_config)
        self.live_versions: set[int] = set()
        self._max_sample: dict[int, int] = {}

    @staticmethod
    def key(version: int, sample_id: int) -> int:
        assert sample_id < (1 << VERSION_SHIFT)
        return (version << VERSION_SHIFT) | sample_id

    def publish(self, version: int, sample_ids: np.ndarray,
                payloads: np.ndarray) -> None:
        keys = (np.uint64(version) << np.uint64(VERSION_SHIFT)) | \
            np.asarray(sample_ids, dtype=np.uint64)
        self.tree.put_batch(keys, np.asarray(payloads, dtype=np.uint64))
        self.live_versions.add(version)
        hi = int(np.asarray(sample_ids).max())
        self._max_sample[version] = max(self._max_sample.get(version, 0),
                                        hi)

    def purge_version(self, version: int) -> None:
        """One range delete retires the whole version.

        The range is bounded by the version's max sample id so that
        point-delete baselines (Decomp/Lookup&D) stay tractable — they
        must touch every key in the range, which is the paper's point."""
        lo = version << VERSION_SHIFT
        hi = lo + self._max_sample.get(version, 0) + 1
        self.tree.range_delete(lo, hi)
        self.live_versions.discard(version)

    def get(self, version: int, sample_id: int):
        return self.tree.get(self.key(version, sample_id))

    def get_batch(self, version: int, sample_ids: np.ndarray):
        keys = (np.uint64(version) << np.uint64(VERSION_SHIFT)) | \
            np.asarray(sample_ids, dtype=np.uint64)
        return self.tree.get_batch(keys)

    def scan_version(self, version: int):
        lo = version << VERSION_SHIFT
        return self.tree.range_scan(lo, lo + (1 << VERSION_SHIFT))
