"""Serving example: batched decode with a GLORAN session registry.

A small LM serves batched requests while per-session state records live in
the LSM KV store; tenant/expiry churn issues range deletes.  Compares
registry lookup I/O under GLORAN vs RocksDB-style range tombstones (LRR).

    PYTHONPATH=src python examples/serve_kv_sessions.py
"""

import numpy as np

from repro.configs import get_config, smoke
from repro.engine import EngineConfig
from repro.models import Transformer
from repro.runtime import ServeLoop, SessionRegistry

model = Transformer(smoke(get_config("chatglm3-6b")))
rng = np.random.default_rng(0)
B = 4

for strategy in ("lrr", "gloran"):
    reg = SessionRegistry(strategy=strategy)
    # A fleet's worth of sessions; most expire in ranges (tenant churn).
    for sid in range(5000):
        reg.register(sid, np.arange(4), np.arange(4) + sid)
    for lo in range(0, 4000, 100):
        reg.expire_range(lo, lo + 60)
    reg.flush()

    live = np.asarray([4100, 4200, 4300, 4400], dtype=np.uint64)
    loop = ServeLoop(model, batch=B, max_len=64, registry=reg)
    prompts = rng.integers(0, model.cfg.vocab, size=(B, 8)).astype(np.int32)
    out = loop.run(prompts, steps=16, session_ids=live)
    per_lookup = loop.stats.registry_io_reads / max(
        1, loop.stats.registry_lookups)
    print(f"{strategy:8s}: generated {out.shape} tokens, registry "
          f"{per_lookup:.3f} I/Os per lookup, "
          f"{loop.stats.tokens_generated / loop.stats.wall_seconds:.0f} "
          f"tok/s")

# The same registry sharded 4 ways through the batched query engine: hot
# lookups are absorbed by the per-shard block caches, the scheduler's
# page probes run as one vectorized batch per shard, and the serve loop
# submits each step's lookups (plan/submit/collect) so the decode step
# overlaps with pipelined shard execution.
reg = SessionRegistry(strategy="gloran", num_shards=4,
                      engine_config=EngineConfig(cache_blocks=4096))
for sid in range(5000):
    reg.register(sid, np.arange(4), np.arange(4) + sid)
for lo in range(0, 4000, 100):
    reg.expire_range(lo, lo + 60)
reg.flush()
live = np.asarray([4100, 4200, 4300, 4400], dtype=np.uint64)
loop = ServeLoop(model, batch=B, max_len=64, registry=reg)
prompts = rng.integers(0, model.cfg.vocab, size=(B, 8)).astype(np.int32)
loop.run(prompts, steps=16, session_ids=live)
per_lookup = loop.stats.registry_io_reads / max(
    1, loop.stats.registry_lookups)
cache = reg.engine.cache_snapshot()
snap = reg.engine.stats()["engine"]
print(f"engine x4: registry {per_lookup:.3f} I/Os per lookup, "
      f"block-cache hit rate {cache['hit_rate']:.2f}")
print(f"engine x4: {snap['pipelined_batches']} pipelined batches, "
      f"registry collect blocked "
      f"{1e3 * loop.stats.registry_stall_seconds:.1f} ms total "
      f"(decode ran while shards executed)")

print("serve_kv_sessions OK")
