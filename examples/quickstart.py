"""Quickstart: the GLORAN LSM key-value store in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.baselines import make_tree

# An LSM-tree KV store with the paper's global range-delete index.
tree = make_tree("gloran", universe=1 << 20)

# Writes.
keys = np.arange(0, 100_000, dtype=np.uint64)
tree.put_batch(keys, keys * np.uint64(2))

# Point reads.
assert tree.get(4242) == 8484

# ONE range delete removes 10k keys (vs 10k tombstones under Decomp).
tree.range_delete(40_000, 50_000)
assert tree.get(45_000) is None
assert tree.get(51_000) == 102_000

# Temporal correctness (§4.1): re-insert after the delete stays visible.
tree.put(45_000, 7)
assert tree.get(45_000) == 7

# Range scan skips deleted ranges.
ks, vs = tree.range_scan(39_990, 40_010)
assert ks.tolist() == list(range(39_990, 40_000))

# The I/O ledger is the paper's cost model — compare strategies:
for strategy in ("lrr", "gloran"):
    t = make_tree(strategy, universe=1 << 20)
    t.put_batch(keys, keys)
    for lo in range(0, 500_000 // 8, 640):
        t.range_delete(lo, lo + 64)
    t.flush()
    r0 = t.io.reads
    t.get_batch(np.random.default_rng(0).integers(
        0, 1 << 20, size=2000).astype(np.uint64))
    print(f"{strategy:8s}: {(t.io.reads - r0) / 2000:.3f} I/Os per lookup")

print("quickstart OK")
