"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
CPU through the full production stack — config, pipeline, fault-tolerant
loop, async checkpoints, resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch gemma3-1b]

The config is a width-reduced member of the chosen arch family sized to
~100M params (CPU-runnable); the loop/checkpoint/optimizer code paths are
exactly the ones the dry-run lowers at full scale.
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data import PipelineConfig, TokenPipeline
from repro.models import Transformer, count_params
from repro.optim import OptimizerConfig
from repro.runtime import TrainLoopConfig, run_training


SIZES = {
    # ~6 min on one CPU core; "100m" is the full-size run (slow on CPU,
    # the production target is the dry-run mesh).
    "small": dict(n_layers=4, d_model=384, d_ff=1536, n_heads=8),
    "100m": dict(n_layers=10, d_model=640, d_ff=2560, n_heads=10),
}


def reduced(arch: str, size: str):
    cfg = get_config(arch)
    s = SIZES[size]
    return dataclasses.replace(
        cfg, n_layers=s["n_layers"], d_model=s["d_model"],
        n_heads=s["n_heads"], n_kv_heads=min(s["n_heads"], cfg.n_kv_heads)
        or s["n_heads"], head_dim=64, d_ff=s["d_ff"],
        vocab=32_000, moe=None, family="dense" if cfg.family in
        ("moe", "vlm", "audio") else cfg.family,
        stub_frontend=None, local_global=cfg.local_global,
        local_window=64 if cfg.local_window else None,
        window=256 if cfg.window else None, dtype="float32",
        optimizer="adamw", sharding_overrides={})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--size", default="small", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_train_lm_<arch>_<size>")
    args = ap.parse_args()
    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_train_lm_{args.arch}_{args.size}"

    cfg = reduced(args.arch, args.size)
    model = Transformer(cfg)
    n = count_params(model.param_specs())
    print(f"arch family: {cfg.family}  params: {n / 1e6:.1f}M")

    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq,
        seed=0))
    res = run_training(
        model, pipe,
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=50,
                        checkpoint_dir=args.ckpt_dir, log_every=10),
        opt_cfg=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                decay_steps=args.steps))
    print(f"steps: {res.final_step}  "
          f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f}  "
          f"(resumed_from={res.resumed_from})")
    assert res.losses[-1] < res.losses[0]
    print("train_lm OK")


if __name__ == "__main__":
    main()
