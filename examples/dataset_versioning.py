"""The paper's ML-pipeline motivation: dataset-version purging.

A training data service stores (version, sample) records; retiring a
version is ONE range delete under GLORAN.  The reader path (point lookups
by the data pipeline) stays fast regardless of how many versions have
been purged.

    PYTHONPATH=src python examples/dataset_versioning.py
"""

import numpy as np

from repro.data import VersionedSampleStore

for strategy in ("decomp", "lrr", "gloran"):
    store = VersionedSampleStore(strategy=strategy)
    rng = np.random.default_rng(1)

    # Publish 8 dataset versions of 20k samples each.
    for v in range(8):
        store.publish(v, np.arange(20_000), rng.integers(
            1, 1 << 40, size=20_000))

    # Retire versions 0-5 (keep the two newest).
    w0 = store.tree.io.total
    for v in range(6):
        store.purge_version(v)
    purge_io = store.tree.io.total - w0
    store.tree.flush()

    # Reader: random access into the live versions.
    r0 = store.tree.io.reads
    found, _ = store.get_batch(7, rng.integers(0, 20_000, size=5000))
    assert found.all()
    read_io = (store.tree.io.reads - r0) / 5000
    print(f"{strategy:8s}: purge cost {purge_io:7d} I/Os, reader "
          f"{read_io:7.3f} I/Os per lookup")

print("dataset_versioning OK")
